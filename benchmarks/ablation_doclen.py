"""Ablation: atomic-edit speedup vs document length.

The engine's per-edit cost is O(n·L·d) (column patches over later rows)
while the dense baseline is O(n²·L·d + n·L·d²), so the speedup should grow
roughly linearly in n once attention dominates — the structural reason the
paper's 2048-token documents show 12.1X while short docs show less.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results, make_vqt_engine, write_csv
from repro.core.edits import Edit
from repro.core.positional import PositionAllocator
from repro.data import SyntheticCorpus


def run(lengths=(128, 256, 512, 1024), n_edits=12, seed=0):
    eng, cfg, counter = make_vqt_engine(seed)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=seed)
    rng = np.random.default_rng(seed)
    rows = []
    for n in lengths:
        tokens = list(corpus.document(n, 0))
        alloc = PositionAllocator(n, cfg.pos_pool)
        base = eng.full_forward(tokens, alloc.positions)
        dense = dense_ops_for(cfg, n)
        sp = []
        for _ in range(n_edits):
            p = int(rng.integers(0, n))
            before = counter.total
            eng.apply_replaces(base, [p], [int(rng.integers(cfg.vocab))])
            sp.append(dense / max(counter.total - before, 1))
        rows.append((n, round(float(np.median(sp)), 2)))
    write_csv(f"{ensure_results()}/ablation_doclen.csv",
              ["doc_len", "median_speedup"], rows)
    for n, s in rows:
        print(f"  n={n:5d}: {s:8.1f}X")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lengths", type=int, nargs="+", default=[128, 256, 512, 1024])
    ap.add_argument("--edits", type=int, default=12)
    args = ap.parse_args()
    run(tuple(args.lengths), args.edits)


if __name__ == "__main__":
    main()
