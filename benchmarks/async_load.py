"""Concurrent-load benchmark for the deadline-batching async front end
(ISSUE 6 tentpole).

Client threads (one per document) drive ``AsyncBatchServer`` under two
traffic shapes:

* ``burst``       — each client submits its whole edit burst, then asks for
  one suggestion: the deadline batcher's best case (bursts coalesce into
  few dispatch rounds);
* ``interactive`` — each client alternates single edit -> blocking
  suggestion: the latency-bound worst case (every round is small, the
  per-request SLO dominates).

Both shapes are compared token-exactly against a sequential replay of the
same per-document request streams on the same ``BatchServer`` — the gated
bits (``tokens_match``, ``suggestions_match``, ``edits_applied``) are
deterministic because threads own disjoint documents and each document's
stream comes from the seeded ``TrafficGenerator`` in ``data/edit_stream``
(shared with ``benchmarks.fleet_load``). Latency percentiles (admission-to-completion, from
``BatchStats.edit_latency`` / ``suggest_latency``), throughput and round
accounting are reported but never gated (runner noise).

Timing protocol: a warmup pass runs the identical workload on scratch
documents first (compiles every dispatch/refresh shape), then the latency
histograms are reset and the timed pass runs on fresh documents — the same
discipline as ``benchmarks.suggest_reuse``.

Emits ``results/BENCH_async_load.json`` plus name,value CSV lines.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import ensure_results


def _submit(server, doc_id: str, op) -> object:
    kind, pos, tok = op
    if kind == "insert":
        return server.submit_insert(doc_id, pos, tok)
    if kind == "delete":
        return server.submit_delete(doc_id, pos)
    return server.submit_replace(doc_id, pos, tok)


def run(n_docs: int = 3, doc_len: int = 24, n_edits: int = 6,
        n_new: int = 4, seed: int = 0, max_batch_delay_ms: float = 5.0,
        warmup: bool = True) -> list[dict]:
    import jax

    from repro.common.compile_cache import enable_persistent_compilation_cache
    from repro.configs.vq_opt_125m import smoke_config
    from repro.data.edit_stream import TrafficGenerator
    from repro.models import transformer as T
    from repro.serving.async_server import AsyncBatchServer
    from repro.serving.batch_server import BatchServer
    from repro.serving.latency import LatencyStats

    enable_persistent_compilation_cache()  # no-op unless the env var is set
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=32,
                      max_batch=max(n_docs, 2), min_doc_capacity=16)

    records = []
    traffic = TrafficGenerator(vocab=cfg.vocab, n_docs=n_docs,
                               doc_len=doc_len, seed=seed)
    for scenario in ("burst", "interactive"):
        # identical seeded streams for warmup / timed / oracle replays
        def make_docs(tag):
            docs = {}
            for i in range(n_docs):
                ref = traffic.base_document(i)
                ops = traffic.session_ops(i, n_edits, list(ref))
                docs[f"{scenario}_{tag}_{i}"] = (ref, ops)
            return docs

        def drive(asrv, doc_id, ops, out):
            if scenario == "burst":
                for op in ops:
                    _submit(asrv, doc_id, op)
                out.append(asrv.suggest(doc_id, n_new).result(600))
            else:  # interactive: edit -> blocking suggestion, per keystroke
                for op in ops:
                    _submit(asrv, doc_id, op)
                    out.append(asrv.suggest(doc_id, n_new).result(600))

        phases = (("warm", False),) if warmup else ()
        phases += (("timed", True),)
        for tag, timed in phases:
            docs = make_docs(tag)
            if timed:
                # fresh histograms: warmup latencies include jit compiles
                srv.stats.edit_latency = LatencyStats()
                srv.stats.suggest_latency = LatencyStats()
            suggestions = {d: [] for d in docs}
            t0 = time.perf_counter()
            with AsyncBatchServer(
                    srv, max_batch_delay_ms=max_batch_delay_ms) as asrv:
                for t in [asrv.open_document(d, ref)
                          for d, (ref, _) in docs.items()]:
                    t.result(600)
                threads = [threading.Thread(
                    target=drive, args=(asrv, d, ops, suggestions[d]))
                    for d, (_, ops) in docs.items()]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                final = {d: asrv.tokens(d).result(600) for d in docs}
                astats = asrv.stats
            wall_s = time.perf_counter() - t0
            if not timed:
                for d in docs:
                    srv.close_document(d)
                continue

            # sequential oracle: same per-document streams, same server
            tokens_match = True
            suggestions_match = True
            for d, (ref, ops) in docs.items():
                oid = f"{d}_oracle"
                srv.open_document(oid, ref)
                want_sugg = []
                if scenario == "burst":
                    for op in ops:
                        _submit(srv, oid, op)
                    want_sugg.append(srv.suggest(oid, n_new))
                else:
                    for op in ops:
                        _submit(srv, oid, op)
                        want_sugg.append(srv.suggest(oid, n_new))
                tokens_match &= bool(
                    np.array_equal(final[d], srv.tokens(oid)))
                suggestions_match &= len(want_sugg) == len(suggestions[d])
                suggestions_match &= all(
                    np.array_equal(g, w)
                    for g, w in zip(suggestions[d], want_sugg))
                srv.close_document(oid)
            for d in docs:
                srv.close_document(d)

            total_edits = n_docs * n_edits
            el, sl = srv.stats.edit_latency, srv.stats.suggest_latency
            rec = {
                "scenario": scenario,
                "n_docs": n_docs,
                "doc_len": doc_len,
                "n_edits": n_edits,
                "n_new": n_new,
                "max_batch_delay_ms": max_batch_delay_ms,
                "tokens_match": tokens_match,
                "suggestions_match": suggestions_match,
                "edits_applied": astats.admitted_edits,
                "suggests_served": astats.admitted_suggests,
                "rounds": astats.rounds,
                "deadline_rounds": astats.deadline_rounds,
                "full_rounds": astats.full_rounds,
                "mean_edits_per_round": astats.mean_edits_per_round,
                "requests_failed": astats.requests_failed,
                # wall-clock: reported, never gated
                "wall_s": wall_s,
                "edits_per_s": total_edits / max(wall_s, 1e-9),
                "edit_latency": el.summary(),
                "suggest_latency": sl.summary(),
            }
            records.append(rec)
            print(f"async_load,{scenario},edits_per_s,"
                  f"{rec['edits_per_s']:.1f}")
            print(f"async_load,{scenario},edit_p99_ms,"
                  f"{el.p99:.1f}")
            print(f"async_load,{scenario},suggest_p99_ms,"
                  f"{sl.p99:.1f}")
            print(f"async_load,{scenario},mean_edits_per_round,"
                  f"{rec['mean_edits_per_round']:.2f}")

    out = os.path.join(ensure_results(), "BENCH_async_load.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"async_load,written,{out}")
    return records


if __name__ == "__main__":
    run()
