"""Paper §3.1–3.2 batch claim: "any batch operation in the network can be
computed with an equivalent complexity to processing a single document".

We process a batch of b revisions of one document through the engine
(shared base + per-revision deltas, the compressed 'base + sparse index
deltas' representation of fig. 2 in execution form) and report
ops(batch) / ops(single) versus b. Dense cost grows as b; the compressed
path should stay near-flat (1 + b·edit_fraction·const).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results, make_vqt_engine, write_csv
from repro.core.edits import random_revision
from repro.core.positional import PositionAllocator
from repro.data import SyntheticCorpus


def run(doc_len=384, max_batch=16, edit_fraction=0.02, seed=0):
    eng, cfg, counter = make_vqt_engine(seed)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=seed)
    base_doc = corpus.document(doc_len, 0)
    rng = np.random.default_rng(seed)
    revisions = [
        np.asarray(random_revision(rng, base_doc, cfg.vocab, edit_fraction))
        for _ in range(max_batch)
    ]

    # cost of one document from scratch (the unit)
    alloc = PositionAllocator(doc_len, cfg.pos_pool)
    counter.counts.clear()
    base_state = eng.full_forward(base_doc, alloc.positions)
    single = counter.total

    rows = []
    for b in (1, 2, 4, 8, 16):
        if b > max_batch:
            break
        counter.counts.clear()
        st = eng.full_forward(base_doc, alloc.positions)  # shared base
        a2 = PositionAllocator(doc_len, cfg.pos_pool)
        for r in range(b):
            a2.positions = list(alloc.positions)
            eng.apply_revision(st, revisions[r], a2)
        batch_ops = counter.total
        dense_batch = b * dense_ops_for(cfg, doc_len)
        rows.append((
            b,
            round(batch_ops / single, 3),  # compressed: vs 1 document
            round(dense_batch / single, 3),  # dense: grows as b
        ))
    write_csv(f"{ensure_results()}/batch_scaling.csv",
              ["batch", "compressed_rel_ops", "dense_rel_ops"], rows)
    for b, c, d in rows:
        print(f"  b={b:3d}: compressed {c:7.2f}x single-doc  (dense {d:7.2f}x)")
    growth = (rows[-1][1] - rows[0][1]) / (rows[-1][0] - rows[0][0])
    print(f"per-extra-revision marginal cost: {growth:.3f} of a full document "
          f"(paper claim: ~edit-fraction-proportional, here frac={edit_fraction})")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--doc-len", type=int, default=384)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--fraction", type=float, default=0.02)
    args = ap.parse_args()
    run(args.doc_len, args.max_batch, args.fraction)


if __name__ == "__main__":
    main()
