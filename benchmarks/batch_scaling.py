"""Paper §3.1–3.2 batch claim: "any batch operation in the network can be
computed with an equivalent complexity to processing a single document".

Two measurements:

1. **op-count** (the paper's metric): a batch of b revisions of one document
   through the NumPy engine (shared base + per-revision deltas) —
   ops(batch) / ops(single) versus b. Dense cost grows as b; the compressed
   path stays near-flat (1 + b·edit_fraction·const).
2. **wall-clock, batched jit path** (ISSUE 1 tentpole): b independent
   documents each with one pending replace-edit, served by ONE vmapped
   fixed-shape `batch_apply_replaces` dispatch. Reported as per-edit
   wall-clock relative to the single-document jit step — the acceptance bar
   is ≤ 1.5x at batch ≥ 8.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results, make_vqt_engine, write_csv
from repro.core.edits import random_revision
from repro.core.positional import PositionAllocator
from repro.data import SyntheticCorpus


def run(doc_len=384, max_batch=16, edit_fraction=0.02, seed=0):
    eng, cfg, counter = make_vqt_engine(seed)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=seed)
    base_doc = corpus.document(doc_len, 0)
    rng = np.random.default_rng(seed)
    revisions = [
        np.asarray(random_revision(rng, base_doc, cfg.vocab, edit_fraction))
        for _ in range(max_batch)
    ]

    # cost of one document from scratch (the unit)
    alloc = PositionAllocator(doc_len, cfg.pos_pool)
    counter.counts.clear()
    base_state = eng.full_forward(base_doc, alloc.positions)
    single = counter.total

    rows = []
    for b in (1, 2, 4, 8, 16):
        if b > max_batch:
            break
        counter.counts.clear()
        st = eng.full_forward(base_doc, alloc.positions)  # shared base
        a2 = PositionAllocator(doc_len, cfg.pos_pool)
        for r in range(b):
            a2.positions = list(alloc.positions)
            eng.apply_revision(st, revisions[r], a2)
        batch_ops = counter.total
        dense_batch = b * dense_ops_for(cfg, doc_len)
        rows.append((
            b,
            round(batch_ops / single, 3),  # compressed: vs 1 document
            round(dense_batch / single, 3),  # dense: grows as b
        ))
    write_csv(f"{ensure_results()}/batch_scaling.csv",
              ["batch", "compressed_rel_ops", "dense_rel_ops"], rows)
    for b, c, d in rows:
        print(f"  b={b:3d}: compressed {c:7.2f}x single-doc  (dense {d:7.2f}x)")
    growth = (rows[-1][1] - rows[0][1]) / (rows[-1][0] - rows[0][0])
    print(f"per-extra-revision marginal cost: {growth:.3f} of a full document "
          f"(paper claim: ~edit-fraction-proportional, here frac={edit_fraction})")
    return rows


def run_jit_batched(doc_len=256, batches=(1, 2, 4, 8, 16), edit_capacity=4,
                    row_capacity=64, seed=1, iters=20):
    """Wall-clock of the batched jit path: per-edit time vs the single-doc
    jit step (each document carries one distinct edit per dispatch)."""
    from benchmarks.common import batched_step_wallclock

    t_single, rows = batched_step_wallclock(
        doc_len, batches, edit_capacity=edit_capacity,
        row_capacity=row_capacity, seed=seed, iters=iters, random_edits=True,
        csv_name="batch_scaling_jit.csv", per_label="per-edit")
    worst_big = max((r[3] for r in rows if r[0] >= 8), default=None)
    if worst_big is not None:
        verdict = "PASS" if worst_big <= 1.5 else "FAIL"
        print(f"  per-edit at batch>=8: {worst_big:.2f}x single-doc step "
              f"(bar: 1.5x) -> {verdict}")
    return t_single, rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--doc-len", type=int, default=384)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--fraction", type=float, default=0.02)
    ap.add_argument("--jit-doc-len", type=int, default=256)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 2, 4, 8, 16])
    ap.add_argument("--skip-opcount", action="store_true")
    ap.add_argument("--skip-jit", action="store_true")
    args = ap.parse_args()
    if not args.skip_opcount:
        print("op-count (NumPy engine, batch of revisions):")
        run(args.doc_len, args.max_batch, args.fraction)
    if not args.skip_jit:
        print("wall-clock (batched jit engine, one edit per document):")
        run_jit_batched(args.jit_doc_len, tuple(args.batches))


if __name__ == "__main__":
    main()
