"""Benchmark regression gate (ISSUE 4 satellite).

Compares fresh benchmark emissions (``results/BENCH_*.json``) against
committed baselines (``results/BASELINE_*.json``) and exits non-zero when a
gated metric regresses beyond its stated tolerance — CI runs this after the
benchmark smoke steps, so a PR cannot silently trade away ops-saved ratio,
prefill reuse, or oracle exactness.

Gate policy:

* only DETERMINISTIC metrics are gated (op counts, reuse fractions,
  traced-shape counts, oracle-match booleans) — wall-clock fields are
  reported but never gated (CI runner noise). The ONE exception is
  same-runner wall-clock *ratios* (``refresh_to_oracle_ratio``): both legs
  run interleaved on the same machine in the same process with synced,
  warmed timing, so runner speed divides out — gated with a wide abs_tol
  plus a hard ``must_be_lt`` ceiling encoding the SLO itself ("incremental
  refresh beats the from-scratch oracle");
* direction-aware: a metric only fails in its *worse* direction, beyond
  ``max(abs_tol, rel_tol * baseline)``; improvements always pass (and are
  listed, so a re-anchor can ratchet the baseline);
* identity fields (workload, doc_len, n_edits, ...) must match the baseline
  exactly — a param drift between CI and the committed baseline is a gate
  misconfiguration, reported as an error rather than a pass.

Usage::

    python -m benchmarks.check_regression            # gate (exit 1 on fail)
    python -m benchmarks.check_regression --update   # re-anchor baselines
    python -m benchmarks.check_regression --results-dir path/to/results

Re-anchoring: run the benchmarks at the gate params (see .github/workflows/
ci.yml), inspect the fresh numbers, then ``--update`` to copy every gated
``BENCH_*.json`` over its ``BASELINE_*.json``. ``results/SUMMARY.json``
(written by ``benchmarks.run``) carries the same records for full-protocol
re-anchors.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

# metric -> {higher_is_better, rel_tol, abs_tol} | {must_equal};
# an optional must_be_lt adds a hard ceiling on top of the baseline delta
# check (fails when fresh >= ceiling, regardless of the baseline)
GATES = {
    "edit_mix": {
        "bench": "BENCH_edit_mix.json",
        "baseline": "BASELINE_edit_mix.json",
        "key": "workload",
        "identity": ("doc_len", "n_edits"),
        "metrics": {
            "ops_speedup": {"higher_is_better": True, "rel_tol": 0.10},
            "ops_incremental": {"higher_is_better": False, "rel_tol": 0.10},
            "traced_shapes": {"higher_is_better": False, "abs_tol": 2},
            # ISSUE 7: the warm measured pass must compile NOTHING (the
            # warmup replays the identical trace) ...
            "measured_pass_new_shapes": {"must_equal": 0},
            # ... and a structural stream must stay within a small factor
            # of the replace-only fast path — same-runner wall-clock
            # ratio (synced + warmup-replayed), so runner speed divides
            # out; the ceiling is the fused-ragged-hot-path SLO. Only the
            # mixed record carries it (it IS the cross-workload ratio).
            "wall_ratio_mixed_vs_replace": {
                "higher_is_better": False, "abs_tol": 0.75,
                "must_be_lt": 3.0, "optional": True},
        },
    },
    # ISSUE 7 satellite: the fused hot path's structural wins, read from
    # the compiled modules themselves (launch census, XLA cost model,
    # achieved-vs-roofline fraction) and from the scheduler's shape
    # counter — all deterministic for a pinned jax version (the bench-gate
    # job pins one; re-anchor on version bumps). Wall-clock never appears.
    "hot_path": {
        "bench": "BENCH_hot_path.json",
        "baseline": "BASELINE_hot_path.json",
        "key": "workload",
        "identity": ("doc_len",),
        "metrics": {
            "launches": {"higher_is_better": False, "rel_tol": 0.15,
                         "optional": True},
            "xla_flops": {"higher_is_better": False, "rel_tol": 0.10,
                          "optional": True},
            "useful_flop_fraction": {"higher_is_better": True,
                                     "rel_tol": 0.15, "optional": True},
            "compiled_shapes_structural_stream": {
                "higher_is_better": False, "abs_tol": 0, "optional": True},
            "kernel_launches_per_edit": {
                "higher_is_better": False, "abs_tol": 0.25,
                "optional": True},
            "device_grows": {"higher_is_better": True, "abs_tol": 0,
                             "optional": True},
        },
    },
    "suggest_reuse": {
        "bench": "BENCH_suggest_reuse.json",
        "baseline": "BASELINE_suggest_reuse.json",
        "key": "workload",
        "identity": ("doc_len", "n_edits", "n_new"),
        "metrics": {
            "reused_prefill_fraction": {
                "higher_is_better": True, "rel_tol": 0.10, "abs_tol": 0.02},
            "suggestions_match_oracle": {"must_equal": True},
            # ISSUE 6: the wall-clock SLO. A same-runner ratio of medians
            # (synced + warmed timing), so runner noise divides out; the
            # must_be_lt ceiling is the acceptance criterion itself —
            # incremental refresh must beat the from-scratch oracle.
            "refresh_to_oracle_ratio": {
                "higher_is_better": False, "abs_tol": 0.15,
                "must_be_lt": 1.0},
        },
    },
    # ISSUE 6 tentpole: deadline-batching async front end. Parity bits and
    # the exact admitted-edit count are deterministic (client threads own
    # disjoint documents, so per-document streams are schedule-independent);
    # latency percentiles and rounds are reported, never gated.
    "async_load": {
        "bench": "BENCH_async_load.json",
        "baseline": "BASELINE_async_load.json",
        "key": "scenario",
        "identity": ("n_docs", "doc_len", "n_edits", "n_new"),
        "metrics": {
            "tokens_match": {"must_equal": True},
            "suggestions_match": {"must_equal": True},
            "edits_applied": {"higher_is_better": True, "abs_tol": 0},
        },
    },
    # ISSUE 10 tentpole: multi-replica fleet behind the router, with a
    # forced cross-replica migration and a forced failover mid-run. The
    # exactness/leak bits and the chaos/ack counts are deterministic
    # (seeded schedule, deterministic placement). p99/throughput are
    # wall-clock — gated ONLY with cavernous tolerances that catch
    # order-of-magnitude serving regressions, never runner noise (the
    # repo-wide wall-clock policy stands; these are smoke ceilings).
    "fleet_load": {
        "bench": "BENCH_fleet_load.json",
        "baseline": "BASELINE_fleet_load.json",
        "key": "n_replicas",
        "identity": ("n_docs", "n_sessions", "doc_len", "n_new", "seed"),
        "metrics": {
            "tokens_exact": {"must_equal": True},
            "suggestions_exact": {"must_equal": True},
            "leak_free": {"must_equal": True},
            "migrations": {"higher_is_better": True, "abs_tol": 0},
            "failovers": {"higher_is_better": True, "abs_tol": 0},
            "edits_acked": {"higher_is_better": True, "abs_tol": 0},
            "hot_hit_rate": {"higher_is_better": True, "abs_tol": 0.02},
            "edit_p99_ms": {"higher_is_better": False, "rel_tol": 5.0},
            "edits_per_s": {"higher_is_better": True, "rel_tol": 0.9},
        },
    },
    # ISSUE 4's benchmark, gated since ISSUE 5: deterministic parity bits
    # and the scheduler's placement quality (run under 4 forced host
    # devices — see the bench-gate job's XLA_FLAGS)
    "sharded_serving": {
        "bench": "BENCH_sharded_serving.json",
        "baseline": "BASELINE_sharded_serving.json",
        "key": "mesh_size",
        "identity": ("doc_len", "n_docs", "n_edits"),
        "metrics": {
            "tokens_match": {"must_equal": True},
            "oracle_match": {"must_equal": True},
            "logits_close_vs_mesh1": {"must_equal": True},
            "mean_shard_imbalance": {"higher_is_better": False,
                                     "abs_tol": 0.05},
            "batch_dispatches": {"higher_is_better": False, "abs_tol": 2},
        },
    },
    # ISSUE 9: the sigma-delta Pareto curve. Everything here is a
    # deterministic function of the seeded trace (transmitted-row counts,
    # bitwise booleans, drift vs a from-scratch oracle) — no wall-clock.
    # The gate holds the curve's SHAPE: threshold 0 stays bitwise-exact,
    # ops stay monotone nonincreasing in threshold, drift stays under the
    # documented bound (delta_pareto.DRIFT_BOUND), and the max-threshold
    # leg keeps saving its baseline fraction of transmissions.
    "delta_pareto": {
        "bench": "BENCH_delta_pareto.json",
        "baseline": "BASELINE_delta_pareto.json",
        "key": "workload",
        "identity": ("doc_len", "n_edits", "thresholds"),
        "metrics": {
            "threshold0_bitwise": {"must_equal": True},
            "ops_monotone_nonincreasing": {"must_equal": True},
            "drift_within_bound": {"must_equal": True},
            "ops_saved_frac_max_threshold": {
                "higher_is_better": True, "abs_tol": 0.05},
        },
    },
    # ISSUE 5: tiered-store churn under a zipf stream. Counters are
    # deterministic under the seeded stream; rehydrate/full-forward
    # latencies are wall-clock and never gated.
    "state_churn": {
        "bench": "BENCH_state_churn.json",
        "baseline": "BASELINE_state_churn.json",
        "key": "workload",
        "identity": ("n_docs", "doc_len", "n_edits", "budget_docs", "n_new"),
        "metrics": {
            "hot_hit_rate": {"higher_is_better": True, "abs_tol": 0.02},
            "evictions": {"higher_is_better": False, "abs_tol": 2},
            "spills": {"higher_is_better": False, "abs_tol": 2},
            "rehydrations": {"higher_is_better": False, "abs_tol": 2},
            "oracle_match": {"must_equal": True},
            "leak_free": {"must_equal": True},
        },
    },
}


def _load(path: str):
    with open(path) as f:
        return json.load(f)


def _index(records: list, key: str) -> dict:
    return {rec[key]: rec for rec in records}


def check_gate(name: str, gate: dict, results_dir: str) -> list[str]:
    """Returns a list of failure strings (empty = gate passes)."""
    bench_path = os.path.join(results_dir, gate["bench"])
    base_path = os.path.join(results_dir, gate["baseline"])
    failures = []
    for path, kind in ((bench_path, "fresh benchmark"),
                       (base_path, "baseline")):
        if not os.path.exists(path):
            return [f"{name}: missing {kind} file {path}"]
    fresh = _index(_load(bench_path), gate["key"])
    base = _index(_load(base_path), gate["key"])
    for wk, brec in sorted(base.items()):
        frec = fresh.get(wk)
        if frec is None:
            failures.append(f"{name}/{wk}: workload missing from fresh run")
            continue
        for field in gate.get("identity", ()):
            if frec.get(field) != brec.get(field):
                failures.append(
                    f"{name}/{wk}: identity field {field} drifted "
                    f"({brec.get(field)} -> {frec.get(field)}) — regenerate "
                    "the baseline or fix the CI invocation")
        for metric, rule in gate["metrics"].items():
            have, want = frec.get(metric), brec.get(metric)
            if have is None and want is None and rule.get("optional"):
                continue  # metric legitimately absent from this workload
            if have is None or want is None:
                failures.append(f"{name}/{wk}: metric {metric} missing "
                                f"(fresh={have!r}, baseline={want!r})")
                continue
            if "must_equal" in rule:
                ok = have == rule["must_equal"]
                verdict = "ok" if ok else "REGRESSED"
                print(f"  {name}/{wk}.{metric}: {have} "
                      f"(required {rule['must_equal']}) {verdict}")
                if not ok:
                    failures.append(
                        f"{name}/{wk}: {metric}={have}, must equal "
                        f"{rule['must_equal']}")
                continue
            tol = max(rule.get("abs_tol", 0.0),
                      rule.get("rel_tol", 0.0) * abs(float(want)))
            delta = float(have) - float(want)
            worse = -delta if rule["higher_is_better"] else delta
            ok = worse <= tol
            ceiling = rule.get("must_be_lt")
            if ceiling is not None and not float(have) < ceiling:
                ok = False
                failures.append(
                    f"{name}/{wk}: {metric}={have} breaches the hard "
                    f"ceiling (must be < {ceiling})")
            verdict = "ok" if ok else "REGRESSED"
            ceil_note = f", ceiling {ceiling}" if ceiling is not None else ""
            print(f"  {name}/{wk}.{metric}: {have} vs baseline {want} "
                  f"(tol {tol:.4g}{ceil_note}) {verdict}")
            if worse > tol:
                failures.append(
                    f"{name}/{wk}: {metric} regressed {want} -> {have} "
                    f"(worse by {worse:.4g} > tol {tol:.4g})")
    return failures


def update_baselines(results_dir: str) -> int:
    rc = 0
    for name, gate in GATES.items():
        src = os.path.join(results_dir, gate["bench"])
        dst = os.path.join(results_dir, gate["baseline"])
        if not os.path.exists(src):
            print(f"{name}: cannot re-anchor, {src} missing")
            rc = 2
            continue
        shutil.copyfile(src, dst)
        print(f"{name}: {src} -> {dst}")
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "results"))
    ap.add_argument("--update", action="store_true",
                    help="copy fresh BENCH files over the BASELINE files")
    args = ap.parse_args(argv)
    if args.update:
        return update_baselines(args.results_dir)
    all_failures = []
    for name, gate in GATES.items():
        print(f"gate {name}:")
        all_failures += check_gate(name, gate, args.results_dir)
    if all_failures:
        print("\nREGRESSIONS:")
        for f in all_failures:
            print(f"  {f}")
        return 1
    print("\nall benchmark gates green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
