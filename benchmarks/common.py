"""Shared helpers for the paper-table benchmarks (CPU-scale protocol).

The paper measures *theoretical arithmetic operations*; we reproduce the
protocol at laptop scale: smoke-size models (2 layers, d=256), 512-token
documents (paper: 1536-2048), tens of edit samples (paper: 500). All knobs
are CLI-adjustable to run the full-size protocol on bigger hardware.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def ensure_results() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def dense_ops_for(cfg, n: int) -> int:
    from repro.core.opcount import dense_transformer_forward_ops

    kinds = {l.ffn for l in cfg.layer_list()}
    return dense_transformer_forward_ops(
        n_layers=cfg.n_layers, d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff, vocab=cfg.vocab, seq_len=n,
        ffn_gated=kinds <= {"swiglu", "geglu"}, include_lm_head=False,
    )


def make_vqt_engine(seed: int = 0, trained_params=None, vq_heads: int = 2):
    import dataclasses

    from repro.configs.vq_opt_125m import smoke_config
    from repro.core.incremental import IncrementalEngine
    from repro.core.opcount import OpCounter
    from repro.models import transformer as T

    cfg = smoke_config(vqt=True)
    if vq_heads != 2:
        cfg = dataclasses.replace(
            cfg, vqt=dataclasses.replace(cfg.vqt, n_heads=vq_heads))
    params = trained_params
    if params is None:
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
    counter = OpCounter()
    return IncrementalEngine(jax.device_get(params), cfg, counter), cfg, counter


def write_csv(path: str, header: list[str], rows: list[tuple]) -> None:
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"wrote {path} ({len(rows)} rows)")
