"""Shared helpers for the paper-table benchmarks (CPU-scale protocol).

The paper measures *theoretical arithmetic operations*; we reproduce the
protocol at laptop scale: smoke-size models (2 layers, d=256), 512-token
documents (paper: 1536-2048), tens of edit samples (paper: 500). All knobs
are CLI-adjustable to run the full-size protocol on bigger hardware.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def ensure_results() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def dense_ops_for(cfg, n: int) -> int:
    from repro.core.opcount import dense_transformer_forward_ops

    kinds = {l.ffn for l in cfg.layer_list()}
    return dense_transformer_forward_ops(
        n_layers=cfg.n_layers, d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff, vocab=cfg.vocab, seq_len=n,
        ffn_gated=kinds <= {"swiglu", "geglu"}, include_lm_head=False,
    )


def make_vqt_engine(seed: int = 0, trained_params=None, vq_heads: int = 2):
    import dataclasses

    from repro.configs.vq_opt_125m import smoke_config
    from repro.core.incremental import IncrementalEngine
    from repro.core.opcount import OpCounter
    from repro.models import transformer as T

    cfg = smoke_config(vqt=True)
    if vq_heads != 2:
        cfg = dataclasses.replace(
            cfg, vqt=dataclasses.replace(cfg.vqt, n_heads=vq_heads))
    params = trained_params
    if params is None:
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
    counter = OpCounter()
    return IncrementalEngine(jax.device_get(params), cfg, counter), cfg, counter


def timeit(fn, iters: int) -> float:
    """Mean seconds per call after one warmup/compile call."""
    import time

    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def make_batched_jit_setup(n: int, max_b: int, *, edit_capacity: int = 4,
                           row_capacity: int = 64, seed: int = 1):
    """Shared harness for the batched-serving wall-clock benchmarks: a
    BatchedJitEngine, a single-doc engine sharing its weight stacks, and an
    ingested batched state of ``max_b`` documents of length ``n``.
    Returns (cfg, batched_engine, single_engine, batched_state)."""
    import jax.numpy as jnp

    from repro.configs.vq_opt_125m import smoke_config
    from repro.models import transformer as T
    from repro.serving.batch_engine import BatchedJitEngine
    from repro.serving.jit_engine import JitIncrementalEngine

    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    eng = BatchedJitEngine(params, cfg, edit_capacity=edit_capacity,
                           row_capacity=row_capacity)
    seng = JitIncrementalEngine({}, cfg, edit_capacity=edit_capacity,
                                row_capacity=row_capacity, _weights=eng.weights)
    from repro.core.positional import spread_positions

    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (max_b, n)), jnp.int32)
    # gapped ids spread over the pool — arange(n)*k would overflow the
    # positional table for long documents and silently clamp
    positions = jnp.asarray(
        np.tile(spread_positions(n, cfg.pos_pool), (max_b, 1)), jnp.int32)
    bstate = jax.block_until_ready(eng.batch_full_forward(tokens, positions))
    return cfg, eng, seng, bstate


def batched_step_wallclock(n: int, batches, *, edit_capacity: int = 4,
                           row_capacity: int = 64, seed: int = 1,
                           iters: int = 20, random_edits: bool = False,
                           csv_name: str = "wallclock_jit_batched.csv",
                           per_label: str = "per-doc"):
    """One vmapped ``batch_apply_replaces`` step for B documents (each with
    one edit) timed against the single-document jit step. Used by both
    ``wallclock_jit.run_batched`` and ``batch_scaling.run_jit_batched``.
    Returns (t_single_seconds, rows of (b, step_ms, per_ms, rel_single))."""
    import jax.numpy as jnp

    cfg, eng, seng, bstate = make_batched_jit_setup(
        n, max(batches), edit_capacity=edit_capacity,
        row_capacity=row_capacity, seed=seed)
    rng = np.random.default_rng(seed)
    pad = [-1] * (edit_capacity - 1)
    zeros = [0] * (edit_capacity - 1)
    ep1 = jnp.asarray([n // 2] + pad, jnp.int32)
    et1 = jnp.asarray([7] + zeros, jnp.int32)
    s1 = jax.tree.map(lambda x: x[0], bstate)
    t_single = timeit(
        lambda: jax.block_until_ready(seng.apply_replaces(s1, ep1, et1)), iters)
    print(f"  single-doc jit step (n={n}): {t_single*1e3:.2f}ms")
    rows = []
    for b in batches:
        sb = jax.tree.map(lambda x: x[:b], bstate)
        if random_edits:  # one distinct edit per document
            ep = jnp.asarray(np.stack(
                [[int(rng.integers(n))] + pad for _ in range(b)]), jnp.int32)
            et = jnp.asarray(np.stack(
                [[int(rng.integers(cfg.vocab))] + zeros for _ in range(b)]),
                jnp.int32)
        else:
            ep, et = jnp.tile(ep1, (b, 1)), jnp.tile(et1, (b, 1))
        t_b = timeit(
            lambda: jax.block_until_ready(eng.batch_apply_replaces(sb, ep, et)),
            iters)
        per = t_b / b
        rows.append((b, round(t_b * 1e3, 3), round(per * 1e3, 3),
                     round(per / t_single, 3)))
        print(f"  b={b:3d}: batched step {t_b*1e3:7.2f}ms  "
              f"{per_label} {per*1e3:6.2f}ms  ({per/t_single:5.2f}x "
              f"single-doc step)")
    write_csv(f"{ensure_results()}/{csv_name}",
              ["batch", "step_ms", f"{per_label.replace('-', '_')}_ms",
               "rel_single_step"], rows)
    return t_single, rows


def write_csv(path: str, header: list[str], rows: list[tuple]) -> None:
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"wrote {path} ({len(rows)} rows)")
