"""Sigma-delta Pareto sweep: ops saved vs logits drift per threshold
(ISSUE 9 tentpole, DESIGN.md §10).

The tolerance knob's whole pitch is a Pareto curve: raising
``delta_threshold`` suppresses more sub-threshold propagation (fewer
transmitted rows = fewer downstream ops) at the price of bounded logits
drift. This benchmark MEASURES that curve, deterministically, so CI can
gate its shape:

* **ops** — transmitted (layer, row) pairs: after every flush the device
  states ``x[1:]`` are diffed bitwise against the pre-flush snapshot and
  changed rows counted. A structural step (grow / defrag / overflow
  fallback — anything that re-runs ``full_forward``) is charged the full
  ``n_layers * n_valid`` recompute, so thresholds can't cheat by pushing
  work into fallbacks;
* **drift** — max |logits - oracle| on the final document, where the
  oracle is a from-scratch ``full_forward`` on the final host mirrors: the
  exact transformer answer, independent of any incremental history;
* **threshold-0 leg** — replayed against a DEFAULT-constructed server:
  tokens and logits must be BITWISE-equal (the documented exactness
  contract: threshold 0 is the exact engine, not merely close to it).

No wall-clock anywhere — every metric is a deterministic function of the
seeded trace, so the regression gate (``check_regression``) holds the
curve itself: ops monotonically nonincreasing in threshold, drift within
``DRIFT_BOUND``, and the max-threshold leg saving at least its baseline
fraction of transmissions.

Workloads reuse the suggestion benchmark's cursor models (typing /
editing / uniform) so the curve is read at three edit localities.

Emits ``results/BENCH_delta_pareto.json`` — one record per workload —
plus name,value CSV lines like the other benchmarks.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import ensure_results
from benchmarks.suggest_reuse import _edit_pos

# The swept thresholds. 0.0 is the exactness anchor; the rest straddle the
# smoke config's typical per-row L-inf deltas (~1-6 under random-init
# weights) so every workload shows a real knee; the largest leg sits above
# almost every delta, approaching the pure sigma-delta limit.
THRESHOLDS = (0.0, 1.0, 3.0, 8.0)

# Documented drift ceiling for the swept thresholds on the smoke config
# (DESIGN.md §10): benchmark-calibrated, NOT a theoretical bound — the
# gate exists to catch the bound quietly growing, not to prove it tight.
# Measured max over the three workloads is ~0.99; 2.0 leaves 2x headroom.
DRIFT_BOUND = 2.0


def _make_trace(rng, ref: list, vocab: int, workload: str,
                n_edits: int) -> list:
    """Deterministic single-token edit trace [(op, pos, tok)] against a
    live reference list, positions drawn by the workload's cursor model."""
    trace = []
    cursor = len(ref) // 2
    for _ in range(n_edits):
        u = rng.random()
        op = "insert" if u < 0.4 else ("replace" if u < 0.8 else "delete")
        if op == "delete" and len(ref) <= 2:
            op = "replace"
        pos = _edit_pos(rng, op, len(ref), cursor, workload)
        cursor = min(pos, len(ref) - 1)
        tok = int(rng.integers(1, vocab))
        if op == "replace":
            ref[pos] = tok
        elif op == "insert":
            ref.insert(pos, tok)
        else:
            del ref[pos]
        trace.append((op, pos, tok))
    return trace


def _snap_x(srv, doc_id):
    """Host copies of the resident x[1:] leaves (the transmitted state)."""
    import jax

    state = srv.store.ensure_hot(srv.docs[doc_id])
    return np.asarray(jax.device_get(state.x))[1:], int(
        np.sum(np.asarray(state.valid)))


def _replay(params, cfg, trace, base_tokens, *, n_layers: int,
            server_kw=None):
    """Drive one server through the trace, metering transmitted rows.
    Returns (server, ops_transmitted)."""
    from repro.core.edits import Edit
    from repro.serving.batch_server import BatchServer

    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=32,
                      max_batch=2, min_doc_capacity=32,
                      **(server_kw or {}))
    srv.open_document("d", list(base_tokens))
    ops = 0
    for op, pos, tok in trace:
        before, _ = _snap_x(srv, "d")
        ff0 = srv.stats.full_forwards
        srv.submit_edit("d", Edit(op, pos, tok))
        srv.flush()
        after, n_valid = _snap_x(srv, "d")
        if srv.stats.full_forwards != ff0 or before.shape != after.shape:
            # structural step: charge the full recompute, not the diff
            ops += n_layers * n_valid
        else:
            ops += int(np.sum(np.any(before != after, axis=-1)))
    return srv, ops


def run(doc_len: int = 96, n_edits: int = 24, seed: int = 0,
        thresholds=THRESHOLDS) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.configs.vq_opt_125m import smoke_config
    from repro.models import transformer as T
    from repro.serving.jit_engine import JitIncrementalEngine

    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    oracle_eng = JitIncrementalEngine(params, cfg, edit_capacity=4,
                                      row_capacity=32)
    n_layers = cfg.n_layers
    records = []
    for workload in ("typing", "editing", "uniform"):
        rng = np.random.default_rng(seed)
        base = list(rng.integers(0, cfg.vocab, doc_len))
        ref = list(base)
        trace = _make_trace(rng, ref, cfg.vocab, workload, n_edits)

        # oracle logits on the FINAL document: from-scratch full forward
        # over the threshold-0 leg's final host mirrors (token-exactness
        # of every leg is asserted against `ref` below, so all legs share
        # this oracle)
        ops_by_thr, drift_by_thr = [], []
        t0_tokens = t0_logits = None
        for thr in thresholds:
            srv, ops = _replay(params, cfg, trace, base, n_layers=n_layers,
                               server_kw={"delta_threshold": thr})
            assert list(srv.tokens("d")) == ref, (workload, thr)
            doc = srv.docs["d"]
            ostate = oracle_eng.full_forward(
                jnp.asarray(np.array(doc.tokens, copy=True)),
                jnp.asarray(np.array(doc.positions, copy=True)),
                jnp.asarray(np.array(doc.valid, copy=True)))
            order = np.argsort(np.asarray(ostate.positions)[
                np.asarray(ostate.valid)])
            last = int(np.flatnonzero(np.asarray(ostate.valid))[order][-1])
            oracle_logits = np.asarray(
                oracle_eng.logits_at(ostate, jnp.asarray(last, jnp.int32)))
            logits = np.asarray(srv.logits("d"))
            drift = float(np.max(np.abs(logits - oracle_logits)))
            ops_by_thr.append(int(ops))
            drift_by_thr.append(round(drift, 5))
            if thr == 0.0:
                t0_tokens = np.asarray(srv.tokens("d"))
                t0_logits = logits

        # exactness anchor: the threshold-0 leg replayed on a DEFAULT
        # server must match bitwise — tokens AND logits
        dsrv, _ = _replay(params, cfg, trace, base, n_layers=n_layers)
        threshold0_bitwise = bool(
            np.array_equal(t0_tokens, np.asarray(dsrv.tokens("d")))
            and np.array_equal(t0_logits, np.asarray(dsrv.logits("d"))))

        monotone = all(a >= b for a, b in zip(ops_by_thr, ops_by_thr[1:]))
        max_drift = max(drift_by_thr)
        saved = 1.0 - ops_by_thr[-1] / max(ops_by_thr[0], 1)
        rec = {
            "workload": workload,
            "doc_len": doc_len,
            "n_edits": n_edits,
            "thresholds": list(thresholds),
            "ops_transmitted": ops_by_thr,
            "logits_drift": drift_by_thr,
            "threshold0_bitwise": threshold0_bitwise,
            "ops_monotone_nonincreasing": monotone,
            "max_drift": round(max_drift, 5),
            "drift_within_bound": bool(max_drift <= DRIFT_BOUND),
            "ops_saved_frac_max_threshold": round(saved, 4),
        }
        records.append(rec)
        print(f"delta_pareto,{workload},ops={ops_by_thr},"
              f"drift={drift_by_thr},saved_frac={rec['ops_saved_frac_max_threshold']},"
              f"bitwise0={threshold0_bitwise}")
    out = os.path.join(ensure_results(), "BENCH_delta_pareto.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"wrote {out}")
    return records


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--doc-len", type=int, default=96)
    ap.add_argument("--n-edits", type=int, default=24)
    args = ap.parse_args()
    run(doc_len=args.doc_len, n_edits=args.n_edits)
