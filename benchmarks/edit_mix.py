"""Edit-mix benchmark: replace-only vs mixed (insert/delete-heavy) streams.

Two views of the same workload, so the perf trajectory of the full edit
algebra (ISSUE 2 tentpole) is tracked from this PR on:

* **ops** — the paper's metric, metered by the NumPy ``IncrementalServer``:
  incremental ops vs the dense recompute-from-scratch equivalent;
* **wall-clock** — the deployment metric: total ``BatchServer.flush`` time
  (typed fixed-shape dispatches, including any defrag/grow/overflow
  re-ingests) per edit, plus the traced-shape count, which must stay
  bounded by the capacity grid rather than grow with traffic.

Emits ``results/BENCH_edit_mix.json`` (machine-readable, one record per
workload) and prints name,value CSV lines like the other benchmarks.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results

MIXES = {
    "replace_only": {"replace": 1.0, "insert": 0.0, "delete": 0.0},
    # the paper's atomic-edit workload is structural-edit heavy (typing
    # inserts + corrections); 40% inserts/deletes comfortably exceeds the
    # >=30% acceptance bar
    "mixed": {"replace": 0.6, "insert": 0.25, "delete": 0.15},
}


def _stream(rng, ref: list, vocab: int, mix: dict, n_edits: int):
    """Yield (op, pos, tok) against a live reference list."""
    ops, ps = list(mix), np.asarray([mix[k] for k in mix])
    for _ in range(n_edits):
        op = str(rng.choice(ops, p=ps / ps.sum()))
        if op == "delete" and len(ref) <= 1:
            op = "replace"
        if op == "replace":
            pos, tok = int(rng.integers(len(ref))), int(rng.integers(vocab))
            ref[pos] = tok
        elif op == "insert":
            pos, tok = int(rng.integers(len(ref) + 1)), int(rng.integers(vocab))
            ref.insert(pos, tok)
        else:
            pos, tok = int(rng.integers(len(ref))), 0
            del ref[pos]
        yield op, pos, tok


def run(doc_len: int = 192, n_edits: int = 24, n_docs: int = 4,
        seed: int = 0) -> list[dict]:
    import jax

    from repro.configs.vq_opt_125m import smoke_config
    from repro.core.edits import Edit
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer
    from repro.serving.engine import IncrementalServer

    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    records = []
    for name, mix in MIXES.items():
        rng = np.random.default_rng(seed)
        base_docs = {f"d{i}": list(rng.integers(0, cfg.vocab, doc_len))
                     for i in range(n_docs)}

        # ---- op view (single-worker NumPy server, the paper's metric)
        op_srv = IncrementalServer(params, cfg)
        ops = dense = 0
        doc_id = "d0"
        ref = list(base_docs[doc_id])
        op_srv.open_document(doc_id, ref)
        for op, pos, tok in _stream(rng, ref, cfg.vocab, mix, n_edits):
            ops += op_srv.apply_edit(doc_id, Edit(op, pos, tok))
            dense += dense_ops_for(cfg, len(ref))

        # ---- wall-clock view (batched jit server, typed buckets)
        srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=64,
                          max_batch=n_docs, min_doc_capacity=64)
        srv.open_documents(base_docs)
        refs = {k: list(v) for k, v in base_docs.items()}
        rng2 = np.random.default_rng(seed + 1)
        submitted = 0
        for i in range(n_edits):
            did = f"d{int(rng2.integers(n_docs))}"
            for op, pos, tok in _stream(rng2, refs[did], cfg.vocab, mix, 1):
                srv.submit_edit(did, Edit(op, pos, tok))
                submitted += 1
        srv.flush()  # warm the dispatch shapes once
        # measured pass: same traffic pattern again on the warm server
        t0 = time.perf_counter()
        for i in range(n_edits):
            did = f"d{int(rng2.integers(n_docs))}"
            for op, pos, tok in _stream(rng2, refs[did], cfg.vocab, mix, 1):
                srv.submit_edit(did, Edit(op, pos, tok))
                submitted += 1
        srv.flush()
        wall = time.perf_counter() - t0
        for did, r in refs.items():
            assert list(srv.tokens(did)) == r, did

        structural = 1.0 - mix["replace"]
        rec = {
            "workload": name,
            "structural_fraction": round(structural, 3),
            "doc_len": doc_len,
            "n_edits": n_edits,
            "ops_incremental": int(ops),
            "ops_dense_equiv": int(dense),
            "ops_speedup": round(dense / max(ops, 1), 2),
            "wall_s_per_edit": round(wall / n_edits, 5),
            "batch_dispatches": srv.stats.batch_steps,
            "traced_shapes": srv.stats.rejits,
            "overflows": srv.stats.overflows,
            "defrags": srv.stats.defrags,
            "grows": srv.stats.grows,
        }
        records.append(rec)
        print(f"edit_mix,{name},ops_speedup={rec['ops_speedup']},"
              f"wall_per_edit_ms={rec['wall_s_per_edit']*1e3:.2f},"
              f"traced_shapes={rec['traced_shapes']}")
    out = os.path.join(ensure_results(), "BENCH_edit_mix.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"wrote {out}")
    return records


if __name__ == "__main__":
    run()
