"""Edit-mix benchmark: replace-only vs mixed (insert/delete-heavy) streams.

Two views of the same workload, so the perf trajectory of the full edit
algebra (ISSUE 2 tentpole) is tracked from this PR on:

* **ops** — the paper's metric, metered by the NumPy ``IncrementalServer``:
  incremental ops vs the dense recompute-from-scratch equivalent;
* **wall-clock** — the deployment metric: total ``BatchServer.flush`` time
  (typed fixed-shape dispatches, including any defrag/grow/overflow
  re-ingests) per edit, plus the traced-shape count, which must stay
  bounded by the capacity grid rather than grow with traffic.

Timing discipline (ISSUE 7): the measured segment is bracketed by
``jax.block_until_ready`` on every resident document state, so async
dispatch cannot leak device work across the timer; and the warmup is a
REPLAY — the same seeded edit trace is pre-generated once and applied to
warmup twins (``w*``) of the measured documents (``d*``) first, so every
compiled shape the measured pass needs is warm, deterministically, before
the clock starts. The mixed/replace-only wall-clock ratio is CI-gated
(``check_regression``): structural streams must stay within a small factor
of the replace-only fast path now that grow/defrag run on-device and
capacity classes collapse the shape lattice.

Emits ``results/BENCH_edit_mix.json`` (machine-readable, one record per
workload) and prints name,value CSV lines like the other benchmarks.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results

MIXES = {
    "replace_only": {"replace": 1.0, "insert": 0.0, "delete": 0.0},
    # the paper's atomic-edit workload is structural-edit heavy (typing
    # inserts + corrections); 40% inserts/deletes comfortably exceeds the
    # >=30% acceptance bar
    "mixed": {"replace": 0.6, "insert": 0.25, "delete": 0.15},
}

# BatchServer knobs for the legacy (pre-fused) serving stack — the A/B
# reference for the fused ragged hot path. `run(legacy=True)` measures it
# under the SAME sync + warmup-replay discipline.
LEGACY_FLAGS = dict(use_fused_kernel=False, capacity_class_step=2,
                    device_grow=False, device_defrag=False)


def _stream(rng, ref: list, vocab: int, mix: dict, n_edits: int):
    """Yield (op, pos, tok) against a live reference list."""
    ops, ps = list(mix), np.asarray([mix[k] for k in mix])
    for _ in range(n_edits):
        op = str(rng.choice(ops, p=ps / ps.sum()))
        if op == "delete" and len(ref) <= 1:
            op = "replace"
        if op == "replace":
            pos, tok = int(rng.integers(len(ref))), int(rng.integers(vocab))
            ref[pos] = tok
        elif op == "insert":
            pos, tok = int(rng.integers(len(ref) + 1)), int(rng.integers(vocab))
            ref.insert(pos, tok)
        else:
            pos, tok = int(rng.integers(len(ref))), 0
            del ref[pos]
        yield op, pos, tok


def _make_trace(rng, refs: dict, vocab: int, mix: dict,
                n_edits: int) -> list:
    """Pre-generate the full deterministic edit trace: [(doc, op, pos, tok)].
    ``refs`` is mutated to the post-trace document contents."""
    doc_ids = sorted(refs)
    trace = []
    for _ in range(n_edits):
        did = doc_ids[int(rng.integers(len(doc_ids)))]
        for op, pos, tok in _stream(rng, refs[did], vocab, mix, 1):
            trace.append((did, op, pos, tok))
    return trace


def _sync(srv) -> None:
    """Barrier every resident device state (timed-segment boundary)."""
    import jax

    for doc in srv.docs.values():
        if doc.state is not None:
            jax.block_until_ready(doc.state)


def run(doc_len: int = 192, n_edits: int = 24, n_docs: int = 4,
        seed: int = 0, legacy: bool = False) -> list[dict]:
    import jax

    from repro.configs.vq_opt_125m import smoke_config
    from repro.core.edits import Edit
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer
    from repro.serving.engine import IncrementalServer

    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    flags = LEGACY_FLAGS if legacy else {}
    records = []
    for name, mix in MIXES.items():
        rng = np.random.default_rng(seed)
        base_docs = {f"d{i}": list(rng.integers(0, cfg.vocab, doc_len))
                     for i in range(n_docs)}

        # ---- op view (single-worker NumPy server, the paper's metric)
        op_srv = IncrementalServer(params, cfg)
        ops = dense = 0
        doc_id = "d0"
        ref = list(base_docs[doc_id])
        op_srv.open_document(doc_id, ref)
        for op, pos, tok in _stream(rng, ref, cfg.vocab, mix, n_edits):
            ops += op_srv.apply_edit(doc_id, Edit(op, pos, tok))
            dense += dense_ops_for(cfg, len(ref))

        # ---- wall-clock view (batched jit server, typed buckets)
        # warmup twins w* carry the IDENTICAL trace first: same initial
        # content, same seed, same edits -> the same (B, n_cap, C, R)
        # dispatch sequence, so the measured pass re-traces nothing
        srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=64,
                          max_batch=n_docs, min_doc_capacity=64, **flags)
        srv.open_documents({f"w{i}": list(v) for i, (_, v) in
                            enumerate(sorted(base_docs.items()))})
        srv.open_documents(base_docs)
        refs = {k: list(v) for k, v in base_docs.items()}
        trace = _make_trace(np.random.default_rng(seed + 1), refs,
                            cfg.vocab, mix, n_edits)
        for did, op, pos, tok in trace:  # warmup replay on the twins
            srv.submit_edit("w" + did[1:], Edit(op, pos, tok))
            srv.flush()
        _sync(srv)
        warm_shapes = srv.stats.traced_shapes
        launches0 = srv.stats.kernel_launches
        t0 = time.perf_counter()
        for did, op, pos, tok in trace:  # measured pass, same trace
            srv.submit_edit(did, Edit(op, pos, tok))
            srv.flush()
        _sync(srv)
        wall = time.perf_counter() - t0
        for did, r in refs.items():
            assert list(srv.tokens(did)) == r, did

        structural = 1.0 - mix["replace"]
        rec = {
            "workload": name,
            "structural_fraction": round(structural, 3),
            "doc_len": doc_len,
            "n_edits": n_edits,
            "legacy_stack": bool(legacy),
            "ops_incremental": int(ops),
            "ops_dense_equiv": int(dense),
            "ops_speedup": round(dense / max(ops, 1), 2),
            "wall_s_per_edit": round(wall / n_edits, 5),
            "batch_dispatches": srv.stats.batch_steps,
            "traced_shapes": srv.stats.traced_shapes,
            "measured_pass_new_shapes":
                srv.stats.traced_shapes - warm_shapes,
            "kernel_launches_per_edit": round(
                (srv.stats.kernel_launches - launches0) / n_edits, 3),
            "overflows": srv.stats.overflows,
            "defrags": srv.stats.defrags,
            "device_defrags": srv.stats.device_defrags,
            "grows": srv.stats.grows,
            "device_grows": srv.stats.device_grows,
        }
        records.append(rec)
        print(f"edit_mix,{name},ops_speedup={rec['ops_speedup']},"
              f"wall_per_edit_ms={rec['wall_s_per_edit']*1e3:.2f},"
              f"traced_shapes={rec['traced_shapes']},"
              f"launches_per_edit={rec['kernel_launches_per_edit']}")
    # the CI-gated fusion metric: how much slower a structural stream is
    # than the replace-only fast path, warm, on the same server config
    by_name = {r["workload"]: r for r in records}
    ratio = (by_name["mixed"]["wall_s_per_edit"]
             / max(by_name["replace_only"]["wall_s_per_edit"], 1e-9))
    by_name["mixed"]["wall_ratio_mixed_vs_replace"] = round(ratio, 3)
    print(f"edit_mix,wall_ratio_mixed_vs_replace,{ratio:.3f}")
    out = os.path.join(ensure_results(), "BENCH_edit_mix.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"wrote {out}")
    return records


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--legacy", action="store_true",
                    help="measure the pre-fused serving stack (A/B reference)")
    ap.add_argument("--doc-len", type=int, default=192)
    ap.add_argument("--n-edits", type=int, default=24)
    args = ap.parse_args()
    run(doc_len=args.doc_len, n_edits=args.n_edits, legacy=args.legacy)
