"""Paper Fig. 3: offline speedup vs edit fraction — validates the paper's
claim that the op reduction is inversely proportional to the fraction of
modified tokens."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results, make_vqt_engine, write_csv
from repro.core.edits import edit_script
from repro.core.positional import PositionAllocator
from repro.data import SyntheticCorpus
from repro.data.edit_stream import EditStream


def run(doc_len=512, n_pairs=24, seed=0):
    eng, cfg, counter = make_vqt_engine(seed)
    stream = EditStream(SyntheticCorpus(vocab=cfg.vocab, seed=seed), doc_len=doc_len,
                        seed=seed)
    fractions = np.geomspace(0.002, 0.2, 8)
    rows = []
    for i in range(n_pairs):
        frac = float(fractions[i % len(fractions)])
        old, new = stream.revision(i, frac)
        script = edit_script(list(old), list(new))
        actual_frac = len(script) / len(old)
        alloc = PositionAllocator(len(old), cfg.pos_pool)
        state = eng.full_forward(list(old), alloc.positions)
        before = counter.total
        state = eng.apply_revision(state, new, alloc)  # batched App. A.1 sweep
        ops = counter.total - before
        speedup = dense_ops_for(cfg, state.n) / max(ops, 1)
        rows.append((round(actual_frac, 5), round(speedup, 3)))
    write_csv(f"{ensure_results()}/fig3_offline.csv",
              ["edit_fraction", "speedup"], rows)
    # paper claim: speedup ~ 1/fraction -> log-log slope ~ -1
    f = np.array([r[0] for r in rows])
    s = np.array([r[1] for r in rows])
    slope = np.polyfit(np.log(f), np.log(s), 1)[0]
    print(f"log-log slope speedup-vs-fraction: {slope:.2f} (paper: ~-1)")
    return rows, slope


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--doc-len", type=int, default=512)
    ap.add_argument("--pairs", type=int, default=24)
    args = ap.parse_args()
    run(args.doc_len, args.pairs)


if __name__ == "__main__":
    main()
