"""Paper Fig. 4: online (atomic edit) speedup vs normalized edit location.

Earlier edits invalidate more of the causal suffix, so the speedup grows
with the relative position of the edit — the paper's Fig. 4 correlation.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results, make_vqt_engine, write_csv
from repro.core.edits import Edit
from repro.core.positional import PositionAllocator
from repro.data import SyntheticCorpus


def run(doc_len=512, n_edits=60, seed=0):
    eng, cfg, counter = make_vqt_engine(seed)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=seed)
    rng = np.random.default_rng(seed)
    rows = []
    tokens = list(corpus.document(doc_len, 0))
    alloc = PositionAllocator(len(tokens), cfg.pos_pool)
    base = eng.full_forward(tokens, alloc.positions)
    dense = dense_ops_for(cfg, doc_len)
    for _ in range(n_edits):
        pos = int(rng.integers(0, doc_len))
        e = Edit("replace", pos, int(rng.integers(cfg.vocab)))
        before = counter.total
        eng.apply_replaces(base, [e.pos], [e.token])  # independent edits off one base
        ops = counter.total - before
        rows.append((round(pos / doc_len, 4), round(dense / max(ops, 1), 3)))
    write_csv(f"{ensure_results()}/fig4_online.csv",
              ["normalized_location", "speedup"], rows)
    loc = np.array([r[0] for r in rows])
    sp = np.array([r[1] for r in rows])
    corr = np.corrcoef(loc, np.log(sp))[0, 1]
    print(f"median speedup {np.median(sp):.1f}X; corr(location, log speedup) = {corr:.2f} "
          "(paper: positive)")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--doc-len", type=int, default=512)
    ap.add_argument("--edits", type=int, default=60)
    args = ap.parse_args()
    run(args.doc_len, args.edits)


if __name__ == "__main__":
    main()
