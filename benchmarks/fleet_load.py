"""Fleet serving load test: router + replica workers under seeded traffic,
with a forced cross-replica migration and a forced failover mid-run
(ISSUE 10 tentpole).

A ``TrafficGenerator`` schedule (zipf document popularity, Poisson-ish
session arrival/departure, typing vs revise bursts — shared with
``benchmarks.async_load``) drives a ``FleetRouter`` over N subprocess
replicas. Halfway through, one document is migrated to another replica
through the shared cold tier; at the three-quarter mark the fleet is
checkpointed and the busiest replica is hard-killed, so the remaining
events exercise failover-recovered documents on the survivors.

Exactness: the identical event schedule is replayed sequentially on a
single in-process ``BatchServer`` built from the same seeded parameters
(the oracle). Every suggestion and every surviving document's final tokens
must be token-exact despite the migration and the kill — that is the
acceptance criterion of DESIGN.md §11, and ``tokens_exact`` /
``suggestions_exact`` / ``leak_free`` are gated ``must_equal True`` in
``benchmarks.check_regression``. ``migrations`` / ``failovers`` /
``edits_acked`` are deterministic counts (gated exactly); ``hot_hit_rate``
gets a small tolerance. Latency p99 and throughput are wall-clock — gated
only with deliberately cavernous tolerances that catch order-of-magnitude
serving regressions, not runner noise.

Timing protocol: per-replica pinned warmup documents pay the jit compiles,
then ``FleetRouter.reset_latency`` restarts the histograms before the
measured event drive.

Emits ``results/BENCH_fleet_load.json`` plus name,value CSV lines.
Default is the gated 2-replica CPU config; ``--full`` adds 1- and
4-replica sweeps.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import ensure_results


def _submit(server, doc_id: str, op) -> object:
    kind, pos, tok = op
    if kind == "insert":
        return server.submit_insert(doc_id, pos, tok)
    if kind == "delete":
        return server.submit_delete(doc_id, pos)
    return server.submit_replace(doc_id, pos, tok)


def _cold_leftovers(cold_dir: str) -> list[str]:
    try:
        return sorted(f for f in os.listdir(cold_dir)
                      if f.endswith((".npz", ".lease")))
    except FileNotFoundError:
        return []


def run_fleet(n_replicas: int = 2, n_docs: int = 3, n_sessions: int = 5,
              doc_len: int = 24, n_new: int = 4, seed: int = 0,
              chaos: bool = True, max_batch_delay_ms: float = 5.0) -> dict:
    import tempfile

    import jax

    from repro.configs import get_config
    from repro.data.edit_stream import TrafficGenerator
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer
    from repro.serving.fleet import FleetRouter

    cfg = get_config("vq-opt-125m", smoke=True)
    traffic = TrafficGenerator(vocab=cfg.vocab, n_docs=n_docs,
                               doc_len=doc_len, seed=seed)
    events, final_refs = traffic.fleet_events(n_sessions, n_new=n_new)
    n_edit_events = sum(1 for e in events if e[0] == "edit")
    chaos = chaos and n_replicas >= 2
    mig_at = len(events) // 2
    kill_at = (3 * len(events)) // 4

    cold_dir = tempfile.mkdtemp(prefix="repro-fleet-bench-")
    suggestions: list[tuple[str, np.ndarray]] = []
    open_docs: set[str] = set()
    pending: list = []
    edits_acked = 0
    migrations_forced = 0

    fleet = FleetRouter(n_replicas, cold_dir=cold_dir,
                        max_batch_delay_ms=max_batch_delay_ms, seed=seed)
    try:
        # warmup: one pinned document per replica compiles every dispatch
        # shape this schedule will touch (open/edit kinds/suggest/tokens)
        for r in range(n_replicas):
            wid = f"warm{r}"
            fleet.open_document(wid, traffic.base_document(0),
                                replica=r).result(600)
            for op in (("insert", 0, 7), ("replace", 1, 8), ("delete", 0, 0)):
                _submit(fleet, wid, op).result(600)
            fleet.suggest(wid, n_new).result(600)
            fleet.close_document(wid).result(600)
        fleet.reset_latency(600)

        t0 = time.perf_counter()
        for i, ev in enumerate(events):
            if chaos and i == mig_at and open_docs:
                # forced live migration: shared-cold-tier export/import
                doc = sorted(open_docs)[0]
                src = fleet.owner_of(doc)
                fleet.migrate(doc, (src + 1) % n_replicas)
                migrations_forced += 1
            if chaos and i == kill_at:
                # forced failover: everything acked, snapshot the fleet,
                # then hard-kill the busiest replica — survivors adopt its
                # documents from the shared snapshots
                for t in pending:
                    t.result(600)
                    edits_acked += 1
                pending.clear()
                fleet.checkpoint(600)
                counts: dict[int, int] = {}
                for d in sorted(open_docs):
                    o = fleet.owner_of(d)
                    counts[o] = counts.get(o, 0) + 1
                victim = (min(counts, key=lambda k: (-counts[k], k))
                          if counts else 0)
                fleet.kill_replica(victim)
            kind = ev[0]
            if kind == "open":
                fleet.open_document(ev[1], ev[2]).result(600)
                open_docs.add(ev[1])
            elif kind == "edit":
                pending.append(_submit(fleet, ev[1], ev[2]))
            elif kind == "suggest":
                suggestions.append((ev[1], fleet.suggest(ev[1],
                                                         ev[2]).result(600)))
            elif kind == "close":
                fleet.close_document(ev[1]).result(600)
                open_docs.discard(ev[1])
        for t in pending:
            t.result(600)
            edits_acked += 1
        pending.clear()
        wall_s = time.perf_counter() - t0

        final_fleet = {d: np.asarray(fleet.tokens(d).result(600))
                       for d in sorted(open_docs)}
        agg = fleet.stats(600)
    finally:
        fleet.close_fleet()

    leftovers = _cold_leftovers(cold_dir)
    procs_left = [r.idx for r in fleet.replicas if r.proc.poll() is None]
    leak_free = not leftovers and not procs_left

    # sequential oracle: identical schedule, one in-process server, same
    # seeded parameters as every replica (DESIGN.md §11 determinism contract)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    srv = BatchServer(params, cfg)
    oracle_sugg: list[tuple[str, np.ndarray]] = []
    for ev in events:
        if ev[0] == "open":
            srv.open_document(ev[1], ev[2])
        elif ev[0] == "edit":
            _submit(srv, ev[1], ev[2])
        elif ev[0] == "suggest":
            oracle_sugg.append((ev[1], np.asarray(srv.suggest(ev[1], ev[2]))))
        elif ev[0] == "close":
            srv.close_document(ev[1])
    tokens_exact = all(
        np.array_equal(final_fleet[d], srv.tokens(d))
        and np.array_equal(final_fleet[d], np.asarray(final_refs[d]))
        for d in final_fleet)
    suggestions_exact = (
        len(suggestions) == len(oracle_sugg)
        and all(da == db and np.array_equal(a, b)
                for (da, a), (db, b) in zip(suggestions, oracle_sugg)))

    router = agg["router"]
    rec = {
        "n_replicas": n_replicas,
        "n_docs": n_docs,
        "n_sessions": n_sessions,
        "doc_len": doc_len,
        "n_new": n_new,
        "seed": seed,
        "n_events": len(events),
        "n_edit_events": n_edit_events,
        "tokens_exact": bool(tokens_exact),
        "suggestions_exact": bool(suggestions_exact),
        "leak_free": bool(leak_free),
        "edits_acked": edits_acked,
        "migrations": router["migrations"],
        "failovers": router["failovers"],
        "failover_rehydrations": router["failover_rehydrations"],
        "failover_reopens": router["failover_reopens"],
        "repair_edits": router["repair_edits"],
        "hot_hit_rate": agg["hot_hit_rate"],
        "requests_failed": agg["requests_failed"],
        "rounds": agg["rounds"],
        "deadline_rounds": agg["deadline_rounds"],
        # wall-clock: reported; gated only with cavernous tolerances
        "wall_s": wall_s,
        "edits_per_s": n_edit_events / max(wall_s, 1e-9),
        "edit_p99_ms": agg["edit_latency"]["p99_ms"],
        "suggest_p99_ms": agg["suggest_latency"]["p99_ms"],
        "edit_latency": agg["edit_latency"],
        "suggest_latency": agg["suggest_latency"],
    }
    assert migrations_forced == 0 or rec["migrations"] >= 1
    for metric in ("tokens_exact", "suggestions_exact", "leak_free",
                   "migrations", "failovers", "edits_acked", "hot_hit_rate",
                   "edits_per_s", "edit_p99_ms"):
        val = rec[metric]
        val = f"{val:.3f}" if isinstance(val, float) else val
        print(f"fleet_load,{n_replicas},{metric},{val}")
    return rec


def run(full: bool = False, seed: int = 0) -> list[dict]:
    from repro.common.compile_cache import enable_persistent_compilation_cache

    enable_persistent_compilation_cache()  # no-op unless the env var is set
    sizes = (1, 2, 4) if full else (2,)
    records = [run_fleet(n_replicas=n, seed=seed) for n in sizes]
    out = os.path.join(ensure_results(), "BENCH_fleet_load.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"fleet_load,written,{out}")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="sweep 1/2/4 replicas (default: gated 2-replica)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(full=args.full, seed=args.seed)
