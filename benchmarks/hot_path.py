"""Hot-path census: launches, compile counts, achieved-vs-roofline FLOPs.

ISSUE 7 satellite: the fused ragged hot path's wins are STRUCTURAL —
fewer device launches per edit step, fewer compiled shapes per stream, a
higher fraction of each step's arithmetic doing algorithmically-necessary
work — and all three are deterministic for a fixed jax version, so CI can
hold them like op counts (``check_regression``), where wall-clock cannot
be held (runner noise).

For each probed ``(B, n_cap)`` bucket this bench lowers + compiles the
batched edit step twice — fused kernel ON and OFF — and records, from the
compiled module itself (never a timer):

* ``launches`` / ``fusions`` / ``custom_calls`` — the
  ``launch/hlo_stats.launch_stats`` census of the optimized HLO;
* ``xla_flops`` / ``xla_bytes`` — XLA ``cost_analysis()``;
* ``useful_flop_fraction`` — analytic incremental-algorithm FLOPs
  (``launch/roofline.edit_step_flops``) over the XLA count;
* ``compiled_shapes_structural_stream`` — compiled-step shapes a seeded
  grow-heavy stream needs end-to-end under the serving scheduler (the
  ragged-bucketing win: capacity classes collapse the lattice).

Records MERGE by key into ``results/BENCH_hot_path.json``: the CI
bench-gate runs the single-device leg and then a forced-4-device leg
(``--mesh4``) in a second process, which appends its records to the same
file before the gate reads it.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import ensure_results

OUT = "BENCH_hot_path.json"


def _merge_write(records: list[dict]) -> str:
    """Merge-by-key into results/BENCH_hot_path.json (second-process legs
    append without clobbering the first leg's records)."""
    out = os.path.join(ensure_results(), OUT)
    merged: dict[str, dict] = {}
    if os.path.exists(out):
        with open(out) as f:
            merged = {r["workload"]: r for r in json.load(f)}
    for r in records:
        merged[r["workload"]] = r
    rows = [merged[k] for k in sorted(merged)]
    with open(out, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")
    return out


def _step_census(eng, B: int, n_cap: int, C: int, R: int,
                 d_ff: int = 0) -> dict:
    """Lower + compile one batched edit step; read its HLO and cost model."""
    import jax.numpy as jnp

    from repro.launch.hlo_stats import launch_stats
    from repro.launch.roofline import edit_step_roofline

    state = eng.batch_full_forward(
        jnp.zeros((B, n_cap), jnp.int32),
        jnp.tile(jnp.arange(n_cap, dtype=jnp.int32) * 3, (B, 1)))
    bucket = jnp.full((B, C), -1, jnp.int32)
    z = jnp.zeros((B, C), jnp.int32)
    if eng.n_shards > 1:  # the sharded dispatch path (shard_map over mesh)
        lowered = eng._sharded("apply_edits").lower(state, bucket, z, z, z)
    else:
        lowered = type(eng)._batch_apply_edits_local.lower(
            eng, state, bucket, z, z, z)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    st = launch_stats(compiled.as_text())
    # cost_analysis() prices the per-device program: under shard_map each
    # device runs B / n_shards document rows, so the analytic side must
    # price the same per-device slice for the fraction to be meaningful
    rl = edit_step_roofline(
        eng.L, eng.meta, n_cap, C, R, batch=B // eng.n_shards, d_ff=d_ff,
        xla_flops=xla_flops, xla_bytes=xla_bytes)
    return {**st.summary(), **rl.summary()}


def _structural_shape_count(params, cfg, *, n_edits: int, seed: int,
                            legacy: bool) -> dict:
    """Compiled shapes + launches a grow/defrag-heavy stream costs under
    the scheduler (insert-heavy so documents cross capacity boundaries)."""
    from repro.core.edits import Edit
    from repro.serving.batch_server import BatchServer

    flags = (dict(use_fused_kernel=False, capacity_class_step=2,
                  device_grow=False, device_defrag=False) if legacy else {})
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=2, min_doc_capacity=8, pos_pool=256, **flags)
    rng = np.random.default_rng(seed)
    srv.open_documents(
        {"a": list(rng.integers(1, cfg.vocab, 6)),
         "b": list(rng.integers(1, cfg.vocab, 6))})
    for i in range(n_edits):
        did = "ab"[int(rng.integers(2))]
        n = srv.docs[did].n_virtual
        if rng.random() < 0.7:
            srv.submit_edit(did, Edit("insert", int(rng.integers(n + 1)),
                                      int(rng.integers(1, cfg.vocab))))
        else:
            srv.submit_edit(did, Edit("replace", int(rng.integers(n)),
                                      int(rng.integers(1, cfg.vocab))))
        srv.flush()
    return {
        "compiled_shapes_structural_stream": srv.stats.traced_shapes,
        "kernel_launches_per_edit": round(
            srv.stats.kernel_launches / max(srv.stats.edits_applied, 1), 3),
        "device_grows": srv.stats.device_grows,
        "device_defrags": srv.stats.device_defrags,
    }


def run(doc_len: int = 64, n_edits: int = 24, seed: int = 0,
        mesh_tag: str = "") -> list[dict]:
    import jax

    from repro.configs.vq_opt_125m import smoke_config
    from repro.models import transformer as T
    from repro.serving.batch_engine import BatchedJitEngine

    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    C, R = 4, 16
    records = []

    mesh = None
    if mesh_tag:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh()
    for fused in (True, False):
        eng = BatchedJitEngine(params, cfg, edit_capacity=C, row_capacity=R,
                               use_fused_kernel=fused, mesh=mesh)
        B = max(2, eng.n_shards)
        rec = {
            "workload": f"{mesh_tag or 'dev1'}_{'fused' if fused else 'unfused'}",
            "doc_len": doc_len, "B": B, "n_cap": doc_len, "C": C, "R": R,
            **_step_census(eng, B, doc_len, C, R, d_ff=cfg.d_ff),
        }
        records.append(rec)
    # scheduler-level shape census is single-device (mesh legs share it)
    if not mesh_tag:
        for legacy in (False, True):
            key = "stream_legacy" if legacy else "stream_fused"
            rec = {"workload": key, "doc_len": doc_len, "n_edits": n_edits,
                   **_structural_shape_count(params, cfg, n_edits=n_edits,
                                             seed=seed, legacy=legacy)}
            records.append(rec)
        fused_launch = next(r for r in records
                            if r["workload"].endswith("_fused")
                            and "launches" in r)["launches"]
        unfused_launch = next(r for r in records
                              if r["workload"].endswith("_unfused"))["launches"]
        print(f"hot_path,launches,fused={fused_launch},"
              f"unfused={unfused_launch}")
    for r in records:
        if "useful_flop_fraction" in r:
            print(f"hot_path,{r['workload']},launches={r['launches']},"
                  f"useful_flop_fraction={r['useful_flop_fraction']}")
        else:
            print(f"hot_path,{r['workload']},"
                  f"shapes={r['compiled_shapes_structural_stream']},"
                  f"launches_per_edit={r['kernel_launches_per_edit']}")
    _merge_write(records)
    return records


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh4", action="store_true",
                    help="forced-multi-device leg: records merge into the "
                    "same BENCH_hot_path.json under a mesh4_ key prefix")
    ap.add_argument("--doc-len", type=int, default=64)
    ap.add_argument("--n-edits", type=int, default=24)
    args = ap.parse_args()
    run(doc_len=args.doc_len, n_edits=args.n_edits,
        mesh_tag="mesh4" if args.mesh4 else "")
