"""§Roofline report: format results/dryrun.jsonl into the EXPERIMENTS.md
tables (all three terms, dominant bottleneck, MODEL_FLOPS ratio)."""
from __future__ import annotations

import argparse
import json
import os

DEFAULT_IN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.jsonl")


def load(path=DEFAULT_IN):
    recs = [json.loads(l) for l in open(path)]
    # keep the latest record per (arch, shape, mesh)
    latest = {}
    for r in recs:
        latest[(r["arch"], r["shape"], r["mesh"])] = r
    return list(latest.values())


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def roofline_table(recs, markdown=False) -> str:
    rows = []
    for r in recs:
        if r.get("mesh") != "16x16" or "roofline" not in r:
            continue
        t = r["roofline"]["terms"]
        rows.append((
            r["arch"], r["shape"], fmt_s(t["compute_s"]), fmt_s(t["memory_s"]),
            fmt_s(t["collective_s"]), t["bottleneck"].replace("_s", ""),
            f"{t['useful_ratio']:.2f}",
        ))
    rows.sort()
    hdr = ("arch", "shape", "compute", "memory", "collective", "bottleneck",
           "useful-FLOP ratio")
    if markdown:
        out = ["| " + " | ".join(hdr) + " |",
               "|" + "---|" * len(hdr)]
        out += ["| " + " | ".join(r) + " |" for r in rows]
    else:
        out = ["  ".join(f"{h:>14s}" for h in hdr)]
        out += ["  ".join(f"{c:>14s}" for c in r) for r in rows]
    return "\n".join(out)


def dryrun_table(recs, markdown=False) -> str:
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], r["mesh"], "SKIP",
                         r.get("reason", "")[:46]))
            continue
        f = r.get("full", {})
        mem = f.get("memory", {})
        arg_gb = (mem.get("argument_bytes") or 0) / 1e9
        tmp_gb = (mem.get("temp_bytes") or 0) / 1e9
        rows.append((
            r["arch"], r["shape"], r["mesh"], r["status"],
            f"args {arg_gb:.2f}GB + temp {tmp_gb:.2f}GB/dev, "
            f"coll {f.get('collective_bytes', 0)/1e6:.1f}MB, "
            f"compile {f.get('compile_s', 0):.0f}s",
        ))
    hdr = ("arch", "shape", "mesh", "status", "per-device memory & collectives")
    if markdown:
        out = ["| " + " | ".join(hdr) + " |", "|" + "---|" * len(hdr)]
        out += ["| " + " | ".join(map(str, r)) + " |" for r in rows]
    else:
        out = ["\t".join(hdr)] + ["\t".join(map(str, r)) for r in rows]
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default=DEFAULT_IN)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    recs = load(args.inp)
    print("== Dry-run matrix ==")
    print(dryrun_table(recs, args.markdown))
    print("\n== Roofline (single pod, 256 chips) ==")
    print(roofline_table(recs, args.markdown))


if __name__ == "__main__":
    main()
