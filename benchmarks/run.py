"""Benchmark aggregator — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick protocol
  PYTHONPATH=src python -m benchmarks.run --full     # longer training runs

Emits name,value CSV lines (plus per-benchmark CSVs/JSONs under results/)
and a single machine-readable aggregate, ``results/SUMMARY.json`` — one
row per benchmark — which the regression gate
(``benchmarks.check_regression``) and future baseline re-anchors consume.
The dry-run/roofline tables read results/dryrun.jsonl (produced by
``python -m repro.launch.dryrun --all --roofline``).
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _write_summary(summary: list) -> str:
    from benchmarks.common import ensure_results

    out = os.path.join(ensure_results(), "SUMMARY.json")
    with open(out, "w") as f:
        json.dump(summary, f, indent=2)
    return out


# every BENCH_*.json a registered benchmark emits. An orphan (present on
# disk but absent here) is a benchmark that was deleted or renamed without
# cleaning up — or a stray local emission — and would silently rot next to
# the gated files, so the aggregator fails loudly instead.
EXPECTED_BENCH = {
    "BENCH_edit_mix.json",
    "BENCH_hot_path.json",
    "BENCH_suggest_reuse.json",
    "BENCH_async_load.json",
    "BENCH_sharded_serving.json",
    "BENCH_state_churn.json",
    "BENCH_delta_pareto.json",
    "BENCH_fleet_load.json",
}


def check_orphan_bench(results_dir: str | None = None) -> list[str]:
    """Return (and print) the list of orphan BENCH_*.json files."""
    import glob

    from benchmarks.common import ensure_results

    results_dir = results_dir or ensure_results()
    orphans = sorted(
        os.path.basename(p)
        for p in glob.glob(os.path.join(results_dir, "BENCH_*.json"))
        if os.path.basename(p) not in EXPECTED_BENCH)
    for o in orphans:
        print(f"ORPHAN benchmark emission: results/{o} — not produced by "
              "any registered benchmark; delete it or register it in "
              "benchmarks.run.EXPECTED_BENCH")
    return orphans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale-ish protocol")
    ap.add_argument("--skip-accuracy", action="store_true",
                    help="skip the (slow) table-1 training pipeline")
    args = ap.parse_args()

    from benchmarks import fig3_offline, fig4_online, table2_speedups

    summary: list[dict] = []  # one row per benchmark -> results/SUMMARY.json
    t0 = time.time()
    print("=== Table 2: edit-processing speedups (op-counted) ===")
    rows = table2_speedups.run(
        doc_len=1024 if args.full else 384,
        n_edits=120 if args.full else 24,
        n_pairs=24 if args.full else 8,
    )
    for r in rows:
        print(f"table2,{r[0]},atomic={r[1]},revision={r[2]},first5={r[3]}")
    summary.append({"benchmark": "table2_speedups", "rows": [
        {"workload": r[0], "atomic": r[1], "revision": r[2], "first5": r[3]}
        for r in rows]})

    print(f"\n=== Fig 3: offline speedup vs edit fraction ({time.time()-t0:.0f}s) ===")
    _, slope = fig3_offline.run(
        doc_len=1024 if args.full else 384, n_pairs=24 if args.full else 12)
    print(f"fig3,loglog_slope,{slope:.3f}")
    summary.append({"benchmark": "fig3_offline", "loglog_slope": slope})

    print(f"\n=== Fig 4: online speedup vs location ({time.time()-t0:.0f}s) ===")
    fig4_online.run(doc_len=1024 if args.full else 384,
                    n_edits=80 if args.full else 30)
    summary.append({"benchmark": "fig4_online", "csv": "results/fig4_online.csv"})

    print(f"\n=== Batch scaling (paper §3.1 claim) ({time.time()-t0:.0f}s) ===")
    from benchmarks import batch_scaling

    rows = batch_scaling.run(doc_len=1024 if args.full else 384,
                             max_batch=16 if args.full else 8)
    print(f"batch_scaling,b={rows[-1][0]},compressed={rows[-1][1]},dense={rows[-1][2]}")
    summary.append({"benchmark": "batch_scaling", "max_batch": rows[-1][0],
                    "compressed": rows[-1][1], "dense": rows[-1][2]})

    print(f"\n=== Batched jit serving: per-edit wall-clock ({time.time()-t0:.0f}s) ===")
    _, jrows = batch_scaling.run_jit_batched(
        doc_len=512 if args.full else 256,
        batches=(1, 4, 8, 16) if args.full else (1, 8))
    print(f"batch_scaling_jit,b={jrows[-1][0]},rel_single_step={jrows[-1][3]}")
    summary.append({"benchmark": "batch_scaling_jit", "batch": jrows[-1][0],
                    "rel_single_step": jrows[-1][3]})

    print(f"\n=== Wall-clock: static-bucket jit engine ({time.time()-t0:.0f}s) ===")
    from benchmarks import wallclock_jit

    rows = wallclock_jit.run(lengths=(256, 1024) if not args.full else (256, 1024, 2048))
    print(f"wallclock_jit,n={rows[-1][0]},speedup={rows[-1][3]}")
    summary.append({"benchmark": "wallclock_jit", "n": rows[-1][0],
                    "speedup": rows[-1][3]})

    print(f"\n=== Edit mix: replace-only vs insert/delete-heavy "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import edit_mix

    recs = edit_mix.run(doc_len=512 if args.full else 128,
                        n_edits=64 if args.full else 16)
    summary.append({"benchmark": "edit_mix", "rows": recs})

    print(f"\n=== Hot path: launch census + roofline fractions "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import hot_path

    recs = hot_path.run(doc_len=128 if args.full else 64,
                        n_edits=48 if args.full else 24)
    summary.append({"benchmark": "hot_path", "rows": recs})

    print(f"\n=== Suggestion reuse: continuation decoding over edits "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import suggest_reuse

    recs = suggest_reuse.run(doc_len=96 if not args.full else 384,
                             n_edits=24 if not args.full else 64,
                             n_new=8)
    summary.append({"benchmark": "suggest_reuse", "rows": recs})

    print(f"\n=== Sharded serving: mesh scaling + dispatch balance "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import sharded_serving

    recs = sharded_serving.run(doc_len=128 if args.full else 64,
                               n_edits=48 if args.full else 24)
    summary.append({"benchmark": "sharded_serving", "rows": recs})

    print(f"\n=== Tiered state churn: evict / persist / rehydrate "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import state_churn

    recs = state_churn.run(n_docs=16 if args.full else 8,
                           n_edits=64 if args.full else 32)
    summary.append({"benchmark": "state_churn", "rows": recs})

    print(f"\n=== Sigma-delta Pareto: ops saved vs drift per threshold "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import delta_pareto

    recs = delta_pareto.run(doc_len=192 if args.full else 96,
                            n_edits=48 if args.full else 24)
    summary.append({"benchmark": "delta_pareto", "rows": recs})

    print(f"\n=== Async concurrent load: deadline batching + latency SLOs "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import async_load

    recs = async_load.run(n_docs=4 if args.full else 3,
                          doc_len=48 if args.full else 24,
                          n_edits=12 if args.full else 6)
    summary.append({"benchmark": "async_load", "rows": recs})

    print(f"\n=== Fleet serving: router + replicas, migration + failover "
          f"({time.time()-t0:.0f}s) ===")
    from benchmarks import fleet_load

    recs = fleet_load.run(full=args.full)
    summary.append({"benchmark": "fleet_load", "rows": recs})

    if not args.skip_accuracy:
        print(f"\n=== Table 1: accuracy parity ({time.time()-t0:.0f}s) ===")
        from benchmarks import table1_accuracy

        rows = table1_accuracy.run(
            lm_steps=400 if args.full else 120,
            distill_steps=400 if args.full else 120,
            ft_steps=250 if args.full else 100,
        )
        for r in rows:
            print(f"table1,{r[0]},acc={r[1]},f1={r[2]}")
        summary.append({"benchmark": "table1_accuracy", "rows": [
            {"task": r[0], "acc": r[1], "f1": r[2]} for r in rows]})

    dr = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.jsonl")
    if os.path.exists(dr):
        print(f"\n=== Dry-run + roofline ({time.time()-t0:.0f}s) ===")
        from benchmarks import roofline

        recs = roofline.load(dr)
        n_ok = sum(1 for r in recs if r["status"] == "ok")
        n_skip = sum(1 for r in recs if r["status"] == "skipped")
        n_err = len(recs) - n_ok - n_skip
        print(f"dryrun,ok={n_ok},skipped={n_skip},errors={n_err}")
        print(roofline.roofline_table(recs))
        summary.append({"benchmark": "dryrun", "ok": n_ok, "skipped": n_skip,
                        "errors": n_err})
    else:
        print("\n(run `python -m repro.launch.dryrun --all --roofline --out "
              "results/dryrun.jsonl` for the dry-run/roofline tables)")

    out = _write_summary(summary)
    print(f"\nwrote {out} ({len(summary)} benchmark rows)")
    orphans = check_orphan_bench()
    if orphans:
        raise SystemExit(
            f"{len(orphans)} orphan BENCH_*.json file(s) in results/ — see "
            "above")
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
