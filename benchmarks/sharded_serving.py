"""Sharded serving benchmark: the batched edit path over a device mesh
(ISSUE 4 tentpole — per-device dispatch balance as a benchmarked quantity).

Runs the SAME seeded mixed edit stream through ``BatchServer`` at every
mesh size (1-D serving mesh over the batch/document axis, DESIGN.md §6)
and reports per mesh size:

* ``wall_s_per_edit`` — warm flush wall-clock per applied edit;
* ``mean_shard_imbalance`` — the scheduler's per-dispatch dirty-slot
  balance quantity (0 = even, 1 = one device did everything);
* ``tokens_match`` / ``oracle_match`` / ``logits_close_vs_mesh1`` — parity
  of every final document against the edit-replayed reference, against a
  NumPy-engine full forward (logits to 3e-4, the differential suite's
  tolerance), and against the mesh-1 run. The oracle leg is what caught
  the asynchronous host-mirror read race fixed in
  ``batch_server._device_copy``.

Mesh sizes above the visible device count are skipped (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to exercise 2/4 on
a laptop or CI — the flag must be set before jax initializes). Emits
``results/BENCH_sharded_serving.json`` plus name,value CSV lines.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import ensure_results

MIX = {"replace": 0.6, "insert": 0.25, "delete": 0.15}


def _apply_stream(srv, refs, rng, vocab: int, n_edits: int) -> int:
    ops, ps = list(MIX), np.asarray([MIX[k] for k in MIX])
    n_docs = len(refs)
    for _ in range(n_edits):
        did = f"d{int(rng.integers(n_docs))}"
        r = refs[did]
        op = str(rng.choice(ops, p=ps / ps.sum()))
        if op == "delete" and len(r) <= 1:
            op = "replace"
        if op == "replace":
            pos, tok = int(rng.integers(len(r))), int(rng.integers(vocab))
            srv.submit_replace(did, pos, tok)
            r[pos] = tok
        elif op == "insert":
            pos, tok = int(rng.integers(len(r) + 1)), int(rng.integers(vocab))
            srv.submit_insert(did, pos, tok)
            r.insert(pos, tok)
        else:
            pos = int(rng.integers(len(r)))
            srv.submit_delete(did, pos)
            del r[pos]
    return srv.flush()


def run(doc_len: int = 64, n_edits: int = 32, n_docs: int = 8,
        mesh_sizes=None, seed: int = 0) -> list[dict]:
    import jax

    from repro.configs.vq_opt_125m import smoke_config
    from repro.core.incremental import IncrementalEngine
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer

    n_dev = jax.device_count()
    if mesh_sizes is None:
        mesh_sizes = [k for k in (1, 2, 4, 8) if k <= n_dev]
    skipped = [k for k in (1, 2, 4, 8) if k > n_dev]
    if skipped:
        print(f"sharded_serving: mesh sizes {skipped} skipped "
              f"({n_dev} devices; set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    neng = IncrementalEngine(params, cfg)
    doc_rng = np.random.default_rng(seed)
    base_docs = {f"d{i}": list(doc_rng.integers(0, cfg.vocab, doc_len))
                 for i in range(n_docs)}

    records = []
    logits_mesh1 = None
    for k in mesh_sizes:
        srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=64,
                          max_batch=n_docs, min_doc_capacity=64,
                          mesh=make_serving_mesh(k))
        srv.open_documents({d: list(t) for d, t in base_docs.items()})
        refs = {d: list(t) for d, t in base_docs.items()}
        rng = np.random.default_rng(seed + 1)
        _apply_stream(srv, refs, rng, cfg.vocab, n_edits)  # warm the shapes
        t0 = time.perf_counter()
        applied = _apply_stream(srv, refs, rng, cfg.vocab, n_edits)
        wall = time.perf_counter() - t0
        tokens_match = all(list(srv.tokens(d)) == r for d, r in refs.items())
        logits = {d: srv.logits(d) for d in refs}
        oracle_match = True
        for d in refs:
            doc = srv.docs[d]
            ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
            if not np.allclose(logits[d], neng.logits_at(ns), atol=3e-4):
                oracle_match = False
        if k == 1:
            logits_mesh1 = logits
        logits_close = (logits_mesh1 is None or all(
            np.allclose(logits[d], logits_mesh1[d], atol=3e-4)
            for d in refs))
        rec = {
            "mesh_size": k,
            "doc_len": doc_len,
            "n_docs": n_docs,
            "n_edits": n_edits,
            "wall_s_per_edit": round(wall / max(applied, 1), 5),
            "mean_shard_imbalance": round(
                srv.stats.mean_shard_imbalance, 4),
            "sharded_dispatches": srv.stats.sharded_dispatches,
            "batch_dispatches": srv.stats.batch_steps,
            "tokens_match": bool(tokens_match),
            "oracle_match": bool(oracle_match),
            "logits_close_vs_mesh1": bool(logits_close),
        }
        records.append(rec)
        print(f"sharded_serving,mesh={k},"
              f"wall_per_edit_ms={rec['wall_s_per_edit']*1e3:.2f},"
              f"imbalance={rec['mean_shard_imbalance']},"
              f"tokens_match={rec['tokens_match']},"
              f"oracle_match={rec['oracle_match']},"
              f"logits_close={rec['logits_close_vs_mesh1']}")
    out = os.path.join(ensure_results(), "BENCH_sharded_serving.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"wrote {out}")
    return records


if __name__ == "__main__":
    run()
