"""Tiered state-churn benchmark (ISSUE 5 tentpole): a fleet larger than the
device budget, under a zipf-touch edit stream.

The production question the tiered store answers: when documents ≫ budget,
what does an evicted document's next touch cost? The store's answer is a
**rehydration** — a pure snapshot re-upload, bit-exact — versus the naive
fallback of dropping evicted state and paying a ``full_forward`` recompute.
This benchmark measures both and the policy quantity in between:

* ``hot_hit_rate`` — fraction of device-state touches served without any
  rehydration (zipf skew means the popular documents stay hot; the LRU
  policy's first-class number);
* ``evictions`` / ``spills`` / ``rehydrations`` — deterministic churn
  counters under the seeded stream (gated in CI);
* ``rehydrate_warm_ms`` / ``rehydrate_cold_ms`` vs ``full_forward_ms`` —
  the latency of a warm/cold re-upload against the recompute it replaces
  (wall-clock: reported, never gated);
* ``oracle_match`` — final tokens AND logits of every document are
  bit-identical to an unbounded-budget server fed the same stream (the
  rehydration-exactness contract, DESIGN.md §7);
* ``leak_free`` — closing every document at the end leaves zero bytes in
  every tier and an empty spill directory.

Emits ``results/BENCH_state_churn.json`` plus name,value CSV lines; gated
against ``results/BASELINE_state_churn.json`` by
``benchmarks.check_regression``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import ensure_results

MIX = {"replace": 0.6, "insert": 0.25, "delete": 0.15}


def _zipf_weights(n: int, a: float) -> np.ndarray:
    w = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), a)
    return w / w.sum()


def _submit_one(srv, refs, did: str, rng, vocab: int) -> None:
    ops, ps = list(MIX), np.asarray([MIX[k] for k in MIX])
    r = refs[did]
    op = str(rng.choice(ops, p=ps / ps.sum()))
    if op == "delete" and len(r) <= 2:
        op = "replace"
    if op == "replace":
        pos, tok = int(rng.integers(len(r))), int(rng.integers(vocab))
        srv.submit_replace(did, pos, tok)
        r[pos] = tok
    elif op == "insert":
        pos, tok = int(rng.integers(len(r) + 1)), int(rng.integers(vocab))
        srv.submit_insert(did, pos, tok)
        r.insert(pos, tok)
    else:
        pos = int(rng.integers(len(r)))
        srv.submit_delete(did, pos)
        del r[pos]


def run(n_docs: int = 8, doc_len: int = 48, n_edits: int = 32,
        budget_docs: int = 3, n_new: int = 4, zipf_a: float = 1.2,
        seed: int = 0, check_oracle: bool = True) -> list[dict]:
    import jax

    from repro.configs.vq_opt_125m import smoke_config
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer
    from repro.serving.jit_engine import state_nbytes_for_config

    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    min_cap = 64  # one capacity bucket for the whole fleet
    spill = tempfile.mkdtemp(prefix="state-churn-")
    per = state_nbytes_for_config(cfg, min_cap)

    def make(budget_docs_k=None):
        if budget_docs_k is None:
            return BatchServer(params, cfg, edit_capacity=4, row_capacity=64,
                               max_batch=2, min_doc_capacity=min_cap)
        return BatchServer(
            params, cfg, edit_capacity=4, row_capacity=64, max_batch=2,
            min_doc_capacity=min_cap,
            device_budget_bytes=int(budget_docs_k * per * 1.25),  # caches too
            host_budget_bytes=2 * per, spill_dir=spill)

    doc_rng = np.random.default_rng(seed)
    base_docs = {f"d{i}": list(doc_rng.integers(0, cfg.vocab, doc_len))
                 for i in range(n_docs)}
    srv = make(budget_docs)
    srv.open_documents({d: list(t) for d, t in base_docs.items()})
    refs = {d: list(t) for d, t in base_docs.items()}
    weights = _zipf_weights(n_docs, zipf_a)
    rng = np.random.default_rng(seed + 1)
    t0 = time.perf_counter()
    for t in range(n_edits):
        did = f"d{int(rng.choice(n_docs, p=weights))}"
        _submit_one(srv, refs, did, rng, cfg.vocab)
        if t % 4 == 0:
            srv.submit_suggest(did, n_new)
        srv.flush()
    wall = time.perf_counter() - t0
    st = srv.stats
    # gated, deterministic churn counters — recorded BEFORE the latency
    # micro-benchmark below adds its own forced evictions
    gated = dict(hot_hit_rate=round(st.hot_hit_rate, 4),
                 evictions=st.evictions, spills=st.spills,
                 rehydrations=st.rehydrations)
    print(f"state_churn,docs={n_docs},budget_docs={budget_docs},"
          f"hot_hit_rate={gated['hot_hit_rate']},"
          f"evictions={gated['evictions']},spills={gated['spills']},"
          f"rehydrations={gated['rehydrations']}")

    # ---- rehydrate latency vs the full_forward fallback (wall, ungated)
    probe_doc = "d0"
    srv.logits(probe_doc)  # make hot, warm the logits jit
    reps = 3

    def timed(tier):
        total = 0.0
        for _ in range(reps):
            srv.evict(probe_doc, tier)
            t1 = time.perf_counter()
            jax.block_until_ready(
                srv.store.ensure_hot(srv.docs[probe_doc]))
            total += time.perf_counter() - t1
        return total / reps

    warm_s = timed("warm")
    cold_s = timed("cold")
    eng = srv.engine(srv.C, srv.R)
    doc = srv.docs[probe_doc]
    toks, poss, vals = (np.array(doc.tokens, copy=True),
                        np.array(doc.positions, copy=True),
                        np.array(doc.valid, copy=True))
    jax.block_until_ready(eng.full_forward(toks, poss, vals))  # warm the jit
    t1 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(eng.full_forward(toks, poss, vals))
    ff_s = (time.perf_counter() - t1) / reps
    print(f"state_churn,rehydrate_warm_ms={warm_s*1e3:.2f},"
          f"rehydrate_cold_ms={cold_s*1e3:.2f},"
          f"full_forward_ms={ff_s*1e3:.2f},"
          f"speedup_vs_fallback={ff_s/max(warm_s, 1e-9):.1f}x")

    # ---- oracle leg: unbounded server, same stream, bit-identical results
    oracle_match = True
    if check_oracle:
        orc = make(None)
        orc.open_documents({d: list(t) for d, t in base_docs.items()})
        orefs = {d: list(t) for d, t in base_docs.items()}
        orng = np.random.default_rng(seed + 1)
        for t in range(n_edits):
            did = f"d{int(orng.choice(n_docs, p=weights))}"
            _submit_one(orc, orefs, did, orng, cfg.vocab)
            if t % 4 == 0:
                orc.submit_suggest(did, n_new)
            orc.flush()
        for d in refs:
            if list(srv.tokens(d)) != orefs[d]:
                oracle_match = False
            if not np.array_equal(srv.logits(d), orc.logits(d)):
                oracle_match = False
            so, sb = orc.suggestion(d), srv.suggestion(d)
            if (so is None) != (sb is None) or (
                    so is not None and not np.array_equal(so, sb)):
                oracle_match = False
        print(f"state_churn,oracle_match={oracle_match}")

    # ---- teardown: closing the fleet must leak nothing
    for d in list(srv.docs):
        srv.close_document(d)
    leak_free = (st.bytes_hot == 0 and st.bytes_warm == 0
                 and st.bytes_cold == 0 and st.bytes_suggest == 0
                 and (not os.path.isdir(spill) or not os.listdir(spill)))
    print(f"state_churn,leak_free={leak_free}")

    rec = {
        "workload": "zipf",
        "n_docs": n_docs,
        "doc_len": doc_len,
        "n_edits": n_edits,
        "budget_docs": budget_docs,
        "n_new": n_new,
        **gated,
        "oracle_match": bool(oracle_match),
        "leak_free": bool(leak_free),
        "wall_s_per_edit": round(wall / max(n_edits, 1), 5),
        "rehydrate_warm_ms": round(warm_s * 1e3, 3),
        "rehydrate_cold_ms": round(cold_s * 1e3, 3),
        "full_forward_ms": round(ff_s * 1e3, 3),
    }
    out = os.path.join(ensure_results(), "BENCH_state_churn.json")
    with open(out, "w") as f:
        json.dump([rec], f, indent=2)
    print(f"wrote {out}")
    return [rec]


if __name__ == "__main__":
    run()
