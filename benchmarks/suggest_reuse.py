"""Suggestion-reuse benchmark: continuation decoding over an edit stream,
with vs without edited-prefix reuse (ISSUE 3 tentpole).

The writing-assistant loop: a document takes single-token edits; after each
edit the server refreshes a greedy ``n_new``-token suggestion. The
``SuggestionEngine`` reuses every decode-cache row before the earliest
invalidated position and re-prefills only the suffix (power-of-two chunk
buckets); the baseline is the from-scratch oracle, which re-prefills the
whole document per refresh.

Workloads (all single-token edits):

* ``typing``  — edits land in the last 8 positions (the tail cursor of a
  writer typing + correcting): reuse is near-total;
* ``editing`` — a cursor random-walks with occasional long jumps (70%
  local, 30% uniform): the realistic mixed case;
* ``uniform`` — edits uniform over the document: the adversarial floor
  (expected reuse under the pow2 chunk buckets ≈ 0.37 at doc_len 96).

Emits ``results/BENCH_suggest_reuse.json`` — one record per workload with
``reused_prefill_fraction`` (reused rows / total rows across refreshes),
oracle-match booleans, and wall-clock per edit+refresh — plus name,value CSV
lines like the other benchmarks.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import ensure_results


def _edit_pos(rng, kind: str, n: int, cursor: int, workload: str) -> int:
    if workload == "typing":
        lo = max(0, n - 8)
        return int(rng.integers(lo, n + (1 if kind == "insert" else 0)))
    if workload == "editing":
        if rng.random() < 0.3:
            cursor = int(rng.integers(n))
        else:
            cursor = int(np.clip(cursor + rng.integers(-3, 4), 0, n - 1))
        return min(cursor, n if kind == "insert" else n - 1)
    return int(rng.integers(n + (1 if kind == "insert" else 0)))


def run(doc_len: int = 96, n_edits: int = 24, n_new: int = 8,
        seed: int = 0, check_oracle: bool = True) -> list[dict]:
    import jax

    from repro.configs.vq_opt_125m import smoke_config
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer
    from repro.serving.jit_engine import JitIncrementalEngine
    from repro.serving.suggest import SuggestionEngine, oracle_suggestion

    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=32,
                      max_batch=4, min_doc_capacity=16)
    oracle_eng = JitIncrementalEngine(params, cfg, edit_capacity=4,
                                      row_capacity=32)
    oracle_sugg = SuggestionEngine(params, cfg)

    records = []
    for workload in ("typing", "editing", "uniform"):
        rng = np.random.default_rng(seed)
        doc_id = f"w_{workload}"
        ref = list(rng.integers(0, cfg.vocab, doc_len))
        srv.open_document(doc_id, ref)
        srv.suggest(doc_id, n_new)  # initial refresh (cache build)
        before = srv.suggest_stats
        rows0 = (before.prefill_rows_reused, before.prefill_rows_recomputed)
        cursor = doc_len - 1
        matches = []
        t_refresh = t_oracle = 0.0
        for _ in range(n_edits):
            kind = str(rng.choice(["replace", "insert", "delete"],
                                  p=[0.7, 0.2, 0.1]))
            n = len(ref)
            if kind == "delete" and n <= 2:
                kind = "replace"
            pos = _edit_pos(rng, kind, n, cursor, workload)
            cursor = pos
            tok = int(rng.integers(cfg.vocab))
            if kind == "replace":
                srv.submit_replace(doc_id, pos, tok)
                ref[pos] = tok
            elif kind == "insert":
                srv.submit_insert(doc_id, pos, tok)
                ref.insert(pos, tok)
            else:
                srv.submit_delete(doc_id, pos)
                del ref[pos]
            t0 = time.perf_counter()
            sugg = srv.suggest(doc_id, n_new)
            t_refresh += time.perf_counter() - t0
            if check_oracle:
                doc = srv.docs[doc_id]
                t0 = time.perf_counter()
                ora = oracle_suggestion(params, cfg, oracle_eng, doc.tokens,
                                        doc.positions, doc.valid, n_new,
                                        suggester=oracle_sugg)
                t_oracle += time.perf_counter() - t0
                matches.append(bool(np.array_equal(sugg, ora)))
        after = srv.suggest_stats
        reused = after.prefill_rows_reused - rows0[0]
        recomputed = after.prefill_rows_recomputed - rows0[1]
        total = reused + recomputed
        rec = {
            "workload": workload,
            "doc_len": doc_len,
            "n_edits": n_edits,
            "n_new": n_new,
            "prefill_rows_reused": int(reused),
            "prefill_rows_recomputed": int(recomputed),
            "reused_prefill_fraction": reused / max(total, 1),
            "full_recompute_rows": int(len(ref) * n_edits),
            "suggestions_match_oracle": (all(matches) if matches else None),
            # includes the edit dispatch itself (suggest() flushes first);
            # the oracle column is the bare from-scratch decode
            "edit_and_refresh_ms_mean": 1e3 * t_refresh / n_edits,
            "oracle_ms_mean": (1e3 * t_oracle / n_edits if check_oracle
                               else None),
        }
        records.append(rec)
        print(f"suggest_reuse,{workload},reused_fraction,"
              f"{rec['reused_prefill_fraction']:.3f}")
        print(f"suggest_reuse,{workload},refresh_ms,"
              f"{rec['edit_and_refresh_ms_mean']:.2f}")

    out = os.path.join(ensure_results(), "BENCH_suggest_reuse.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"suggest_reuse,written,{out}")
    return records


if __name__ == "__main__":
    run()
