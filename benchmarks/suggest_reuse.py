"""Suggestion-reuse benchmark: continuation decoding over an edit stream,
with vs without edited-prefix reuse (ISSUE 3 tentpole; timing protocol
fixed in ISSUE 6).

The writing-assistant loop: a document takes single-token edits; after each
edit the server refreshes a greedy ``n_new``-token suggestion. The
``SuggestionEngine`` reuses every decode-cache row before the earliest
invalidated position and re-prefills only the suffix (power-of-two chunk
buckets); the baseline is the from-scratch oracle, which re-prefills the
whole document per refresh.

Timing protocol (the two hazards this benchmark used to get wrong):

* **Async dispatch.** jax dispatches asynchronously: without a device sync
  at every timed-segment boundary, pending work from one leg is silently
  billed to whichever leg's timer happens to be running when the device
  gets to it. Every segment here starts and ends on
  ``jax.block_until_ready(jax.live_arrays())`` — the same discipline as
  ``benchmarks.common.timeit``.
* **Compile amortization.** Each distinct re-prefill chunk shape traces +
  compiles once (~seconds on CPU, vs ~tens of ms steady-state); the oracle
  compiles ONE shape while the incremental path compiles O(log n_cap), so
  unwarmed per-edit timings compare compile counts, not serving cost. A
  warmup pass replays the identical seeded stream on a scratch document
  first, so the timed pass measures steady state — the regime the
  persistent compilation cache (``repro.common.compile_cache``) puts a
  restarted server in from its first edit.

Workloads (all single-token edits):

* ``typing``  — edits land in the last 8 positions (the tail cursor of a
  writer typing + correcting): reuse is near-total;
* ``editing`` — a cursor random-walks with occasional long jumps (70%
  local, 30% uniform): the realistic mixed case;
* ``uniform`` — edits uniform over the document: the adversarial floor
  (expected reuse under the pow2 chunk buckets ≈ 0.37 at doc_len 96).

Emits ``results/BENCH_suggest_reuse.json`` — one record per workload with
``reused_prefill_fraction`` (reused rows / total rows across refreshes),
oracle-match booleans, wall-clock per edit+refresh, and
``refresh_to_oracle_ratio`` (median incremental edit+refresh over median
from-scratch oracle; < 1 means the paper's headline win survives in
wall-clock, gated in CI) — plus name,value CSV lines like the other
benchmarks.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import ensure_results


def _edit_pos(rng, kind: str, n: int, cursor: int, workload: str) -> int:
    if workload == "typing":
        lo = max(0, n - 8)
        return int(rng.integers(lo, n + (1 if kind == "insert" else 0)))
    if workload == "editing":
        if rng.random() < 0.3:
            cursor = int(rng.integers(n))
        else:
            cursor = int(np.clip(cursor + rng.integers(-3, 4), 0, n - 1))
        return min(cursor, n if kind == "insert" else n - 1)
    return int(rng.integers(n + (1 if kind == "insert" else 0)))


def _sync() -> None:
    """Device-sync barrier for timed-segment boundaries: blocks on every
    live array, so no pending dispatch from the previous segment can be
    billed to the next one (jax async dispatch, DESIGN.md §8)."""
    import jax

    jax.block_until_ready(jax.live_arrays())


def run(doc_len: int = 96, n_edits: int = 24, n_new: int = 8,
        seed: int = 0, check_oracle: bool = True,
        warmup: bool = True) -> list[dict]:
    import jax

    from repro.common.compile_cache import enable_persistent_compilation_cache
    from repro.configs.vq_opt_125m import smoke_config
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer
    from repro.serving.jit_engine import JitIncrementalEngine
    from repro.serving.suggest import SuggestionEngine, oracle_suggestion

    enable_persistent_compilation_cache()  # no-op unless the env var is set
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(seed), cfg))
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=32,
                      max_batch=4, min_doc_capacity=16)
    oracle_eng = JitIncrementalEngine(params, cfg, edit_capacity=4,
                                      row_capacity=32)
    oracle_sugg = SuggestionEngine(params, cfg)

    records = []
    for workload in ("typing", "editing", "uniform"):
        # warmup pass: replay the identical seeded stream on a scratch
        # document so both legs' shapes are compiled before the timed pass
        phases = (("warm", False),) if warmup else ()
        phases += (("timed", True),)
        for phase, timed in phases:
            rng = np.random.default_rng(seed)
            doc_id = f"w_{workload}_{phase}"
            ref = list(rng.integers(0, cfg.vocab, doc_len))
            srv.open_document(doc_id, ref)
            srv.suggest(doc_id, n_new)  # initial refresh (cache build)
            if timed:
                before = srv.suggest_stats
                rows0 = (before.prefill_rows_reused,
                         before.prefill_rows_recomputed)
            cursor = doc_len - 1
            matches = []
            refresh_ms: list[float] = []
            oracle_ms: list[float] = []
            for _ in range(n_edits):
                kind = str(rng.choice(["replace", "insert", "delete"],
                                      p=[0.7, 0.2, 0.1]))
                n = len(ref)
                if kind == "delete" and n <= 2:
                    kind = "replace"
                pos = _edit_pos(rng, kind, n, cursor, workload)
                cursor = pos
                tok = int(rng.integers(cfg.vocab))
                if kind == "replace":
                    srv.submit_replace(doc_id, pos, tok)
                    ref[pos] = tok
                elif kind == "insert":
                    srv.submit_insert(doc_id, pos, tok)
                    ref.insert(pos, tok)
                else:
                    srv.submit_delete(doc_id, pos)
                    del ref[pos]
                _sync()
                t0 = time.perf_counter()
                sugg = srv.suggest(doc_id, n_new)
                _sync()
                refresh_ms.append(1e3 * (time.perf_counter() - t0))
                if check_oracle:
                    doc = srv.docs[doc_id]
                    t0 = time.perf_counter()
                    ora = oracle_suggestion(params, cfg, oracle_eng,
                                            doc.tokens, doc.positions,
                                            doc.valid, n_new,
                                            suggester=oracle_sugg)
                    _sync()
                    oracle_ms.append(1e3 * (time.perf_counter() - t0))
                    if timed:
                        matches.append(bool(np.array_equal(sugg, ora)))
            if not timed:
                srv.close_document(doc_id)  # scratch session: release state
                continue
            after = srv.suggest_stats
            reused = after.prefill_rows_reused - rows0[0]
            recomputed = after.prefill_rows_recomputed - rows0[1]
            total = reused + recomputed
            med_refresh = float(np.median(refresh_ms))
            med_oracle = (float(np.median(oracle_ms)) if check_oracle
                          else None)
            rec = {
                "workload": workload,
                "doc_len": doc_len,
                "n_edits": n_edits,
                "n_new": n_new,
                "prefill_rows_reused": int(reused),
                "prefill_rows_recomputed": int(recomputed),
                "reused_prefill_fraction": reused / max(total, 1),
                "full_recompute_rows": int(len(ref) * n_edits),
                "suggestions_match_oracle": (all(matches) if matches
                                             else None),
                # includes the edit dispatch itself (suggest() flushes
                # first); the oracle column is the bare from-scratch decode.
                # Segments are device-synced; shapes pre-compiled by warmup.
                "edit_and_refresh_ms_mean": float(np.mean(refresh_ms)),
                "oracle_ms_mean": (float(np.mean(oracle_ms)) if check_oracle
                                   else None),
                "edit_and_refresh_ms_median": med_refresh,
                "oracle_ms_median": med_oracle,
                # medians are runner-noise-robust; <1 = incremental refresh
                # beats the from-scratch oracle in wall-clock (gated)
                "refresh_to_oracle_ratio": (
                    med_refresh / med_oracle if check_oracle else None),
            }
            records.append(rec)
            print(f"suggest_reuse,{workload},reused_fraction,"
                  f"{rec['reused_prefill_fraction']:.3f}")
            print(f"suggest_reuse,{workload},refresh_ms,"
                  f"{rec['edit_and_refresh_ms_mean']:.2f}")
            if check_oracle:
                print(f"suggest_reuse,{workload},refresh_to_oracle_ratio,"
                      f"{rec['refresh_to_oracle_ratio']:.3f}")

    out = os.path.join(ensure_results(), "BENCH_suggest_reuse.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=2)
    print(f"suggest_reuse,written,{out}")
    return records


if __name__ == "__main__":
    run()
