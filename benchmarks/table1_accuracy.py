"""Paper Table 1: accuracy parity on document classification.

Full pipeline at laptop scale (the paper's §4 protocol, scaled down):
  1. train a plain-OPT *teacher* on the synthetic LM corpus (stand-in for
     the pre-trained OPT-125M — no offline weights available);
  2. distill three students with the Sanh-et-al. loss: VQ-OPT (h=2),
     VQ-OPT (h=4), and DistilOPT (half the layers);
  3. fine-tune every model on the planted-topic binary classification task
     (IMDB stand-in) with a mean-pool + linear head;
  4. report accuracy — the paper's claim is VQ-OPT ~ teacher (within a few
     points), not absolute numbers.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ensure_results, write_csv
from repro.configs.vq_opt_125m import smoke_config
from repro.data import SyntheticCorpus, lm_batches
from repro.models import transformer as T
from repro.training import (
    adamw_init, adamw_update, make_distill_step, make_schedule, make_train_step,
    train_state_init,
)
from repro.training.losses import classification_loss


def _train_lm(cfg, corpus, steps, seed=0, batch=8, seq=96):
    state = train_state_init(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(make_train_step(
        cfg, make_schedule(peak_lr=6e-4, warmup_steps=steps // 10, total_steps=steps)))
    for b in lm_batches(corpus, batch=batch, seq_len=seq, steps=steps, seed=seed,
                        pos_pool=cfg.pos_pool if cfg.pos == "sampled" else None):
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    return state.params, float(m["lm_loss"])


def _distill(student_cfg, teacher_cfg, teacher_params, corpus, steps, seed=1,
             batch=8, seq=96):
    state = train_state_init(jax.random.PRNGKey(seed), student_cfg)
    step = jax.jit(make_distill_step(
        student_cfg, teacher_cfg,
        make_schedule(peak_lr=6e-4, warmup_steps=steps // 10, total_steps=steps)))
    for b in lm_batches(corpus, batch=batch, seq_len=seq, steps=steps, seed=seed,
                        pos_pool=student_cfg.pos_pool if student_cfg.pos == "sampled" else None):
        bb = {"tokens": jnp.asarray(b["tokens"])}
        if "positions" in b:
            bb["positions"] = jnp.asarray(b["positions"])
        state, m = step(state, teacher_params, bb)
    return state.params, {k: float(v) for k, v in m.items()}


def _finetune_classify(cfg, params, corpus, steps, seed=2, batch=8, seq=96,
                       eval_docs=64):
    head = {"w": jnp.zeros((cfg.d_model, 2)), "b": jnp.zeros((2,))}
    full = {"model": params, "head": head}
    opt = adamw_init(full)
    sched = make_schedule(peak_lr=7e-4, warmup_steps=max(steps // 10, 1),
                          total_steps=steps)
    rng_pos = np.random.default_rng(seed)

    def batch_of(i, n_docs, base):
        toks, labels = [], []
        for j in range(n_docs):
            d, l = corpus.classification_doc(seq, base + i * n_docs + j)
            toks.append(d)
            labels.append(l)
        out = {"tokens": jnp.asarray(np.stack(toks)),
               "labels": jnp.asarray(np.asarray(labels))}
        if cfg.pos == "sampled":
            pos = np.sort(np.stack([
                rng_pos.choice(cfg.pos_pool, seq, replace=False) for _ in range(n_docs)
            ]), axis=-1)
            out["positions"] = jnp.asarray(pos, jnp.int32)
        return out

    def loss_fn(full, batch, rng):
        logits, aux = T.forward(full["model"], cfg, batch["tokens"],
                                batch.get("positions"), train=True, rng=rng)
        pooled = aux["hidden"].mean(axis=1)
        cls = pooled @ full["head"]["w"] + full["head"]["b"]
        loss, acc = classification_loss(cls, batch["labels"])
        return loss + 0.1 * aux["aux_loss"], acc

    @jax.jit
    def step(full, opt, batch, rng, i):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(full, batch, rng)
        lr = sched(i)
        full, opt, _ = adamw_update(full, grads, opt, lr)
        return full, opt, loss, acc

    for i in range(steps):
        b = batch_of(i, batch, base=0)
        full, opt, loss, acc = step(full, opt, b, jax.random.PRNGKey(1000 + i),
                                    jnp.asarray(i))

    # held-out eval (eval mode: hard VQ, no gumbel)
    @jax.jit
    def eval_logits(full, batch):
        _, aux = T.forward(full["model"], cfg, batch["tokens"], batch.get("positions"))
        pooled = aux["hidden"].mean(axis=1)
        return pooled @ full["head"]["w"] + full["head"]["b"]

    correct = total = 0
    f1_tp = f1_fp = f1_fn = 0
    for i in range(eval_docs // batch):
        b = batch_of(i, batch, base=500_000)
        pred = np.asarray(jnp.argmax(eval_logits(full, b), -1))
        lab = np.asarray(b["labels"])
        correct += int((pred == lab).sum())
        total += len(lab)
        f1_tp += int(((pred == 1) & (lab == 1)).sum())
        f1_fp += int(((pred == 1) & (lab == 0)).sum())
        f1_fn += int(((pred == 0) & (lab == 1)).sum())
    acc = correct / total
    f1 = 2 * f1_tp / max(2 * f1_tp + f1_fp + f1_fn, 1)
    return acc, f1


def run(lm_steps=150, distill_steps=150, ft_steps=120, seed=0):
    t0 = time.time()
    teacher_cfg = smoke_config(vqt=False)
    corpus = SyntheticCorpus(vocab=teacher_cfg.vocab, seed=seed)
    print("training teacher (plain OPT, scaled)...")
    teacher_params, lm_loss = _train_lm(teacher_cfg, corpus, lm_steps, seed)
    print(f"  teacher lm loss {lm_loss:.3f} ({time.time()-t0:.0f}s)")

    students = {}
    from repro.configs.vq_opt_125m import smoke_config as sc

    vq2_cfg = sc(vqt=True)
    vq4_cfg = dataclasses.replace(
        sc(vqt=True), vqt=dataclasses.replace(sc(vqt=True).vqt, n_heads=4))
    distil_cfg = dataclasses.replace(
        teacher_cfg, n_layers=1, stages=((teacher_cfg.stages[0][0], 1),),
        name="distilopt-smoke")
    for name, cfg in [("VQ-OPT(h=2)", vq2_cfg), ("VQ-OPT(h=4)", vq4_cfg),
                      ("DistilOPT", distil_cfg)]:
        print(f"distilling {name}...")
        p, m = _distill(cfg, teacher_cfg, teacher_params, corpus, distill_steps)
        students[name] = (cfg, p)
        print(f"  kl={m['kl']:.3f} lm={m['lm']:.3f} ({time.time()-t0:.0f}s)")

    rows = []
    for name, (cfg, p) in [("OPT(teacher)", (teacher_cfg, teacher_params)),
                           *students.items()]:
        acc, f1 = _finetune_classify(cfg, p, corpus, ft_steps, seed + 3)
        rows.append((name, round(acc, 4), round(f1, 4)))
        print(f"  {name:16s} acc={acc:.3f} f1={f1:.3f} ({time.time()-t0:.0f}s)")
    write_csv(f"{ensure_results()}/table1_accuracy.csv",
              ["model", "accuracy", "f1"], rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lm-steps", type=int, default=150)
    ap.add_argument("--distill-steps", type=int, default=150)
    ap.add_argument("--ft-steps", type=int, default=120)
    args = ap.parse_args()
    rows = run(args.lm_steps, args.distill_steps, args.ft_steps)
    print(f"\n{'model':18s} {'acc':>7s} {'f1':>7s}   (paper: OPT 94.4, VQ-OPT h=2 90.3, h=4 91.6, Distil 92.4)")
    for r in rows:
        print(f"{r[0]:18s} {r[1]:7.3f} {r[2]:7.3f}")


if __name__ == "__main__":
    main()
