"""Paper Table 2: theoretical speedups for processing edit sequences.

Rows: OPT (1X baseline), DistilOPT (2X, structural: half the layers),
VQ-OPT (h=2) — measured with the incremental engine's op counter.
Columns: Atomic (online single edits), Entire Revision (offline), First 5%
(atomic edits restricted to the first 5% of the document).

Speedup = dense-from-scratch ops of the SAME backbone / incremental ops —
the paper's "ratio of arithmetic operations for the original OPT to VQ-OPT".
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import dense_ops_for, ensure_results, make_vqt_engine, write_csv
from repro.core.edits import apply_edit, random_atomic_edit
from repro.core.positional import PositionAllocator
from repro.data import SyntheticCorpus
from repro.data.edit_stream import EditStream, revision_pairs


def _atomic_speedups(eng, cfg, counter, *, doc_len, n_edits, seed, first_frac=None):
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=seed)
    rng = np.random.default_rng(seed)
    dense = dense_ops_for(cfg, doc_len)
    speedups = []
    tokens = list(corpus.document(doc_len, 0))
    alloc = PositionAllocator(len(tokens), cfg.pos_pool)
    state = eng.full_forward(tokens, alloc.positions)
    for _ in range(n_edits):
        e = random_atomic_edit(rng, tokens, cfg.vocab)
        if first_frac is not None:
            lim = max(1, int(first_frac * len(tokens)))
            e = type(e)(e.op, int(rng.integers(0, lim)), e.token)
        before = counter.total
        state = eng.apply_edit(state, e, alloc)
        ops = counter.total - before
        tokens = apply_edit(tokens, e)
        speedups.append(dense / max(ops, 1))
    return speedups


def _revision_speedups(eng, cfg, counter, *, doc_len, n_pairs, seed):
    stream = EditStream(SyntheticCorpus(vocab=cfg.vocab, seed=seed), doc_len=doc_len,
                        seed=seed)
    out = []
    for old, new, script, frac in revision_pairs(stream, n_pairs):
        alloc = PositionAllocator(len(old), cfg.pos_pool)
        state = eng.full_forward(list(old), alloc.positions)
        before = counter.total
        state = eng.apply_revision(state, new, alloc)  # batched App. A.1 sweep
        ops = counter.total - before
        dense = dense_ops_for(cfg, state.n)
        out.append((dense / max(ops, 1), frac))
    return out


def run(doc_len=512, n_edits=40, n_pairs=12, seed=0, trained_params=None):
    rows = [
        ("OPT-125M(scaled)", 1.0, 1.0, 1.0),
        ("DistilOPT", 2.0, 2.0, 2.0),  # structural: half the layers
    ]
    # h=2 and h=4 (paper Table 2: larger effective codebook => more code
    # changes propagate => smaller reuse: 12.1X vs 5.2X at full scale)
    for vq_heads in (2, 4):
        if trained_params is not None and vq_heads != 2:
            continue  # trained weights are h=2
        eng, cfg, counter = make_vqt_engine(seed, trained_params, vq_heads=vq_heads)
        atomic = _atomic_speedups(eng, cfg, counter, doc_len=doc_len,
                                  n_edits=n_edits, seed=seed)
        first5 = _atomic_speedups(eng, cfg, counter, doc_len=doc_len,
                                  n_edits=n_edits, seed=seed + 1, first_frac=0.05)
        rev = _revision_speedups(eng, cfg, counter, doc_len=doc_len,
                                 n_pairs=n_pairs, seed=seed)
        rows.append((
            f"VQ-OPT(h={vq_heads})",
            round(float(np.median(atomic)), 2),
            round(float(np.median([s for s, _ in rev])), 2),
            round(float(np.median(first5)), 2),
        ))
    write_csv(
        f"{ensure_results()}/table2_speedups.csv",
        ["model", "atomic", "entire_revision", "first_5pct"],
        rows,
    )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--doc-len", type=int, default=512)
    ap.add_argument("--edits", type=int, default=40)
    ap.add_argument("--pairs", type=int, default=12)
    args = ap.parse_args()
    rows = run(args.doc_len, args.edits, args.pairs)
    print(f"{'model':20s} {'atomic':>8s} {'revision':>9s} {'first5%':>8s}")
    for r in rows:
        print(f"{r[0]:20s} {r[1]:8.1f} {r[2]:9.1f} {r[3]:8.1f}")
    print("(paper, full scale: VQ-OPT h=2 -> 12.1X atomic, 4.7X revision, 4.8X first-5%)")


if __name__ == "__main__":
    main()
