"""Wall-clock (not op-count) benchmark of the static-bucket jit engine.

The paper reports *theoretical* op reductions; this measures real time for
the TPU-servable jit path (`repro.serving.jit_engine`) on the current
backend: full_forward vs one bucketed replace-edit step — plus the batched
serving path (`repro.serving.batch_engine`): one vmapped step serving B
documents' edit buckets at once, reported as per-document time against the
single-document step.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    batched_step_wallclock, ensure_results, timeit, write_csv,
)
from repro.configs.vq_opt_125m import smoke_config
from repro.core.positional import spread_positions
from repro.models import transformer as T
from repro.serving.jit_engine import JitIncrementalEngine


def run(lengths=(256, 512, 1024), edit_capacity=4, row_capacity=64, seed=1):
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    rows = []
    for n in lengths:
        eng = JitIncrementalEngine(params, cfg, edit_capacity=edit_capacity,
                                   row_capacity=row_capacity)
        tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, n))
        positions = jnp.asarray(spread_positions(n, cfg.pos_pool))
        st = eng.full_forward(tokens, positions)
        jax.block_until_ready(st)
        t_full = timeit(
            lambda: jax.block_until_ready(eng.full_forward(tokens, positions)), 5)
        ep = jnp.asarray([10] + [-1] * (edit_capacity - 1), jnp.int32)
        et = jnp.asarray([5] + [0] * (edit_capacity - 1), jnp.int32)
        t_inc = timeit(
            lambda: jax.block_until_ready(eng.apply_replaces(st, ep, et)), 20)
        rows.append((n, round(t_full * 1e3, 2), round(t_inc * 1e3, 2),
                     round(t_full / t_inc, 2)))
        print(f"  n={n:5d}: full {t_full*1e3:7.1f}ms  incr {t_inc*1e3:7.1f}ms "
              f"-> {t_full/t_inc:5.1f}X wall-clock")
    write_csv(f"{ensure_results()}/wallclock_jit.csv",
              ["n", "full_ms", "incremental_ms", "speedup"], rows)
    return rows


def run_batched(n=256, batches=(1, 2, 4, 8, 16), edit_capacity=4,
                row_capacity=64, seed=1):
    """Batched jit path: one vmapped step for B documents vs B single-doc
    steps. per_doc_ms = t(batched step)/B; ratio < 1 means batching wins."""
    return batched_step_wallclock(
        n, batches, edit_capacity=edit_capacity, row_capacity=row_capacity,
        seed=seed, csv_name="wallclock_jit_batched.csv", per_label="per-doc")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lengths", type=int, nargs="+", default=[256, 512, 1024])
    ap.add_argument("--batched-n", type=int, default=256)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 2, 4, 8, 16])
    ap.add_argument("--skip-single", action="store_true")
    args = ap.parse_args()
    if not args.skip_single:
        print("single-document jit engine:")
        run(tuple(args.lengths))
    print("batched jit engine (vmapped step):")
    run_batched(args.batched_n, tuple(args.batches))


if __name__ == "__main__":
    main()
