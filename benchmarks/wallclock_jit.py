"""Wall-clock (not op-count) benchmark of the static-bucket jit engine.

The paper reports *theoretical* op reductions; this measures real time for
the TPU-servable jit path (`repro.serving.jit_engine`) on the current
backend: full_forward vs one bucketed replace-edit step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ensure_results, write_csv
from repro.configs.vq_opt_125m import smoke_config
from repro.models import transformer as T
from repro.serving.jit_engine import JitIncrementalEngine


def run(lengths=(256, 512, 1024), edit_capacity=4, row_capacity=64, seed=1):
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    rows = []
    for n in lengths:
        eng = JitIncrementalEngine(params, cfg, edit_capacity=edit_capacity,
                                   row_capacity=row_capacity)
        tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, n))
        positions = jnp.arange(n) * 3
        st = eng.full_forward(tokens, positions)
        jax.block_until_ready(st)
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(eng.full_forward(tokens, positions))
        t_full = (time.perf_counter() - t0) / 5
        ep = jnp.asarray([10] + [-1] * (edit_capacity - 1), jnp.int32)
        et = jnp.asarray([5] + [0] * (edit_capacity - 1), jnp.int32)
        st2, _ = eng.apply_replaces(st, ep, et)
        jax.block_until_ready(st2)
        t0 = time.perf_counter()
        for _ in range(20):
            st2, _ = eng.apply_replaces(st, ep, et)
            jax.block_until_ready(st2)
        t_inc = (time.perf_counter() - t0) / 20
        rows.append((n, round(t_full * 1e3, 2), round(t_inc * 1e3, 2),
                     round(t_full / t_inc, 2)))
        print(f"  n={n:5d}: full {t_full*1e3:7.1f}ms  incr {t_inc*1e3:7.1f}ms "
              f"-> {t_full/t_inc:5.1f}X wall-clock")
    write_csv(f"{ensure_results()}/wallclock_jit.csv",
              ["n", "full_ms", "incremental_ms", "speedup"], rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lengths", type=int, nargs="+", default=[256, 512, 1024])
    args = ap.parse_args()
    run(tuple(args.lengths))


if __name__ == "__main__":
    main()
