"""End-to-end driver (paper §4 pipeline at CPU scale):

  1. train a plain-OPT teacher on the synthetic corpus for a few hundred steps;
  2. distill it into a VQ-OPT student (Gumbel-ST VQ, σ-attention, sampled
     positional embeddings);
  3. verify accuracy parity on the planted-topic classification task;
  4. measure the edit-processing speedup of the distilled student.

    PYTHONPATH=src python examples/distill_vqt.py [--steps 300]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from benchmarks.table1_accuracy import _distill, _finetune_classify, _train_lm
from benchmarks.table2_speedups import run as speedup_run
from repro.checkpoint import save_pytree
from repro.configs.vq_opt_125m import smoke_config
from repro.data import SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="results/vq_opt_distilled.npz")
    args = ap.parse_args()
    t0 = time.time()

    teacher_cfg = smoke_config(vqt=False)
    student_cfg = smoke_config(vqt=True)
    corpus = SyntheticCorpus(vocab=teacher_cfg.vocab, seed=0)

    print(f"[1/4] training teacher ({args.steps} steps)...")
    teacher_params, lm_loss = _train_lm(teacher_cfg, corpus, args.steps)
    print(f"      teacher LM loss {lm_loss:.3f}  ({time.time()-t0:.0f}s)")

    print(f"[2/4] distilling VQ-OPT (h=2) ({args.steps} steps)...")
    student_params, m = _distill(student_cfg, teacher_cfg, teacher_params, corpus,
                                 args.steps)
    print(f"      kl={m['kl']:.3f} lm={m['lm']:.3f}  ({time.time()-t0:.0f}s)")
    save_pytree(args.ckpt, jax.device_get(student_params))
    print(f"      saved distilled weights -> {args.ckpt}")

    print("[3/4] classification fine-tune (teacher vs student)...")
    acc_t, f1_t = _finetune_classify(teacher_cfg, teacher_params, corpus,
                                     max(args.steps // 2, 50))
    acc_s, f1_s = _finetune_classify(student_cfg, student_params, corpus,
                                     max(args.steps // 2, 50))
    print(f"      teacher acc={acc_t:.3f}  VQ-OPT acc={acc_s:.3f} "
          f"(paper: 94.4 vs 90.3 at full scale)  ({time.time()-t0:.0f}s)")

    print("[4/4] edit-processing speedups with the *distilled* student...")
    rows = speedup_run(doc_len=384, n_edits=24, n_pairs=8,
                       trained_params=student_params)
    print(f"      VQ-OPT distilled: atomic {rows[2][1]}X, revision {rows[2][2]}X, "
          f"first-5% {rows[2][3]}X  ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
