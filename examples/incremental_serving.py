"""Writing-assistant serving demo: a user edits a document word-by-word
(online) and a review queue processes whole revisions (offline) — the two
settings of paper §3.

    PYTHONPATH=src python examples/incremental_serving.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.core.edits import apply_edit, random_atomic_edit
from repro.data import SyntheticCorpus
from repro.data.edit_stream import EditStream
from repro.models import transformer as T
from repro.serving.engine import IncrementalServer

cfg = get_config("vq-opt-125m", smoke=True)
params = T.init_params(jax.random.PRNGKey(0), cfg)
server = IncrementalServer(jax.device_get(params), cfg)
corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)

# ---- online: a live editing session --------------------------------------
doc = list(corpus.document(256, 0))
server.open_document("live", doc)
rng = np.random.default_rng(0)
print("online session: 15 atomic edits")
tokens = doc
for i in range(15):
    e = random_atomic_edit(rng, tokens, cfg.vocab)
    ops = server.apply_edit("live", e)
    tokens = apply_edit(tokens, e)
    dense = server._dense_ops(len(tokens))
    print(f"  {i:2d} {e.op:8s} pos={e.pos:4d}  {dense/max(ops,1):6.1f}X")

# ---- offline: queued revisions -------------------------------------------
print("\noffline queue: 4 whole revisions of one article")
stream = EditStream(corpus, doc_len=256, seed=1)
old = stream.base_document(99)
server.open_document("article", list(old))
cur = np.asarray(old)
for frac in (0.01, 0.03, 0.08, 0.2):
    rng2 = np.random.default_rng(int(frac * 1e4))
    from repro.core.edits import random_revision

    new = np.asarray(random_revision(rng2, cur, cfg.vocab, frac))
    ops = server.submit_revision("article", list(new))
    dense = server._dense_ops(len(new))
    print(f"  edit-fraction ~{frac:4.2f}: {dense/max(ops,1):6.1f}X "
          f"({len(new)} tokens)")
    cur = new

s = server.stats
print(f"\nserver totals: {s.requests} requests, {s.edits} edits, "
      f"{s.defrags} defrags, cumulative speedup {s.speedup:.1f}X")
