"""Multi-tenant writing-assistant demo: many users edit their documents
concurrently — replacing, INSERTING and DELETING tokens — the batch server
serves every pending edit with capacity-bucketed, vmapped jit dispatches
(ISSUE 2: the full edit algebra over slot-buffer documents), and a subset
of users keep a SUGGESTION subscription open (ISSUE 3): after each tick the
server refreshes their greedy continuations, reusing every decode-cache row
before the earliest edited position instead of re-prefilling the document
from scratch — the paper's "update suggestions in real time as a document
is edited" scenario.

    PYTHONPATH=src python examples/incremental_serving.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.data import SyntheticCorpus
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer
from repro.serving.engine import IncrementalServer

cfg = get_config("vq-opt-125m", smoke=True)
params = jax.device_get(T.init_params(jax.random.PRNGKey(0), cfg))
corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
rng = np.random.default_rng(0)

# ---- open a fleet of documents -------------------------------------------
server = BatchServer(params, cfg, edit_capacity=4, row_capacity=32,
                     max_batch=8, min_doc_capacity=64)
N_DOCS = 12
docs = {}
for i in range(N_DOCS):
    n = int(rng.integers(48, 100))  # mixed lengths -> multiple n_cap buckets
    docs[f"user{i}"] = list(corpus.document(n, i))
server.open_documents(docs)  # same-bucket docs share one ingest dispatch
print(f"opened {N_DOCS} documents via batched ingest "
      f"({server.stats.rejits} compiled ingest shapes)")

# a subset of writers keeps live suggestions open (the assistant pane)
N_SUGGEST = 4
for i in range(N_SUGGEST):
    server.submit_suggest(f"user{i}", n_new=6)

# ---- simulate edit traffic ------------------------------------------------
# Each tick, a random subset of users edits: ~45% replaces, ~35% inserts,
# ~20% deletes (an editing session is insert/delete-heavy — prefix-growing
# typing plus corrections). The scheduler translates sequence positions to
# slots, groups pending edits into typed (n_cap, C, R, op) buckets, and
# serves each bucket with ONE vmapped jit step; replace/insert/delete
# buckets share the same compiled step (the op vector is data).
print("\ntraffic: 6 ticks of concurrent mixed edits")
for tick in range(6):
    n_active = int(rng.integers(3, N_DOCS + 1))
    for uid in rng.choice(N_DOCS, n_active, replace=False):
        doc_id = f"user{uid}"
        ref = docs[doc_id]
        for _ in range(int(rng.integers(1, 4))):
            op = rng.choice(["replace", "insert", "delete"],
                            p=[0.45, 0.35, 0.20])
            if op == "replace":
                pos = int(rng.integers(len(ref)))
                tok = int(rng.integers(cfg.vocab))
                server.submit_replace(doc_id, pos, tok)
                ref[pos] = tok
            elif op == "insert":
                pos = int(rng.integers(len(ref) + 1))
                tok = int(rng.integers(cfg.vocab))
                server.submit_insert(doc_id, pos, tok)
                ref.insert(pos, tok)
            elif len(ref) > 1:
                pos = int(rng.integers(len(ref)))
                server.submit_delete(doc_id, pos)
                del ref[pos]
    pending = server.pending_count()
    applied = server.flush()  # edits apply, then stale suggestions refresh
    s = server.stats
    print(f"  tick {tick}: {pending:2d} pending -> {applied:2d} applied in "
          f"{s.batch_steps} total dispatches "
          f"(mean batch {s.mean_batch:.1f}, overflows {s.overflows}, "
          f"defrags {s.defrags}, grows {s.grows}); "
          f"suggestions: {s.suggest_refreshes} refreshes, "
          f"{s.suggest_invalidations} invalidated by newer edits")

# ---- verify + inspect -----------------------------------------------------
for doc_id, ref in docs.items():
    assert list(server.tokens(doc_id)) == ref, doc_id
some_doc = "user0"
logits = server.logits(some_doc)
s = server.stats
print(f"\nall {N_DOCS} token buffers match the edit-replayed references "
      f"(lengths changed under inserts/deletes: "
      f"{[len(docs[f'user{i}']) for i in range(4)]}...)")
print(f"logits({some_doc!r}): shape {logits.shape}, "
      f"argmax token {int(logits.argmax())}")
print(f"server totals: {s.edits_applied} edits in {s.batch_steps} batched "
      f"dispatches (mean batch {s.mean_batch:.1f}), {s.overflows} overflows, "
      f"{s.defrags} defrags, {s.grows} grows, "
      f"{s.full_forwards} full forwards, {s.rejits} traced shapes")

# ---- the assistant pane: fresh suggestions with prefix reuse --------------
for i in range(N_SUGGEST):
    sug = server.suggestion(f"user{i}")
    assert sug is not None  # flush refreshed every stale subscription
    print(f"  user{i} suggestion: {list(sug)}")
ss = server.suggest_stats
print(f"suggestion serving: {ss.refreshes} refreshes reused "
      f"{ss.prefill_rows_reused}/{ss.prefill_rows_total} prefill rows "
      f"({100 * ss.reused_fraction:.0f}% — a from-scratch assistant would "
      f"re-prefill every row every time), {ss.decode_steps} decode steps")

# ---- op-count view (the paper's metric, single-worker server) ------------
# The NumPy IncrementalServer meters arithmetic ops; one quick revision
# shows the per-request speedup the batch above is built on.
op_server = IncrementalServer(params, cfg)
base = list(corpus.document(256, 999))
op_server.open_document("doc", base)
new = list(base)
for pos in sorted(rng.choice(256, 3, replace=False), reverse=True):
    new[int(pos)] = int(rng.integers(cfg.vocab))
new.insert(128, int(rng.integers(cfg.vocab)))  # a structural edit too
del new[40]
ops = op_server.submit_revision("doc", new)
dense = op_server._dense_ops(len(new))
print(f"\nop-count view: a 5-edit revision (replaces+insert+delete) of a "
      f"256-token doc costs {dense/max(ops,1):.1f}X less than "
      f"recompute-from-scratch")
