"""Serve a small model with batched requests across architectures: greedy
decode with every cache type the framework supports (KV, ring-buffer SWA,
MLA latent, Mamba state, RWKV state).

    PYTHONPATH=src python examples/multiarch_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import all_arch_names, get_config
from repro.models import transformer as T
from repro.serving.decode import make_serve_step

B, PROMPT, NEW = 2, 12, 8

for arch in ["stablelm-1.6b", "gemma3-12b", "deepseek-v2-236b", "hymba-1.5b",
             "rwkv6-7b", "musicgen-large"]:
    cfg = get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if cfg.n_codebooks > 1:
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT, cfg.n_codebooks),
                                    0, cfg.vocab)
    else:
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab)
    caches = T.init_caches(cfg, B, PROMPT + NEW, dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg))
    t0 = time.time()
    cur = prompt[:, :1]
    out = []
    for i in range(PROMPT + NEW):
        pos = jnp.full((B, 1), i, jnp.int32)
        cur_in = prompt[:, i:i+1] if i < PROMPT else cur
        logits, caches = step(params, caches, cur_in, pos)
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if i >= PROMPT:
            out.append(cur)
    gen = jnp.concatenate(out, axis=1)
    print(f"{arch:20s} [{cfg.family:6s}] generated {gen.shape} "
          f"in {time.time()-t0:.1f}s: {gen[0].reshape(-1)[:8].tolist()}")
