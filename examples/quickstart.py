"""Quickstart: build a VQ-Transformer, run it, edit a document incrementally.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.edits import Edit
from repro.models import transformer as T
from repro.serving.engine import IncrementalServer

# 1. A VQT model (the paper's vq-opt family, reduced for CPU).
cfg = get_config("vq-opt-125m", smoke=True)  # vqt=True by default for this arch
print(f"model: {cfg.name} — {cfg.n_layers} layers, d={cfg.d_model}, "
      f"σ-attention + VQ(h={cfg.vqt.n_heads}, q={cfg.vqt.codebook_size})")
params = T.init_params(jax.random.PRNGKey(0), cfg)

# 2. Ordinary batched forward (training-style API).
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
positions = jnp.arange(64)[None].repeat(2, 0) * 3  # gapped absolute ids
logits, aux = T.forward(params, cfg, tokens, positions)
print(f"forward: logits {logits.shape}, vq aux loss {float(aux['aux_loss']):.4f}")

# 3. Incremental inference: open a document once, then pay only for edits.
server = IncrementalServer(jax.device_get(params), cfg)
doc = list(np.random.default_rng(0).integers(0, cfg.vocab, 96))
server.open_document("draft", doc)

for e in [Edit("replace", 10, 7), Edit("insert", 40, 123), Edit("delete", 80)]:
    ops = server.apply_edit("draft", e)
    dense = server._dense_ops(len(server.tokens("draft")))
    print(f"{e.op:8s}@{e.pos:3d}: {ops:>12,} ops "
          f"({dense / max(ops, 1):5.1f}X cheaper than re-running)")

print(f"cumulative speedup so far: {server.stats.speedup:.1f}X")
print(f"next-token logits after edits: {server.logits('draft')[:5]}")
