from repro.checkpoint.store import (
    save_pytree, restore_pytree, save_train_state, restore_train_state,
    save_document_state, restore_document_state,
)
