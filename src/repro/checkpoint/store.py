"""NPZ-based pytree checkpointing with sharding-aware metadata.

Arrays are flattened to ``path -> ndarray`` npz entries; the treedef is
reconstructed from the target structure on restore (restore-into-like, the
standard JAX pattern when no orbax is available). On a sharded runtime the
restore path re-applies each array's recorded sharding spec.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import path_entry_name, path_names


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(path_entry_name(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def restore_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = "/".join(path_entry_name(q) for q in p)
        if key not in data:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = data[key]
        want = jnp.shape(leaf)
        if tuple(arr.shape) != tuple(want):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {want}")
        leaves.append(jnp.asarray(arr, dtype=jnp.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_train_state(path: str, state, step: Optional[int] = None) -> None:
    save_pytree(path, state, metadata={"step": step})


def restore_train_state(path: str, like):
    return restore_pytree(path, like)
