"""NPZ-based pytree checkpointing with sharding-aware metadata.

Arrays are flattened to ``path -> ndarray`` npz entries; the treedef is
reconstructed from the target structure on restore (restore-into-like, the
standard JAX pattern when no orbax is available). On a sharded runtime the
restore path re-applies each array's recorded sharding spec.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import path_entry_name, path_names


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(path_entry_name(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def restore_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = "/".join(path_entry_name(q) for q in p)
        if key not in data:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = data[key]
        want = jnp.shape(leaf)
        if tuple(arr.shape) != tuple(want):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {want}")
        leaves.append(jnp.asarray(arr, dtype=jnp.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_train_state(path: str, state, step: Optional[int] = None) -> None:
    save_pytree(path, state, metadata={"step": step})


def restore_train_state(path: str, like):
    return restore_pytree(path, like)


# --------------------------------------------------------------------------
# Serving document state (the state-store cold tier, DESIGN.md §7)
#
# A serving document's durable incremental state is more than a pytree of
# arrays: the position allocator's id sequence and the suggestion
# watermarks travel with the ``JitState`` so a restore (same process or a
# later one) reproduces the document exactly — rehydration is a pure
# re-upload, never a recompute. Everything lives in ONE npz (no sidecar):
# state leaves under ``state/<field>``, the allocator snapshot under
# ``allocator/ids``, and the scalar metadata as a JSON string array
# (unicode arrays load without pickle).

_DOC_META_KEY = "doc_meta/json"


def atomic_savez(path: str, arrays: dict) -> None:
    """``np.savez`` with crash-safe visibility: write to a temp file in the
    SAME directory, fsync, then ``os.replace`` into place. A crash mid-write
    leaves at most an orphan ``*.tmp`` — the destination path either does not
    exist or holds a complete npz, so a reader (rehydrate, migration import)
    can never observe a truncated archive. Same-directory temp matters:
    ``os.replace`` is only atomic within one filesystem."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        # np.savez appends ".npz" when handed a bare str path; an open file
        # object keeps the temp name exactly as constructed.
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save_document_state(path: str, state, *, allocator_ids,
                        invalid_from: Optional[int] = None,
                        touched_from: Optional[int] = None,
                        extra: Optional[dict] = None) -> None:
    """Serialize a full serving ``JitState`` plus its host-side durable
    companions: the allocator's position-id snapshot and the suggestion
    watermarks (``invalid_from`` / ``touched_from``, DESIGN.md §5). The
    state may hold device or host arrays; leaves are materialized to numpy.
    ``extra`` merges additional JSON-serializable metadata (e.g. a doc id)."""
    from repro.serving.jit_engine import JitState

    if not isinstance(state, JitState):
        raise TypeError(f"expected a JitState, got {type(state).__name__}")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"state/{name}": np.asarray(leaf)
              for name, leaf in zip(JitState._fields, state)}
    arrays["allocator/ids"] = np.asarray(allocator_ids, np.int32)
    meta = dict(extra or {})
    meta["invalid_from"] = invalid_from
    meta["touched_from"] = touched_from
    arrays[_DOC_META_KEY] = np.asarray(json.dumps(meta))
    atomic_savez(path, arrays)


def restore_document_state(path: str):
    """Inverse of ``save_document_state``. Returns
    ``(state, allocator_ids, meta)`` where ``state`` is a host-array
    ``JitState`` (upload with ``serving.jit_engine.state_from_host``),
    ``allocator_ids`` the int32 position-id snapshot, and ``meta`` the
    metadata dict (watermarks restored to ``None`` where saved as such).
    Bit-exact: every leaf round-trips through npz unchanged."""
    from repro.serving.jit_engine import JitState

    data = np.load(path if path.endswith(".npz") else path + ".npz")
    missing = [f for f in JitState._fields if f"state/{f}" not in data]
    if missing:
        raise KeyError(f"document checkpoint missing state fields {missing}")
    state = JitState(*(data[f"state/{f}"] for f in JitState._fields))
    if "allocator/ids" not in data:
        raise KeyError("document checkpoint missing allocator/ids")
    meta = json.loads(str(data[_DOC_META_KEY])) if _DOC_META_KEY in data else {}
    return state, data["allocator/ids"], meta


# --------------------------------------------------------------------------
# Full serving-document snapshots (fleet migration / failover, DESIGN.md §11)
#
# Migration needs more than the JitState: the BatchServer's host mirrors
# (tokens/valid/positions at n_cap) and — critically — the slot layout and
# free-list ORDER. Attention reduces over the slot axis, so a permutation of
# slots changes float summation order; bit-exact migration therefore ships
# the layout verbatim instead of re-deriving it on import.

_MIRROR_FIELDS = ("tokens", "valid", "positions", "slots", "free")


def save_serving_document(path: str, state, *, allocator_ids,
                          mirrors: dict, meta: dict) -> None:
    """Atomic one-file snapshot of a live serving document: the durable
    ``JitState`` + allocator ids (as in ``save_document_state``) plus the
    server-side host mirrors and slot layout under ``mirror/<name>``, and a
    JSON metadata blob (row_capacity, watermarks, pos_pool, consistency
    flag...). This is the unit of cross-replica migration (DESIGN.md §11)."""
    from repro.serving.jit_engine import JitState

    if not isinstance(state, JitState):
        raise TypeError(f"expected a JitState, got {type(state).__name__}")
    missing = [m for m in _MIRROR_FIELDS if m not in mirrors]
    if missing:
        raise KeyError(f"serving snapshot missing mirrors {missing}")
    arrays = {f"state/{name}": np.asarray(leaf)
              for name, leaf in zip(JitState._fields, state)}
    arrays["allocator/ids"] = np.asarray(allocator_ids, np.int32)
    for name in _MIRROR_FIELDS:
        arrays[f"mirror/{name}"] = np.asarray(mirrors[name])
    arrays[_DOC_META_KEY] = np.asarray(json.dumps(meta))
    atomic_savez(path, arrays)


def restore_serving_document(path: str):
    """Inverse of ``save_serving_document``. Returns
    ``(state, allocator_ids, mirrors, meta)`` with host-array leaves;
    raises ``KeyError`` when the file is a bare ``save_document_state``
    checkpoint (no ``mirror/*`` entries) so callers can fall back."""
    from repro.serving.jit_engine import JitState

    data = np.load(path if path.endswith(".npz") else path + ".npz")
    missing = [f for f in JitState._fields if f"state/{f}" not in data]
    if missing:
        raise KeyError(f"serving snapshot missing state fields {missing}")
    state = JitState(*(data[f"state/{f}"] for f in JitState._fields))
    mirrors = {}
    for name in _MIRROR_FIELDS:
        key = f"mirror/{name}"
        if key not in data:
            raise KeyError(f"serving snapshot missing {key} "
                           "(plain document checkpoint? use restore_document_state)")
        mirrors[name] = data[key]
    meta = json.loads(str(data[_DOC_META_KEY])) if _DOC_META_KEY in data else {}
    return state, data["allocator/ids"], mirrors, meta
