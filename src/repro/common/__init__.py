from repro.common.pytree import static_field
