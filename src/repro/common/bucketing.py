"""Capacity bucketing shared by the serving scheduler and the kernels.

Every dynamic quantity in the static-shape path (edit count, dirty-row
count, document length, batch size) is rounded up to a power-of-two
bucket so the compiled-shape grid stays O(log) in each dimension.
"""
from __future__ import annotations


def next_pow2(n: int, minimum: int = 1) -> int:
    """The smallest power-of-two multiple of ``minimum`` >= ``n``
    (``minimum`` itself must be a power of two for pow2 results)."""
    c = max(int(minimum), 1)
    while c < n:
        c *= 2
    return c


def capacity_class(n_cap: int, minimum: int, step: int = 4) -> int:
    """Padded device capacity for a logical slot capacity ``n_cap``: the
    smallest ``minimum * step**k`` >= ``n_cap`` (DESIGN.md §9 "the fused
    ragged hot path").

    Compiled dispatch shapes are keyed on the PADDED capacity, so a
    coarser-than-pow2 class grid (``step=4`` by default) lets one compiled
    step serve a *range* of logical ``n_cap`` buckets: a document whose
    slot buffer doubles inside its class grows with pure host bookkeeping —
    no device reshape, no re-jit. ``step=2`` degenerates to the plain
    power-of-two lattice (one class per ``n_cap``, the pre-ragged
    behavior)."""
    if step < 2:
        raise ValueError("capacity_class step must be >= 2")
    c = max(int(minimum), 1)
    while c < n_cap:
        c *= step
    return c
