"""Capacity bucketing shared by the serving scheduler and the kernels.

Every dynamic quantity in the static-shape path (edit count, dirty-row
count, document length, batch size) is rounded up to a power-of-two
bucket so the compiled-shape grid stays O(log) in each dimension.
"""
from __future__ import annotations


def next_pow2(n: int, minimum: int = 1) -> int:
    """The smallest power-of-two multiple of ``minimum`` >= ``n``
    (``minimum`` itself must be a power of two for pow2 results)."""
    c = max(int(minimum), 1)
    while c < n:
        c *= 2
    return c
