"""Persistent JAX compilation cache (ROADMAP item 2 slice, ISSUE 6).

The serving stack compiles one step per ``(B, n_cap, C, R)`` bucket shape;
those traces are deterministic functions of the config, so recompiling them
on every process restart is pure waste — on the CPU smoke config a single
bucket step costs ~2s of XLA time, which is exactly the "orchestration
overhead eats the saved FLOPs" failure mode of BENCH_suggest_reuse.

``enable_persistent_compilation_cache`` turns on jax's on-disk compilation
cache so bucket steps survive restarts. It is opt-in (a flag on
``BatchServer`` / the benchmarks, or the ``REPRO_COMPILE_CACHE_DIR``
environment variable) because the cache directory is a side effect test
suites should not create implicitly. CI persists the directory across runs
via an actions cache keyed on the jax version (see .github/workflows/ci.yml,
bench-gate job).
"""
from __future__ import annotations

import os
from typing import Optional

ENV_VAR = "REPRO_COMPILE_CACHE_DIR"

_enabled_dir: Optional[str] = None


def enable_persistent_compilation_cache(
        cache_dir: Optional[str] = None) -> Optional[str]:
    """Point jax's persistent compilation cache at ``cache_dir`` (or
    ``$REPRO_COMPILE_CACHE_DIR`` when None). Returns the directory in use,
    or None when neither source names one — callers treat that as "feature
    off" rather than an error, so the flag can be threaded unconditionally.

    Idempotent: repeat calls with the same directory are no-ops; a second
    call with a DIFFERENT directory re-points the cache (jax reads the
    config value per compilation, so this is safe, just unusual).
    """
    global _enabled_dir
    cache_dir = cache_dir or os.environ.get(ENV_VAR) or None
    if cache_dir is None:
        return None
    cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
    if _enabled_dir == cache_dir:
        return cache_dir
    import jax

    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # serving bucket steps are small but hot — cache everything, not just
    # the >1s compiles jax defaults to
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    _enabled_dir = cache_dir
    return cache_dir
