"""Small helpers for dataclass pytrees (no flax available — pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


def static_field(**kwargs: Any) -> Any:
    """A dataclass field treated as static (metadata) by jax pytrees."""
    meta = kwargs.pop("metadata", {})
    meta = {**meta, "static": True}
    return dataclasses.field(metadata=meta, **kwargs)


def path_entry_name(p: Any) -> str:
    """Readable name for one tree-path entry (DictKey / SequenceKey /
    GetAttrKey / FlattenedIndexKey)."""
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def path_names(path) -> tuple[str, ...]:
    return tuple(path_entry_name(p) for p in path)


def pytree_dataclass(cls):
    """Register a dataclass as a jax pytree, honoring static_field metadata."""
    cls = dataclasses.dataclass(cls)
    data_fields = []
    meta_fields = []
    for f in dataclasses.fields(cls):
        if f.metadata.get("static", False):
            meta_fields.append(f.name)
        else:
            data_fields.append(f.name)
    return jax.tree_util.register_dataclass(
        cls, data_fields=data_fields, meta_fields=meta_fields
    )
