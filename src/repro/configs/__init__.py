"""Architecture registry. Each assigned architecture has a module here with
``config()`` (full-size, exact paper/model-card dims) and ``smoke_config()``
(reduced: <=2 layers, d_model<=512, <=4 experts) for CPU tests."""
from __future__ import annotations

import importlib

ARCHS = [
    "deepseek_v2_236b",
    "gemma3_12b",
    "deepseek_v3_671b",
    "internvl2_1b",
    "musicgen_large",
    "h2o_danube_1_8b",
    "phi4_mini_3_8b",
    "stablelm_1_6b",
    "hymba_1_5b",
    "rwkv6_7b",
    "vq_opt_125m",  # the paper's own model
]

_ALIASES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "gemma3-12b": "gemma3_12b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "internvl2-1b": "internvl2_1b",
    "musicgen-large": "musicgen_large",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "stablelm-1.6b": "stablelm_1_6b",
    "hymba-1.5b": "hymba_1_5b",
    "rwkv6-7b": "rwkv6_7b",
    "vq-opt-125m": "vq_opt_125m",
}


def get_config(name: str, smoke: bool = False, **kwargs):
    """kwargs are forwarded to the arch module's config()/smoke_config()
    (e.g. ``vqt=True`` to enable the paper's feature on any architecture)."""
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config(**kwargs) if smoke else mod.config(**kwargs)


def all_arch_names() -> list[str]:
    return list(_ALIASES.keys())
