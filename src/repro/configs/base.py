"""Architecture configuration schema.

An ``ArchConfig`` fully describes one model: dims, mixer family per layer,
FFN/MoE, positions, and (optionally) the paper's VQT feature (vector-quantized
attention outputs + element-wise σ attention + sampled positional embeddings).

The layer list is expressed as *stages*: ``(pattern, repeat)`` where pattern
is a tuple of ``LayerCfg``. The model scans over ``repeat`` with the pattern
body unrolled — this keeps HLO size (and single-core compile time) bounded
for 48-61-layer models while supporting heterogeneous layouts like Gemma-3's
5 local : 1 global, DeepSeek's dense-first-k, and Hymba's 3 global layers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core.vq import VQConfig


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    # capacity factor for fixed-size expert buffers (tokens dropped beyond it)
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLACfg:
    q_lora: int
    kv_lora: int
    rope_dim: int
    nope_dim: int
    v_dim: int


@dataclass(frozen=True)
class SSMCfg:
    """Mamba2-style SSD branch (Hymba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    n_ssm_heads: int = 8  # heads for the SSD scalar-decay recurrence


@dataclass(frozen=True)
class RWKVCfg:
    head_dim: int = 64
    decay_lora: int = 64


@dataclass(frozen=True)
class LayerCfg:
    mixer: str  # 'gqa' | 'mla' | 'hymba' | 'rwkv6'
    ffn: str  # 'swiglu' | 'geglu' | 'gelu' | 'relu2' | 'moe' | 'rwkv_cm'
    window: Optional[int] = None  # sliding-window size; None = global


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    stages: Tuple[Tuple[Tuple[LayerCfg, ...], int], ...]
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"
    pos: str = "rope"  # 'rope' | 'learned' | 'sampled' | 'none'
    rope_theta: float = 10000.0
    max_seq: int = 131072
    pos_pool: int = 0  # for pos == 'sampled'
    attn_softmax: bool = True  # False -> element-wise σ (VQT, paper eq. 1)
    attn_bias: bool = False
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    rwkv: Optional[RWKVCfg] = None
    vqt: Optional[VQConfig] = None
    # multimodal stubs: 'tokens' | 'audio_codes' | 'vlm'
    input_mode: str = "tokens"
    n_codebooks: int = 1  # musicgen: 4 parallel EnCodec streams
    n_patches: int = 256  # vlm: stub patch-embedding count
    mtp: bool = False  # DeepSeek-V3 multi-token-prediction head
    tie_embeddings: bool = False
    # citation for the config values
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def layer_list(self) -> list[LayerCfg]:
        out = []
        for pattern, repeat in self.stages:
            for _ in range(repeat):
                out.extend(pattern)
        return out

    def validate(self) -> "ArchConfig":
        assert len(self.layer_list()) == self.n_layers, (
            f"{self.name}: stages produce {len(self.layer_list())} layers, "
            f"config says {self.n_layers}"
        )
        return self


def uniform_stages(layer: LayerCfg, n_layers: int):
    return (((layer,), n_layers),)


def reduce_for_smoke(cfg: ArchConfig, *, d_model: int = 256, n_layers: int = 2,
                     n_heads: int = 4, n_kv_heads: int = 2, d_ff: int = 512,
                     vocab: int = 512, max_seq: int = 128) -> ArchConfig:
    """Produce a reduced same-family variant (<=2 layers, d<=512, <=4 experts)."""
    changes = dict(
        name=cfg.name + "-smoke",
        d_model=d_model,
        n_layers=n_layers,
        n_heads=n_heads,
        n_kv_heads=min(n_kv_heads, n_heads),
        d_ff=d_ff,
        vocab=vocab,
        max_seq=max_seq,
        head_dim=None,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=128, n_shared=min(cfg.moe.n_shared, 1)
        )
    if cfg.mla is not None:
        changes["mla"] = MLACfg(q_lora=64, kv_lora=32, rope_dim=16, nope_dim=48, v_dim=64)
    if cfg.ssm is not None:
        changes["ssm"] = SSMCfg(d_state=16, d_conv=4, expand=2, n_ssm_heads=2)
    if cfg.rwkv is not None:
        changes["rwkv"] = RWKVCfg(head_dim=32, decay_lora=16)
    if cfg.pos == "sampled":
        changes["pos_pool"] = max_seq * 16
    if cfg.vqt is not None:
        changes["vqt"] = cfg.vqt
    # Rebuild stages with the same *kind* of pattern but n_layers layers.
    first_layer = cfg.layer_list()[0]
    last_layer = cfg.layer_list()[-1]
    window = 64 if any(l.window for l in cfg.layer_list()) else None
    lo = dataclasses.replace(first_layer, window=window if first_layer.window else None)
    hi = dataclasses.replace(last_layer, window=window if last_layer.window else None)
    changes["stages"] = (((lo,), 1), ((hi,), n_layers - 1)) if n_layers > 1 else (((lo,), 1),)
    return dataclasses.replace(cfg, **changes).validate()
