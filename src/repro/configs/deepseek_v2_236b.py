"""DeepSeek-V2 236B [arXiv:2405.04434].

60 layers, d_model 5120, 128 attention heads (MLA: kv_lora=512, rope 64,
nope 128, v 128, q_lora 1536), MoE with 2 shared + 160 routed experts top-6,
expert d_ff 1536 (the assignment's d_ff), first layer dense FFN (8x expert
width = 12288), vocab 102400.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, MLACfg, MoECfg, reduce_for_smoke
from repro.core.vq import VQConfig

_DENSE = LayerCfg(mixer="mla", ffn="swiglu")
_MOE = LayerCfg(mixer="mla", ffn="moe")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,  # dense (first) layer FFN = 8 x expert width
        vocab=102400,
        stages=(((_DENSE,), 1), ((_MOE,), 59)),
        head_dim=192,  # nope 128 + rope 64
        norm="rmsnorm",
        pos="rope",
        rope_theta=10000.0,
        max_seq=131072,
        moe=MoECfg(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
        mla=MLACfg(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128, v_dim=128),
        source="arXiv:2405.04434",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
