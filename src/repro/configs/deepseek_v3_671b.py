"""DeepSeek-V3 671B [arXiv:2412.19437].

61 layers, d_model 7168, 128 heads (MLA kv_lora=512), MoE 1 shared + 256
routed top-8, expert d_ff 2048 (assignment's d_ff), first 3 layers dense
(d_ff 18432 = 9 x expert width), vocab 129280, multi-token prediction head.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, MLACfg, MoECfg, reduce_for_smoke
from repro.core.vq import VQConfig

_DENSE = LayerCfg(mixer="mla", ffn="swiglu")
_MOE = LayerCfg(mixer="mla", ffn="moe")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,  # dense (first-3) layer FFN = 9 x expert width
        vocab=129280,
        stages=(((_DENSE,), 3), ((_MOE,), 58)),
        head_dim=192,
        norm="rmsnorm",
        pos="rope",
        rope_theta=10000.0,
        max_seq=131072,
        moe=MoECfg(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1),
        mla=MLACfg(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128, v_dim=128),
        mtp=True,
        source="arXiv:2412.19437",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
