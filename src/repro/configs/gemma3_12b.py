"""Gemma-3 12B [hf:google/gemma-3-1b-pt family, scaled per assignment].

48 layers, d_model 3840, 16 heads (GQA kv=8, head_dim 256), d_ff 15360,
vocab 262144. 5 local (sliding-window 1024) : 1 global layer pattern, 128k
context.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, reduce_for_smoke
from repro.core.vq import VQConfig

_LOCAL = LayerCfg(mixer="gqa", ffn="geglu", window=1024)
_GLOBAL = LayerCfg(mixer="gqa", ffn="geglu")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="gemma3-12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        d_ff=15360,
        vocab=262144,
        # 5:1 local:global, 8 repeats of the 6-layer pattern = 48 layers
        stages=(((_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL), 8),),
        head_dim=256,
        norm="rmsnorm",
        pos="rope",
        rope_theta=1000000.0,
        max_seq=131072,
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
