"""H2O-Danube 1.8B [arXiv:2401.16818].

24 layers, d_model 2560, 32 heads (GQA kv=8), d_ff 6912, vocab 32000.
Llama+Mistral mix with sliding-window attention (window 4096).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, reduce_for_smoke, uniform_stages
from repro.core.vq import VQConfig

_LAYER = LayerCfg(mixer="gqa", ffn="swiglu", window=4096)


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab=32000,
        stages=uniform_stages(_LAYER, 24),
        norm="rmsnorm",
        pos="rope",
        rope_theta=10000.0,
        max_seq=524288,  # SWA: cache is window-bounded, context unbounded
        source="arXiv:2401.16818",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
