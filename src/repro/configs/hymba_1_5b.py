"""Hymba 1.5B [arXiv:2411.13676].

32 layers, d_model 1600, 25 heads (GQA kv=5, head_dim 64), d_ff 5504,
vocab 32001, ssm_state 16. Parallel attention + mamba heads per layer;
3 global-attention layers (first / middle / last), the rest sliding-window.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, SSMCfg, reduce_for_smoke
from repro.core.vq import VQConfig

_LOCAL = LayerCfg(mixer="hymba", ffn="swiglu", window=1024)
_GLOBAL = LayerCfg(mixer="hymba", ffn="swiglu")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab=32001,
        # global at layers 0, 15, 31 (first / middle / last, per the paper)
        stages=(
            ((_GLOBAL,), 1),
            ((_LOCAL,), 14),
            ((_GLOBAL,), 1),
            ((_LOCAL,), 15),
            ((_GLOBAL,), 1),
        ),
        head_dim=64,
        norm="rmsnorm",
        pos="rope",
        rope_theta=10000.0,
        max_seq=524288,  # SWA + SSM: sub-quadratic, unbounded context
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, n_ssm_heads=25),
        source="arXiv:2411.13676",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
