"""InternVL2-1B language backbone (Qwen2-0.5B) [arXiv:2404.16821].

24 layers, d_model 896, 14 heads (GQA kv=2), d_ff 4864, vocab 151655.
The InternViT vision encoder is a stub per spec: ``input_specs`` provides
precomputed patch embeddings [b, n_patches, d_model]; we implement the
projector + language decoder that consumes them.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, reduce_for_smoke, uniform_stages
from repro.core.vq import VQConfig

_LAYER = LayerCfg(mixer="gqa", ffn="swiglu")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151655,
        stages=uniform_stages(_LAYER, 24),
        norm="rmsnorm",
        pos="rope",
        rope_theta=1000000.0,
        max_seq=32768,
        attn_bias=True,  # Qwen2 QKV bias
        input_mode="vlm",
        n_patches=256,
        tie_embeddings=True,
        source="arXiv:2404.16821",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
