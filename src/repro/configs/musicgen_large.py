"""MusicGen-large decoder [arXiv:2306.05284].

48 layers, d_model 2048, 32 heads (kv=32), d_ff 8192, vocab 2048 per EnCodec
codebook, 4 codebooks (delay interleaving pattern). Decoder-only over EnCodec
tokens; the mel/EnCodec frontend is a stub per spec — ``input_specs`` feeds
token ids [b, n, 4] directly.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, reduce_for_smoke, uniform_stages
from repro.core.vq import VQConfig

_LAYER = LayerCfg(mixer="gqa", ffn="gelu")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=2048,
        stages=uniform_stages(_LAYER, 48),
        norm="layernorm",
        pos="learned",
        max_seq=32768,
        attn_bias=True,
        n_codebooks=4,
        source="arXiv:2306.05284",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(
            cfg, attn_softmax=False, vqt=VQConfig(n_heads=2), pos="sampled",
            pos_pool=cfg.max_seq * 4,
        )
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
