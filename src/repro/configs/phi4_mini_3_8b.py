"""Phi-4-mini 3.8B [arXiv:2412.08905].

32 layers, d_model 3072, 24 heads (GQA kv=8), d_ff 8192, vocab 200064.
RoPE + SwiGLU + GQA.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, reduce_for_smoke, uniform_stages
from repro.core.vq import VQConfig

_LAYER = LayerCfg(mixer="gqa", ffn="swiglu")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab=200064,
        stages=uniform_stages(_LAYER, 32),
        norm="rmsnorm",
        pos="rope",
        rope_theta=10000.0,
        max_seq=131072,
        source="arXiv:2412.08905",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
