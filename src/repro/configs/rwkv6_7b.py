"""RWKV-6 "Finch" 7B [arXiv:2404.05892].

32 layers, d_model 4096 (attention-free: 64 WKV heads of dim 64), channel-mix
d_ff 14336, vocab 65536. Data-dependent per-channel decay via decay-LoRA.

VQT inapplicability (DESIGN.md §Arch-applicability): the WKV recurrence makes
every position depend on the entire prefix, so there is no row/column-sparse
attention patch; serving uses prefix-state caching instead.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, LayerCfg, RWKVCfg, reduce_for_smoke, uniform_stages

_LAYER = LayerCfg(mixer="rwkv6", ffn="rwkv_cm")


def config(vqt: bool = False) -> ArchConfig:
    # vqt is accepted for registry uniformity but is a no-op (inapplicable).
    return ArchConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,
        n_kv_heads=64,
        d_ff=14336,
        vocab=65536,
        stages=uniform_stages(_LAYER, 32),
        norm="layernorm",
        pos="none",
        max_seq=524288,  # O(1) state: unbounded context
        rwkv=RWKVCfg(head_dim=64, decay_lora=64),
        source="arXiv:2404.05892",
    ).validate()


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config())
