"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b].

24 layers, d_model 2048, 32 heads (kv=32, MHA), d_ff 5632, vocab 100352.
LayerNorm, RoPE (full, simplified from the model card's 25% partial rotary).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, reduce_for_smoke, uniform_stages
from repro.core.vq import VQConfig

_LAYER = LayerCfg(mixer="gqa", ffn="swiglu")


def config(vqt: bool = False) -> ArchConfig:
    cfg = ArchConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab=100352,
        stages=uniform_stages(_LAYER, 24),
        norm="layernorm",
        pos="rope",
        rope_theta=10000.0,
        max_seq=4096,
        source="hf:stabilityai/stablelm-2-1_6b",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(cfg, attn_softmax=False, vqt=VQConfig(n_heads=2))
    return cfg


def smoke_config(vqt: bool = False) -> ArchConfig:
    return reduce_for_smoke(config(vqt))
