"""VQ-OPT-125M — the paper's own model (OPT-125M + VQT, paper §4).

OPT-125M base [Zhang et al. 2022]: 12 layers, d_model 768, 12 heads,
d_ff 3072, vocab 50272, LayerNorm, learned positions, GELU FFN, biases.

VQT modifications (paper §3): element-wise GELU attention (no softmax),
multi-head VQ (h=2, codebook 64) on attention outputs, sampled absolute
positional embeddings drawn from a pool 100x the max sequence length.
``config(vqt=False)`` returns the plain OPT-125M teacher.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, LayerCfg, reduce_for_smoke, uniform_stages
from repro.core.vq import VQConfig

_LAYER = LayerCfg(mixer="gqa", ffn="gelu")

MAX_SEQ = 2048


def config(vqt: bool = True, vq_heads: int = 2) -> ArchConfig:
    cfg = ArchConfig(
        name="vq-opt-125m" if vqt else "opt-125m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=50272,
        stages=uniform_stages(_LAYER, 12),
        norm="layernorm",
        pos="learned",
        max_seq=MAX_SEQ,
        attn_bias=True,
        tie_embeddings=True,
        source="arXiv:2205.01068 + paper §4",
    ).validate()
    if vqt:
        cfg = dataclasses.replace(
            cfg,
            attn_softmax=False,
            vqt=VQConfig(n_heads=vq_heads, codebook_size=64),
            pos="sampled",
            pos_pool=100 * MAX_SEQ,
        )
    return cfg


def smoke_config(vqt: bool = True) -> ArchConfig:
    return reduce_for_smoke(config(vqt), n_kv_heads=4)  # OPT is MHA
