"""Compressed representation of vector-quantized activations (paper §3.1-3.2).

A (batched) activation tensor ``X ∈ R^{b×n×d}`` whose rows are drawn from a
small set of unique vectors is stored as a codebook ``C ∈ R^{q×d}`` plus an
index map ``P ∈ {0..q-1}^{b×n}`` with ``X[b,n,:] = C[P[b,n],:]``.

Two facts make this useful (paper §3.2):

* *per-location* ops ``Y = F(X)`` with ``Y[i,j,:] = f(X[i,j,:])`` reduce to
  ``(P, f(C))`` — cost ``O(q·cost(f))`` instead of ``O(b·n·cost(f))``;
* *binary element-wise* ops between two compressed tensors reduce to applying
  ``f`` on the **unique pairs** of codebook rows (App. A.3).

The classes here are pytrees and work both eagerly (exact sizes; used by the
incremental serving engine and the op-counting benchmarks) and under jit with
a static ``capacity`` (used by the compressed batch forward).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import pytree_dataclass


@pytree_dataclass
class Compressed:
    """codebook: [cap, d]; idx: int32 [...] with values in [0, n_codes)."""

    codebook: jax.Array
    idx: jax.Array
    n_codes: jax.Array  # scalar int32 <= cap

    @property
    def capacity(self) -> int:
        return self.codebook.shape[0]

    @property
    def d(self) -> int:
        return self.codebook.shape[-1]

    def to_dense(self) -> jax.Array:
        return jnp.take(self.codebook, self.idx, axis=0)

    def occupancy(self) -> jax.Array:
        """Number of *distinct* codes actually referenced by idx."""
        used = jnp.zeros((self.capacity,), jnp.bool_).at[self.idx.reshape(-1)].set(True)
        return jnp.sum(used)


def from_dense_rows(rows: jax.Array, idx: jax.Array, n_codes=None) -> Compressed:
    """Wrap explicit (codebook, idx) without dedup."""
    if n_codes is None:
        n_codes = rows.shape[0]
    return Compressed(rows, idx.astype(jnp.int32), jnp.asarray(n_codes, jnp.int32))


def from_tokens(embedding: jax.Array, tokens: jax.Array) -> Compressed:
    """Token embeddings are 'born quantized' (paper footnote 1): the embedding
    matrix is the codebook and the token ids are the index map."""
    return Compressed(
        embedding, tokens.astype(jnp.int32), jnp.asarray(embedding.shape[0], jnp.int32)
    )


def compress(x: jax.Array, capacity: Optional[int] = None) -> Compressed:
    """Dedup the rows of a dense tensor [..., d] into a Compressed.

    Eager-only when ``capacity`` is None (exact size). With ``capacity`` set it
    is jit-compatible; rows beyond capacity raise in eager mode.
    """
    *lead, d = x.shape
    flat = x.reshape(-1, d)
    if capacity is None:
        np_flat = np.asarray(flat)
        uniq, inverse = np.unique(np_flat, axis=0, return_inverse=True)
        return Compressed(
            jnp.asarray(uniq),
            jnp.asarray(inverse.reshape(lead), jnp.int32),
            jnp.asarray(uniq.shape[0], jnp.int32),
        )
    # jit path: hash rows is unsafe; use lexicographic unique via void view is
    # not available in jnp. We instead require the caller to provide indices
    # (activations in this codebase are always constructed quantized).
    raise NotImplementedError(
        "jit-compatible dense compression is not needed: activations are "
        "constructed in compressed form by the VQ layers."
    )


def per_location(f: Callable[[jax.Array], jax.Array], c: Compressed) -> Compressed:
    """Apply a per-location vector op on the codebook only (paper eq. 2)."""
    return Compressed(f(c.codebook), c.idx, c.n_codes)


def binary(
    f: Callable[[jax.Array, jax.Array], jax.Array],
    a: Compressed,
    b: Compressed,
    capacity: Optional[int] = None,
) -> Compressed:
    """Binary element-wise op between two compressed tensors (App. A.3).

    If the index maps are identical this is a pure per-location op; otherwise
    we dedup the *pairs* of indices and apply ``f`` once per unique pair.
    """
    assert a.idx.shape == b.idx.shape, (a.idx.shape, b.idx.shape)
    key = a.idx.astype(jnp.int64) * int(b.capacity) + b.idx.astype(jnp.int64)
    flat = key.reshape(-1)
    if capacity is None:
        uniq, inverse = jnp.unique(flat, return_inverse=True)
        n_codes = uniq.shape[0]
    else:
        uniq, inverse = jnp.unique(
            flat, return_inverse=True, size=capacity, fill_value=jnp.int64(-1)
        )
        n_codes = jnp.sum(uniq >= 0)
    ia = (jnp.maximum(uniq, 0) // int(b.capacity)).astype(jnp.int32)
    ib = (jnp.maximum(uniq, 0) % int(b.capacity)).astype(jnp.int32)
    rows = f(jnp.take(a.codebook, ia, axis=0), jnp.take(b.codebook, ib, axis=0))
    return Compressed(
        rows,
        inverse.reshape(a.idx.shape).astype(jnp.int32),
        jnp.asarray(n_codes, jnp.int32),
    )


def add(a: Compressed, b: Compressed, capacity: Optional[int] = None) -> Compressed:
    """Residual connection over compressed tensors."""
    return binary(jnp.add, a, b, capacity=capacity)


def recompress(c: Compressed, capacity: Optional[int] = None) -> Compressed:
    """Drop unreferenced codebook rows (keeps codebooks from growing across
    layers; paper's additive-growth argument keeps this O(n+b))."""
    flat = c.idx.reshape(-1)
    if capacity is None:
        uniq, inverse = jnp.unique(flat, return_inverse=True)
        n_codes = uniq.shape[0]
    else:
        uniq, inverse = jnp.unique(
            flat, return_inverse=True, size=capacity, fill_value=jnp.int32(-1)
        )
        n_codes = jnp.sum(uniq >= 0)
    rows = jnp.take(c.codebook, jnp.maximum(uniq, 0).astype(jnp.int32), axis=0)
    return Compressed(
        rows, inverse.reshape(c.idx.shape).astype(jnp.int32), jnp.asarray(n_codes, jnp.int32)
    )


def base_and_deltas(c: Compressed) -> tuple[jax.Array, jax.Array]:
    """Sparse representation of a batch index map (paper §3.1, fig. 2).

    For idx of shape [b, n], returns (base [n], delta_mask [b, n]) where
    ``base[j]`` is the most frequent index at sequence location j and
    ``delta_mask[i, j] = idx[i, j] != base[j]``. The number of True entries in
    delta_mask is the O(b) side of the paper's O(n+b) storage bound.
    """
    idx = c.idx
    assert idx.ndim == 2, "base_and_deltas expects a [batch, seq] index map"
    # Mode along the batch axis, computed via one-hot counting over capacity.
    counts = jax.nn.one_hot(idx, c.capacity, dtype=jnp.int32).sum(axis=0)  # [n, cap]
    base = jnp.argmax(counts, axis=-1).astype(jnp.int32)  # [n]
    delta_mask = idx != base[None, :]
    return base, delta_mask
