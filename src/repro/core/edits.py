"""Edit scripts over token sequences (paper §3.3, §4).

Atomic edits are replace / insert / delete of a single token. Offline
revisions are aligned with difflib (same role as the paper's Wikipedia
revision alignment) to produce a minimal edit script.
"""
from __future__ import annotations

import difflib
from dataclasses import dataclass
from typing import Iterable, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Edit:
    op: str  # 'replace' | 'insert' | 'delete'
    pos: int  # position in the *current* sequence
    token: int = -1  # new token for replace/insert

    def __post_init__(self):
        assert self.op in ("replace", "insert", "delete"), self.op


def apply_edit(tokens: Sequence[int], e: Edit) -> list[int]:
    t = list(tokens)
    if e.op == "replace":
        t[e.pos] = e.token
    elif e.op == "insert":
        t.insert(e.pos, e.token)
    else:
        del t[e.pos]
    return t


def apply_edits(tokens: Sequence[int], edits: Iterable[Edit]) -> list[int]:
    t = list(tokens)
    for e in edits:
        t = apply_edit(t, e)
    return t


def align(old: Sequence[int], new: Sequence[int]) -> list[tuple]:
    """difflib opcodes aligning ``old`` against ``new`` — the single source
    of truth for revision alignment. Compute once and share between the
    edit-script view (``edit_script(..., opcodes=...)``) and the engine's
    batched revision path (``IncrementalEngine.apply_revision``); aligning
    twice per request is pure waste (the alignment is O(n·m))."""
    sm = difflib.SequenceMatcher(a=list(old), b=list(new), autojunk=False)
    return sm.get_opcodes()


def edit_script(old: Sequence[int], new: Sequence[int],
                opcodes: Optional[list] = None) -> list[Edit]:
    """Minimal-ish edit script old -> new, as a sequence of atomic edits whose
    positions refer to the sequence state *at the time of application*.
    Pass precomputed ``align(old, new)`` opcodes to skip the alignment."""
    if opcodes is None:
        opcodes = align(old, new)
    edits: list[Edit] = []
    shift = 0  # cumulative position shift from edits of *previous* opcodes
    for tag, i1, i2, j1, j2 in opcodes:
        if tag == "equal":
            continue
        if tag == "replace":
            common = min(i2 - i1, j2 - j1)
            for k in range(common):
                edits.append(Edit("replace", i1 + k + shift, int(new[j1 + k])))
            # deletes within a run all land on the same (post-shift) position
            for _ in range(i2 - i1 - common):
                edits.append(Edit("delete", i1 + common + shift))
            # inserts within a run advance by one per inserted token
            for k in range(j2 - j1 - common):
                edits.append(
                    Edit("insert", i1 + common + k + shift, int(new[j1 + common + k]))
                )
            shift += (j2 - j1) - (i2 - i1)
        elif tag == "delete":
            for _ in range(i2 - i1):
                edits.append(Edit("delete", i1 + shift))
            shift -= i2 - i1
        elif tag == "insert":
            for k in range(j2 - j1):
                edits.append(Edit("insert", i1 + k + shift, int(new[j1 + k])))
            shift += j2 - j1
    return edits


def random_atomic_edit(rng: np.random.Generator, tokens: Sequence[int], vocab: int,
                       ops=("replace", "insert", "delete")) -> Edit:
    op = ops[rng.integers(len(ops))]
    n = len(tokens)
    if op == "replace":
        return Edit("replace", int(rng.integers(n)), int(rng.integers(vocab)))
    if op == "insert":
        return Edit("insert", int(rng.integers(n + 1)), int(rng.integers(vocab)))
    return Edit("delete", int(rng.integers(n)))


def random_revision(
    rng: np.random.Generator,
    tokens: Sequence[int],
    vocab: int,
    edit_fraction: float,
    ops=("replace", "insert", "delete"),
) -> list[int]:
    """Produce a new revision by applying ~edit_fraction*n atomic edits at
    clustered locations (Wikipedia edits are bursty, not uniform)."""
    t = list(tokens)
    n_edits = max(1, int(round(edit_fraction * len(t))))
    # Bursty: pick a handful of cluster centers, edits near them.
    n_clusters = max(1, min(n_edits, int(rng.integers(1, 4))))
    centers = rng.integers(0, max(1, len(t)), size=n_clusters)
    for i in range(n_edits):
        c = int(centers[i % n_clusters])
        pos = int(np.clip(c + rng.integers(-8, 9), 0, max(0, len(t) - 1)))
        op = ops[rng.integers(len(ops))]
        if op == "replace" and len(t) > 0:
            t[pos] = int(rng.integers(vocab))
        elif op == "insert":
            t.insert(pos, int(rng.integers(vocab)))
        elif op == "delete" and len(t) > 1:
            del t[pos]
    return t
