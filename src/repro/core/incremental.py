"""The paper's incremental-inference engine for VQ-Transformers (§3, App. A).

Processes *edits* to a cached document instead of re-running the model:

* per-location ops (norms, QKV/FFN projections) run only at *dirty*
  positions (§3.2 — across a batch of revisions this is the compressed-
  format trick; for a single edited document the unique rows ARE the dirty
  positions);
* self-attention is patched row/column-wise (App. A.1): an edited position
  contributes one changed query row (recompute that row) and one changed
  key/value column (patch all later rows' accumulated sums);
* the VQ score trick (App. A.2): because attention is linear in V, we track
  the per-row *codebook scores* ``T[i,h,c] = Σ_j w[h,i,j] · (v[j,h]·C_c)``
  instead of the attention output itself, so re-quantization after a patch
  costs O(q) per row, and the quantized output is reconstructed from the
  precomputed ``C @ W_o`` table in O(h·d);
* positions whose VQ code did **not** change stop propagating — the paper's
  central filtering effect. The dirty set of layer l+1 is
  ``{code changed} ∪ {residual input changed}``.

The engine is a host-side (NumPy) dynamic-shape implementation — the paper's
evaluation metric is *counted arithmetic operations*, not wall-clock, and
every operation is metered through ``OpCounter`` with the same conventions as
the dense baseline (``opcount.dense_transformer_forward_ops``). The
TPU-native static-bucket variant lives in ``repro.serving`` / ``repro.kernels``.

Exactness invariant (tested): incremental state == ``full_forward`` of the
edited document, bit-for-bit in float32 (same primitive order for patched
quantities, same codes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.edits import Edit
from repro.core.opcount import OpCounter

_SQRT_2_OVER_PI = np.sqrt(2.0 / np.pi).astype(np.float32)


def gelu(x: np.ndarray) -> np.ndarray:
    """tanh-approximate GELU, matching jax.nn.gelu(approximate=True)."""
    x = x.astype(np.float32)
    return 0.5 * x * (1.0 + np.tanh(_SQRT_2_OVER_PI * (x + 0.044715 * x ** 3)))


def layernorm(x: np.ndarray, scale: np.ndarray, bias: np.ndarray, eps=1e-5) -> np.ndarray:
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / np.sqrt(var + eps) * scale + bias


@dataclass
class LayerWeights:
    ln1_s: np.ndarray
    ln1_b: np.ndarray
    wq: np.ndarray  # [d, H, dh]
    bq: np.ndarray  # [H, dh]
    wk: np.ndarray
    bk: np.ndarray
    wv: np.ndarray
    bv: np.ndarray
    bo: np.ndarray  # [d]
    ln2_s: np.ndarray
    ln2_b: np.ndarray
    w_up: np.ndarray
    b_up: np.ndarray
    w_down: np.ndarray
    b_down: np.ndarray
    # VQ tables
    codebook: np.ndarray  # [hq, Q, d_vq]  (d_vq = H*dh / hq)
    vq_bias: np.ndarray  # [hq, Q] = -||C||^2/2
    c_wo: np.ndarray  # [hq, Q, d]  codebook rows pushed through W_o


@dataclass
class LayerState:
    """Cached per-layer activations for one document."""

    q: np.ndarray  # [n, H, dh]
    k: np.ndarray
    v: np.ndarray
    vc: np.ndarray  # [n, H, Q] per-head value·codebook inner products
    T: np.ndarray  # [n, H, Q] accumulated w̃·vc sums (unnormalized scores)
    codes: np.ndarray  # [n, hq] int32

    def copy(self) -> "LayerState":
        return LayerState(*(a.copy() for a in dataclasses.astuple(self)))


@dataclass
class DocState:
    tokens: np.ndarray  # [n] int
    positions: np.ndarray  # [n] int (gapped ids; order == sequence order)
    xs: list  # L+1 residual-stream snapshots [n, d]
    layers: list  # list[LayerState]

    @property
    def n(self) -> int:
        return len(self.tokens)

    def copy(self) -> "DocState":
        return DocState(
            self.tokens.copy(),
            self.positions.copy(),
            [x.copy() for x in self.xs],
            [l.copy() for l in self.layers],
        )


def _flatten_stage_params(params: dict, cfg: ArchConfig) -> list[dict]:
    import jax

    out = []
    for (pattern, repeat), sp in zip(cfg.stages, params["stages"]):
        for r in range(repeat):
            layer_params = jax.tree.map(lambda a: np.asarray(a[r]), sp)
            out.extend(layer_params)
    return out


class IncrementalEngine:
    """Incremental inference for a VQT model (gqa mixer, dense FFN, σ-attention,
    multi-head VQ on attention outputs, absolute positional embeddings)."""

    def __init__(self, params: dict, cfg: ArchConfig, counter: Optional[OpCounter] = None):
        assert cfg.vqt is not None, "IncrementalEngine requires a VQT config"
        assert not cfg.attn_softmax, "VQT uses element-wise σ attention (paper eq. 1)"
        assert cfg.pos in ("learned", "sampled"), "VQT uses absolute positional embeddings"
        for layer in cfg.layer_list():
            assert layer.mixer == "gqa" and layer.ffn in ("gelu", "relu", "relu2"), (
                "engine supports the paper's OPT-style blocks; "
                f"got mixer={layer.mixer} ffn={layer.ffn}"
            )
        assert cfg.n_kv_heads == cfg.n_heads, "engine assumes MHA (OPT)"
        self.cfg = cfg
        self.counter = counter if counter is not None else OpCounter()
        self.H = cfg.n_heads
        self.dh = cfg.resolved_head_dim
        self.d = cfg.d_model
        self.scale = np.float32(self.dh ** -0.5)
        self.hq = cfg.vqt.n_heads
        self.Q = cfg.vqt.codebook_size
        self.d_vq = (self.H * self.dh) // self.hq
        self.heads_per_vq = self.H // self.hq
        assert self.H % self.hq == 0, "attention heads must split evenly across VQ heads"

        emb = params["embed"]
        self.tok_emb = np.asarray(emb["tok"], np.float32)
        self.pos_emb = np.asarray(emb["pos"], np.float32)
        self.fn_s = np.asarray(params["final_norm"]["scale"], np.float32)
        self.fn_b = np.asarray(params["final_norm"]["bias"], np.float32)
        self.head_w = (
            self.tok_emb.T if cfg.tie_embeddings else np.asarray(params["lm_head"], np.float32)
        )

        self.layers: list[LayerWeights] = []
        for lp in _flatten_stage_params(params, cfg):
            mp = lp["mixer"]
            d, H, dh = self.d, self.H, self.dh
            cb = np.asarray(mp["vq"].codebook, np.float32)  # [hq, Q, d_vq]
            wo = np.asarray(mp["wo"], np.float32)  # [H*dh, d]
            c_wo = np.einsum(
                "hqv,hvd->hqd", cb, wo.reshape(self.hq, self.d_vq, d)
            )  # [hq, Q, d]
            self.layers.append(
                LayerWeights(
                    ln1_s=np.asarray(lp["norm1"]["scale"], np.float32),
                    ln1_b=np.asarray(lp["norm1"]["bias"], np.float32),
                    wq=np.asarray(mp["wq"], np.float32).reshape(d, H, dh),
                    bq=np.asarray(mp["bq"], np.float32).reshape(H, dh),
                    wk=np.asarray(mp["wk"], np.float32).reshape(d, H, dh),
                    bk=np.asarray(mp["bk"], np.float32).reshape(H, dh),
                    wv=np.asarray(mp["wv"], np.float32).reshape(d, H, dh),
                    bv=np.asarray(mp["bv"], np.float32).reshape(H, dh),
                    bo=np.asarray(mp["bo"], np.float32),
                    ln2_s=np.asarray(lp["norm2"]["scale"], np.float32),
                    ln2_b=np.asarray(lp["norm2"]["bias"], np.float32),
                    w_up=np.asarray(lp["ffn"]["w_up"], np.float32),
                    b_up=np.asarray(lp["ffn"]["b_up"], np.float32),
                    w_down=np.asarray(lp["ffn"]["w_down"], np.float32),
                    b_down=np.asarray(lp["ffn"]["b_down"], np.float32),
                    codebook=cb,
                    vq_bias=-0.5 * np.sum(cb ** 2, axis=-1),
                    c_wo=c_wo,
                )
            )

    # ------------------------------------------------------------- pieces

    def _embed(self, tokens: np.ndarray, positions: np.ndarray) -> np.ndarray:
        self.counter.elementwise("embed", tokens.size * self.d)
        return self.tok_emb[tokens] + self.pos_emb[positions]

    def _qkv_at(self, W: LayerWeights, x_rows: np.ndarray):
        """Per-location: LN1 + QKV projections for a set of rows [m, d]."""
        m = x_rows.shape[0]
        self.counter.elementwise("perloc_ln", m * self.d, 8)
        h = layernorm(x_rows, W.ln1_s, W.ln1_b)
        self.counter.matmul("perloc_qkv", m, self.d, 3 * self.H * self.dh)
        q = np.einsum("md,dhe->mhe", h, W.wq) + W.bq
        k = np.einsum("md,dhe->mhe", h, W.wk) + W.bk
        v = np.einsum("md,dhe->mhe", h, W.wv) + W.bv
        return q, k, v

    def _vc_of(self, W: LayerWeights, v_rows: np.ndarray) -> np.ndarray:
        """v rows [m, H, dh] -> per-attention-head codebook products [m, H, Q]."""
        m = v_rows.shape[0]
        # codebook resliced so each attention head sees its span of the VQ chunk:
        # [hq, Q, heads_per_vq, dh] -> [hq, heads_per_vq, Q, dh] -> [H, Q, dh]
        cb = W.codebook.reshape(self.hq, self.Q, self.heads_per_vq, self.dh)
        cb_per_head = cb.transpose(0, 2, 1, 3).reshape(self.H, self.Q, self.dh)
        self.counter.matmul("vq_vc", m * self.H, self.dh, self.Q)
        return np.einsum("mhe,hqe->mhq", v_rows, cb_per_head)

    def _row_scores(self, W: LayerWeights, q_rows: np.ndarray, st: LayerState,
                    row_idx: np.ndarray) -> np.ndarray:
        """Full row recompute of T for query rows (App. A.1 'altered rows').

        q_rows: [m, H, dh] for rows row_idx (sorted). Returns T rows [m, H, Q].
        """
        m = len(row_idx)
        if m == 0:
            return np.zeros((0, self.H, self.Q), np.float32)
        n = st.k.shape[0]
        self.counter.matmul("attn_row_scores", m * self.H, self.dh, n)
        s = np.einsum("mhe,jhe->mhj", q_rows, st.k) * self.scale  # [m, H, n]
        self.counter.elementwise("attn_sigma", m * self.H * n)
        w = gelu(s)
        # causal mask: row i attends to j <= i
        mask = np.arange(n)[None, :] <= row_idx[:, None]  # [m, n]
        w = w * mask[:, None, :]
        self.counter.matmul("attn_row_accum", m * self.H, n, self.Q)
        return np.einsum("mhj,jhq->mhq", w, st.vc)

    def _codes_of(self, T_rows: np.ndarray, W: LayerWeights, counts: np.ndarray) -> np.ndarray:
        """T rows [m, H, Q] + attended counts [m] -> VQ codes [m, hq]."""
        m = T_rows.shape[0]
        s = T_rows.reshape(m, self.hq, self.heads_per_vq, self.Q).sum(2)  # [m, hq, Q]
        s = s / counts[:, None, None] + W.vq_bias[None]
        self.counter.elementwise("vq_argmax", m * self.hq * self.Q, 2)
        return np.argmax(s, axis=-1).astype(np.int32)

    def _attn_out(self, W: LayerWeights, codes: np.ndarray) -> np.ndarray:
        """Quantized attention output via the precomputed C@W_o table [m, d]."""
        m = codes.shape[0]
        self.counter.elementwise("attn_out_lookup", m * self.hq * self.d)
        out = W.bo[None, :].repeat(m, 0)
        for h in range(self.hq):
            out += W.c_wo[h][codes[:, h]]
        return out

    def _ffn_at(self, W: LayerWeights, x_rows: np.ndarray) -> np.ndarray:
        m = x_rows.shape[0]
        self.counter.elementwise("perloc_ln", m * self.d, 8)
        h = layernorm(x_rows, W.ln2_s, W.ln2_b)
        self.counter.matmul("perloc_ffn", m, self.d, self.cfg.d_ff)
        u = h @ W.w_up + W.b_up
        self.counter.elementwise("ffn_gelu", m * self.cfg.d_ff)
        u = gelu(u)
        self.counter.matmul("perloc_ffn", m, self.cfg.d_ff, self.d)
        return u @ W.w_down + W.b_down

    # ------------------------------------------------------------- full pass

    def full_forward(self, tokens: Sequence[int], positions: Sequence[int]) -> DocState:
        tokens = np.asarray(tokens, np.int64)
        positions = np.asarray(positions, np.int64)
        n = len(tokens)
        x = self._embed(tokens, positions)
        xs = [x.copy()]
        layers = []
        counts = np.arange(1, n + 1, dtype=np.float32)
        all_rows = np.arange(n)
        for W in self.layers:
            q, k, v = self._qkv_at(W, x)
            vc = self._vc_of(W, v)
            st = LayerState(q=q, k=k, v=v, vc=vc, T=None, codes=None)  # type: ignore
            st.T = self._row_scores(W, q, st, all_rows)
            st.codes = self._codes_of(st.T, W, counts)
            x = x + self._attn_out(W, st.codes)
            self.counter.elementwise("residual", n * self.d)
            x = x + self._ffn_at(W, x)
            self.counter.elementwise("residual", n * self.d)
            layers.append(st)
            xs.append(x.copy())
        return DocState(tokens, positions, xs, layers)

    # ------------------------------------------------------------- edits

    def apply_replaces(self, state: DocState, pos_list: Sequence[int],
                       new_tokens: Sequence[int]) -> DocState:
        """Batched token replacement (offline revisions collapse to this after
        alignment). Dirty-set propagation per §3.2 / App. A.1."""
        state = state.copy()
        order = np.argsort(np.asarray(pos_list))
        D = np.asarray(pos_list, np.int64)[order]
        state.tokens[D] = np.asarray(new_tokens, np.int64)[order]
        n = state.n
        counts = np.arange(1, n + 1, dtype=np.float32)

        new_x_rows = self._embed(state.tokens[D], state.positions[D])
        dirty = D
        x_prev_rows = new_x_rows  # new residual-stream rows at `dirty`
        for li, W in enumerate(self.layers):
            st = state.layers[li]
            x_in = state.xs[li]
            # 1. per-location updates at dirty rows
            old_k = st.k[dirty].copy()
            old_vc = st.vc[dirty].copy()
            x_in[dirty] = x_prev_rows
            q_new, k_new, v_new = self._qkv_at(W, x_prev_rows)
            vc_new = self._vc_of(W, v_new)
            st.q[dirty], st.k[dirty], st.v[dirty], st.vc[dirty] = q_new, k_new, v_new, vc_new

            # 2a. column patches: rows i > min(dirty), i not dirty
            #     ΔT[i] = Σ_{j∈dirty, j<=i} w̃_new[i,j]·vc_new[j] − w̃_old[i,j]·vc_old[j]
            first = int(dirty.min())
            later = np.setdiff1d(np.arange(first, n), dirty, assume_unique=False)
            if len(later) > 0:
                q_rows = st.q[later]  # unchanged queries
                self.counter.matmul("attn_col_scores", len(later) * self.H, self.dh,
                                    2 * len(dirty))
                s_new = np.einsum("mhe,jhe->mhj", q_rows, k_new) * self.scale
                s_old = np.einsum("mhe,jhe->mhj", q_rows, old_k) * self.scale
                self.counter.elementwise("attn_sigma", 2 * len(later) * self.H * len(dirty))
                w_new, w_old = gelu(s_new), gelu(s_old)
                mask = dirty[None, :] <= later[:, None]  # causal: col j <= row i
                w_new = w_new * mask[:, None, :]
                w_old = w_old * mask[:, None, :]
                self.counter.matmul("attn_col_patch", len(later) * self.H, len(dirty),
                                    2 * self.Q)
                st.T[later] += np.einsum("mhj,jhq->mhq", w_new, vc_new) - np.einsum(
                    "mhj,jhq->mhq", w_old, old_vc
                )
            # 2b. dirty rows: full row recompute
            st.T[dirty] = self._row_scores(W, q_new, st, dirty)

            # 3. re-quantize affected rows; filtering = unchanged codes stop here
            affected = np.union1d(later, dirty) if len(later) else dirty
            new_codes = self._codes_of(st.T[affected], W, counts[affected])
            code_changed = affected[np.any(new_codes != st.codes[affected], axis=1)]
            st.codes[affected] = new_codes
            changed = np.union1d(code_changed, dirty)

            # 4. rebuild residual stream at changed rows only
            x_mid_rows = x_in[changed] + self._attn_out(W, st.codes[changed])
            self.counter.elementwise("residual", len(changed) * self.d)
            x_out_rows = x_mid_rows + self._ffn_at(W, x_mid_rows)
            self.counter.elementwise("residual", len(changed) * self.d)
            state.xs[li + 1][changed] = x_out_rows
            dirty = changed
            x_prev_rows = x_out_rows
        return state

    def _renumber_insert(self, state: DocState, p: int, token: int, position_id: int) -> None:
        """Grow every cached array by one row at sequence index p."""
        state.tokens = np.insert(state.tokens, p, token)
        state.positions = np.insert(state.positions, p, position_id)
        for li in range(len(self.layers)):
            st = state.layers[li]
            for name in ("q", "k", "v", "vc", "T"):
                arr = getattr(st, name)
                setattr(st, name, np.insert(arr, p, 0.0, axis=0))
            st.codes = np.insert(st.codes, p, 0, axis=0)
        state.xs = [np.insert(x, p, 0.0, axis=0) for x in state.xs]

    def apply_insert(self, state: DocState, p: int, token: int, position_id: int) -> DocState:
        """Insert a token before sequence index p with a pre-allocated gapped
        position id (paper §3.3). Later rows gain one attended column and a
        renormalization; the new row is computed like a dirty row."""
        state = state.copy()
        self._renumber_insert(state, p, token, position_id)
        n = state.n
        counts = np.arange(1, n + 1, dtype=np.float32)
        x_new = self._embed(state.tokens[p : p + 1], state.positions[p : p + 1])
        dirty = np.array([p])
        x_prev_rows = x_new
        for li, W in enumerate(self.layers):
            st = state.layers[li]
            x_in = state.xs[li]
            # the inserted row itself (always dirty) + any propagated rows
            x_in[dirty] = x_prev_rows
            q_new, k_new, v_new = self._qkv_at(W, x_prev_rows)
            vc_new = self._vc_of(W, v_new)
            # rows at/after the *insert point* see a new column & count change;
            # rows in `dirty` (propagated) need handling like replaces.
            insert_dirty = dirty[dirty == p]
            repl_dirty = dirty[dirty != p]
            old_k = st.k[repl_dirty].copy()
            old_vc = st.vc[repl_dirty].copy()
            st.q[dirty], st.k[dirty], st.v[dirty], st.vc[dirty] = q_new, k_new, v_new, vc_new

            later = np.setdiff1d(np.arange(p, n), dirty)
            if len(later) > 0:
                q_rows = st.q[later]
                # new column at p (always present for rows > p)
                self.counter.matmul("attn_col_scores", len(later) * self.H, self.dh, 1)
                s_p = np.einsum("mhe,he->mh", q_rows, st.k[p]) * self.scale
                self.counter.elementwise("attn_sigma", len(later) * self.H)
                w_p = gelu(s_p)
                self.counter.matmul("attn_col_patch", len(later) * self.H, 1, self.Q)
                st.T[later] += w_p[..., None] * st.vc[p][None]
                # replaced (propagated) columns among dirty rows
                if len(repl_dirty) > 0:
                    self.counter.matmul(
                        "attn_col_scores", len(later) * self.H, self.dh, 2 * len(repl_dirty)
                    )
                    s_new = np.einsum("mhe,jhe->mhj", q_rows, st.k[repl_dirty]) * self.scale
                    s_old = np.einsum("mhe,jhe->mhj", q_rows, old_k) * self.scale
                    self.counter.elementwise(
                        "attn_sigma", 2 * len(later) * self.H * len(repl_dirty)
                    )
                    w_new, w_old = gelu(s_new), gelu(s_old)
                    mask = repl_dirty[None, :] <= later[:, None]
                    w_new, w_old = w_new * mask[:, None, :], w_old * mask[:, None, :]
                    self.counter.matmul(
                        "attn_col_patch", len(later) * self.H, len(repl_dirty), 2 * self.Q
                    )
                    st.T[later] += np.einsum(
                        "mhj,jhq->mhq", w_new, st.vc[repl_dirty]
                    ) - np.einsum("mhj,jhq->mhq", w_old, old_vc)
            st.T[dirty] = self._row_scores(W, st.q[dirty], st, dirty)

            affected = np.union1d(later, dirty) if len(later) else dirty
            # count renormalization shifts all rows >= p (handled in _codes_of
            # via the counts vector, which already reflects the new length)
            new_codes = self._codes_of(st.T[affected], W, counts[affected])
            code_changed = affected[np.any(new_codes != st.codes[affected], axis=1)]
            st.codes[affected] = new_codes
            changed = np.union1d(code_changed, dirty)

            x_mid_rows = x_in[changed] + self._attn_out(W, st.codes[changed])
            self.counter.elementwise("residual", len(changed) * self.d)
            x_out_rows = x_mid_rows + self._ffn_at(W, x_mid_rows)
            self.counter.elementwise("residual", len(changed) * self.d)
            state.xs[li + 1][changed] = x_out_rows
            dirty = changed
            x_prev_rows = x_out_rows
        return state

    def apply_delete(self, state: DocState, p: int) -> DocState:
        """Delete the token at sequence index p. Later rows lose one column
        (patch T by subtraction) and renormalize."""
        state = state.copy()
        n_old = state.n
        # subtract the deleted column's contribution from all later rows
        for li, W in enumerate(self.layers):
            st = state.layers[li]
            later = np.arange(p + 1, n_old)
            if len(later) > 0:
                q_rows = st.q[later]
                self.counter.matmul("attn_col_scores", len(later) * self.H, self.dh, 1)
                s_p = np.einsum("mhe,he->mh", q_rows, st.k[p]) * self.scale
                self.counter.elementwise("attn_sigma", len(later) * self.H)
                w_p = gelu(s_p)
                self.counter.matmul("attn_col_patch", len(later) * self.H, 1, self.Q)
                st.T[later] -= w_p[..., None] * st.vc[p][None]
        # shrink every cached array
        state.tokens = np.delete(state.tokens, p)
        state.positions = np.delete(state.positions, p)
        for li in range(len(self.layers)):
            st = state.layers[li]
            for name in ("q", "k", "v", "vc", "T"):
                setattr(st, name, np.delete(getattr(st, name), p, axis=0))
            st.codes = np.delete(st.codes, p, axis=0)
        state.xs = [np.delete(x, p, axis=0) for x in state.xs]
        n = state.n
        counts = np.arange(1, n + 1, dtype=np.float32)

        # re-quantize rows >= p (count renormalization) and propagate
        dirty = np.zeros((0,), np.int64)
        x_prev_rows = np.zeros((0, self.d), np.float32)
        for li, W in enumerate(self.layers):
            st = state.layers[li]
            x_in = state.xs[li]
            old_k = st.k[dirty].copy()
            old_vc = st.vc[dirty].copy()
            x_in[dirty] = x_prev_rows
            if len(dirty) > 0:
                q_new, k_new, v_new = self._qkv_at(W, x_prev_rows)
                vc_new = self._vc_of(W, v_new)
                st.q[dirty], st.k[dirty], st.v[dirty], st.vc[dirty] = (
                    q_new, k_new, v_new, vc_new,
                )
            later = np.setdiff1d(np.arange(p, n), dirty)
            if len(later) > 0 and len(dirty) > 0:
                q_rows = st.q[later]
                self.counter.matmul(
                    "attn_col_scores", len(later) * self.H, self.dh, 2 * len(dirty)
                )
                s_new = np.einsum("mhe,jhe->mhj", q_rows, st.k[dirty]) * self.scale
                s_old = np.einsum("mhe,jhe->mhj", q_rows, old_k) * self.scale
                self.counter.elementwise("attn_sigma", 2 * len(later) * self.H * len(dirty))
                w_new, w_old = gelu(s_new), gelu(s_old)
                mask = dirty[None, :] <= later[:, None]
                w_new, w_old = w_new * mask[:, None, :], w_old * mask[:, None, :]
                self.counter.matmul(
                    "attn_col_patch", len(later) * self.H, len(dirty), 2 * self.Q
                )
                st.T[later] += np.einsum("mhj,jhq->mhq", w_new, st.vc[dirty]) - np.einsum(
                    "mhj,jhq->mhq", w_old, old_vc
                )
            if len(dirty) > 0:
                st.T[dirty] = self._row_scores(W, st.q[dirty], st, dirty)
            affected = np.union1d(later, dirty)
            if len(affected) == 0:
                continue
            new_codes = self._codes_of(st.T[affected], W, counts[affected])
            code_changed = affected[np.any(new_codes != st.codes[affected], axis=1)]
            st.codes[affected] = new_codes
            changed = np.union1d(code_changed, dirty).astype(np.int64)

            x_mid_rows = x_in[changed] + self._attn_out(W, st.codes[changed])
            self.counter.elementwise("residual", len(changed) * self.d)
            x_out_rows = x_mid_rows + self._ffn_at(W, x_mid_rows)
            self.counter.elementwise("residual", len(changed) * self.d)
            state.xs[li + 1][changed] = x_out_rows
            dirty = changed
            x_prev_rows = x_out_rows
        return state

    def apply_revision(self, state: DocState, new_tokens: Sequence[int],
                       allocator=None, opcodes=None) -> DocState:
        """Offline batch path (paper §3 / App. A.1): align a whole revision
        against the cached document and process ALL structural changes in a
        single pass per layer — one column-patch sweep instead of one per
        edit. Falls back to a (counted) full forward when the positional
        gaps cannot host the inserted tokens. Pass precomputed
        ``core.edits.align(state.tokens, new_tokens)`` opcodes to reuse an
        alignment the caller already needed (e.g. for edit-count stats).
        """
        from repro.core.edits import align

        old_tokens = state.tokens
        new_tokens = np.asarray(list(new_tokens), np.int64)
        if opcodes is None:
            opcodes = align(old_tokens, new_tokens)
        kept_old, kept_new = [], []
        m0 = None  # first new index affected by any change
        for tag, i1, i2, j1, j2 in opcodes:
            if tag == "equal":
                kept_old.extend(range(i1, i2))
                kept_new.extend(range(j1, j2))
            elif m0 is None:
                m0 = j1
        if m0 is None:  # identical revision
            return state.copy()
        kept_old = np.asarray(kept_old, np.int64)
        kept_new = np.asarray(kept_new, np.int64)
        n_new = len(new_tokens)
        fresh = np.setdiff1d(np.arange(n_new), kept_new)
        removed_old = np.setdiff1d(np.arange(state.n), kept_old)

        # ---- position ids: kept rows keep theirs; fresh runs get mid-gap ids
        new_positions = np.full(n_new, -1, np.int64)
        new_positions[kept_new] = state.positions[kept_old]
        pool = self.pos_emb.shape[0]
        ok = True
        i = 0
        while i < n_new:
            if new_positions[i] >= 0:
                i += 1
                continue
            run_start = i
            while i < n_new and new_positions[i] < 0:
                i += 1
            lo = new_positions[run_start - 1] if run_start > 0 else -1
            hi = new_positions[i] if i < n_new else pool
            run = i - run_start
            if hi - lo - 1 < run:
                ok = False
                break
            for k in range(run):
                new_positions[run_start + k] = lo + (hi - lo) * (k + 1) // (run + 1)
            if len(set(new_positions[run_start:i])) != run:
                ok = False
                break
        if not ok:
            # defragment: every id changes -> full recompute (counted)
            if allocator is not None:
                allocator.positions = [0] * n_new
                allocator.defragment()
                pos = np.asarray(allocator.positions)
            else:
                from repro.core.positional import spread_positions

                pos = spread_positions(n_new, pool)
            return self.full_forward(new_tokens, pos)
        if allocator is not None:
            allocator.positions = [int(p) for p in new_positions]

        out = DocState(new_tokens.copy(), new_positions, [], [])
        counts = np.arange(1, n_new + 1, dtype=np.float32)
        value_dirty = fresh  # rows whose residual input changed (new indexing)
        x_dirty_rows = self._embed(new_tokens[fresh], new_positions[fresh])
        for li, W in enumerate(self.layers):
            old_st = state.layers[li]
            old_x = state.xs[li]
            # structural copy of the residual-stream input
            x_in = np.zeros((n_new, self.d), np.float32)
            x_in[kept_new] = old_x[kept_old]
            x_in[value_dirty] = x_dirty_rows
            st = LayerState(
                q=np.zeros((n_new, self.H, self.dh), np.float32),
                k=np.zeros((n_new, self.H, self.dh), np.float32),
                v=np.zeros((n_new, self.H, self.dh), np.float32),
                vc=np.zeros((n_new, self.H, self.Q), np.float32),
                T=np.zeros((n_new, self.H, self.Q), np.float32),
                codes=np.zeros((n_new, self.hq), np.int32),
            )
            for name in ("q", "k", "v", "vc", "T"):
                getattr(st, name)[kept_new] = getattr(old_st, name)[kept_old]
            st.codes[kept_new] = old_st.codes[kept_old]
            # per-location updates at value-dirty rows
            q_new, k_new, v_new = self._qkv_at(W, x_in[value_dirty])
            vc_new = self._vc_of(W, v_new)
            st.q[value_dirty], st.k[value_dirty] = q_new, k_new
            st.v[value_dirty], st.vc[value_dirty] = v_new, vc_new

            # ---- single column-patch sweep over stable kept rows ----
            stable = kept_new[kept_new >= m0]
            stable = np.setdiff1d(stable, value_dirty)
            if len(stable) > 0:
                q_rows = st.q[stable]  # unchanged queries
                # (a) subtract columns that vanished or changed value:
                #     removed old columns + old values of value-dirty kept rows
                vdirty_kept_old = kept_old[np.isin(kept_new, value_dirty)]
                sub_old = np.concatenate([removed_old, vdirty_kept_old])
                if len(sub_old) > 0:
                    stable_old = kept_old[np.isin(kept_new, stable)]
                    self.counter.matmul("attn_col_scores", len(stable) * self.H,
                                        self.dh, len(sub_old))
                    s_old = np.einsum("mhe,jhe->mhj", q_rows, old_st.k[sub_old]) \
                        * self.scale
                    self.counter.elementwise(
                        "attn_sigma", len(stable) * self.H * len(sub_old))
                    w_old = gelu(s_old) * (sub_old[None, :] <= stable_old[:, None]
                                           )[:, None, :]
                    self.counter.matmul("attn_col_patch", len(stable) * self.H,
                                        len(sub_old), self.Q)
                    st.T[stable] -= np.einsum("mhj,jhq->mhq", w_old,
                                              old_st.vc[sub_old])
                # (b) add new/changed columns (new indexing)
                add_new = np.union1d(fresh, value_dirty)
                if len(add_new) > 0:
                    self.counter.matmul("attn_col_scores", len(stable) * self.H,
                                        self.dh, len(add_new))
                    s_n = np.einsum("mhe,jhe->mhj", q_rows, st.k[add_new]) * self.scale
                    self.counter.elementwise(
                        "attn_sigma", len(stable) * self.H * len(add_new))
                    w_n = gelu(s_n) * (add_new[None, :] <= stable[:, None])[:, None, :]
                    self.counter.matmul("attn_col_patch", len(stable) * self.H,
                                        len(add_new), self.Q)
                    st.T[stable] += np.einsum("mhj,jhq->mhq", w_n, st.vc[add_new])
            # dirty rows: full recompute against the new arrays
            st.T[value_dirty] = self._row_scores(W, st.q[value_dirty], st, value_dirty)

            # re-quantize everything at/after the first edit (count renorm)
            affected = np.arange(m0, n_new)
            if len(affected) > 0:
                new_codes = self._codes_of(st.T[affected], W, counts[affected])
                code_changed = affected[np.any(new_codes != st.codes[affected], axis=1)]
                st.codes[affected] = new_codes
            else:
                code_changed = np.zeros((0,), np.int64)
            changed = np.union1d(code_changed, value_dirty).astype(np.int64)

            x_mid = x_in[changed] + self._attn_out(W, st.codes[changed])
            self.counter.elementwise("residual", len(changed) * self.d)
            x_out_rows = x_mid + self._ffn_at(W, x_mid)
            self.counter.elementwise("residual", len(changed) * self.d)
            out.layers.append(st)
            out.xs.append(x_in)
            value_dirty = changed
            x_dirty_rows = x_out_rows
        # final residual stream snapshot
        x_last = np.zeros((n_new, self.d), np.float32)
        x_last[kept_new] = state.xs[-1][kept_old]
        x_last[value_dirty] = x_dirty_rows
        out.xs.append(x_last)
        return out

    def apply_edit(self, state: DocState, e: Edit, allocator=None) -> DocState:
        """Apply one atomic edit. For inserts an id is taken from ``allocator``
        (PositionAllocator); if the gap is exhausted the engine defragments
        and re-runs a full forward (counted — paper §3.3)."""
        if e.op == "replace":
            return self.apply_replaces(state, [e.pos], [e.token])
        if e.op == "delete":
            if allocator is not None:
                allocator.delete_at(e.pos)
            return self.apply_delete(state, e.pos)
        # insert
        if allocator is None:
            # fabricate a mid-gap id (test paths)
            lo = state.positions[e.pos - 1] if e.pos > 0 else -1
            hi = (
                state.positions[e.pos]
                if e.pos < state.n
                else self.pos_emb.shape[0]
            )
            if hi - lo <= 1:
                raise ValueError("no positional gap; provide an allocator")
            pid = int((lo + hi) // 2)
        else:
            pid = allocator.insert_at(e.pos)
            if pid is None:
                # defragmentation: every position id changes -> full recompute
                # (counted; paper §3.3 "akin to defragmentation")
                allocator.positions.insert(e.pos, -1)  # placeholder, re-spread next
                new_positions = allocator.defragment()
                tokens = list(state.tokens)
                tokens.insert(e.pos, e.token)
                return self.full_forward(tokens, list(new_positions))
        return self.apply_insert(state, e.pos, e.token, pid)

    # ------------------------------------------------------------- outputs

    def logits_at(self, state: DocState, row: int = -1) -> np.ndarray:
        x = state.xs[-1][row]
        self.counter.elementwise("perloc_ln", self.d, 8)
        h = layernorm(x[None], self.fn_s, self.fn_b)[0]
        self.counter.matmul("head", 1, self.d, self.head_w.shape[1])
        return h @ self.head_w

    def hidden(self, state: DocState) -> np.ndarray:
        return state.xs[-1]
