"""Arithmetic-operation accounting (paper Tables 2, Figs. 3-4).

The paper's headline numbers are *theoretical arithmetic operations* for the
forward pass, assuming the previous revision is cached. We count
multiply-accumulates as 2 ops (one mul + one add) and element-wise ops as 1,
consistently for the dense baseline and the incremental path, so the ratios
are implementation-independent.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field


@dataclass
class OpCounter:
    counts: dict = field(default_factory=lambda: defaultdict(int))

    def add(self, name: str, ops) -> None:
        self.counts[name] += int(ops)

    def matmul(self, name: str, m, k, n) -> None:
        """[m,k] @ [k,n] -> 2*m*k*n ops."""
        self.add(name, 2 * int(m) * int(k) * int(n))

    def elementwise(self, name: str, numel, ops_per_element=1) -> None:
        self.add(name, int(numel) * int(ops_per_element))

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def merge(self, other: "OpCounter") -> None:
        for k, v in other.counts.items():
            self.counts[k] += v

    def summary(self) -> dict:
        out = dict(sorted(self.counts.items()))
        out["TOTAL"] = self.total
        return out


def dense_transformer_forward_ops(
    *,
    n_layers: int,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    vocab: int,
    seq_len: int,
    ffn_gated: bool = False,
    include_lm_head: bool = True,
) -> int:
    """Analytic op count for one full dense forward pass over ``seq_len``
    tokens (the paper's baseline: re-running OPT from scratch per revision).
    """
    n = seq_len
    d = d_model
    dh = d // n_heads
    ops = 0
    per_layer = 0
    # QKV + output projections.
    per_layer += 2 * n * d * d  # Q
    per_layer += 2 * 2 * n * d * (n_kv_heads * dh)  # K, V
    per_layer += 2 * n * d * d  # out proj
    # Attention core: QK^T and AV, per head.
    per_layer += 2 * n * n * d  # QK^T over all heads = 2*n*n*dh*h
    per_layer += 2 * n * n * d  # AV
    per_layer += n * n * n_heads  # sigma / softmax-ish elementwise (1 op/entry)
    # FFN.
    ffn_mats = 3 if ffn_gated else 2
    per_layer += 2 * ffn_mats * n * d * d_ff
    per_layer += n * d_ff  # activation
    # Norms + residuals (per-location, ~8 ops/element for LN, 1 for add).
    per_layer += 2 * 8 * n * d + 2 * n * d
    ops += n_layers * per_layer
    if include_lm_head:
        ops += 2 * n * d * vocab
    return ops
