"""Sampled absolute positional embeddings (paper §3.3, App. B).

Training samples a random *ordered* subset of a large positional-embedding
pool per document, so the network learns to use only the relative order of
position ids, not their absolute values. At serving time this lets us assign
*gapped* position ids so that token insertion gets a fresh id between its
neighbours without shifting anyone else — the key to reusing activations
across insert/delete edits.
"""
from __future__ import annotations

import bisect

import jax
import jax.numpy as jnp
import numpy as np


def sample_positions(key: jax.Array, n: int, pool_size: int) -> jax.Array:
    """Sample a sorted n-subset of [0, pool_size) (training mode)."""
    if n > pool_size:
        raise ValueError(f"n={n} > pool_size={pool_size}")
    # Gumbel top-k trick for sampling without replacement, then sort.
    g = jax.random.gumbel(key, (pool_size,))
    _, idx = jax.lax.top_k(g, n)
    return jnp.sort(idx).astype(jnp.int32)


def sample_positions_batch(key: jax.Array, batch: int, n: int, pool_size: int) -> jax.Array:
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: sample_positions(k, n, pool_size))(keys)


def spread_positions(n: int, pool_size: int) -> np.ndarray:
    """Deterministic serving-time initial assignment: spread ids evenly so
    every adjacent pair has a gap ~ pool_size/n for future insertions."""
    return (np.arange(n, dtype=np.int64) * pool_size // max(n, 1)).astype(np.int64)


class PositionAllocator:
    """Host-side position-id allocator for the online editing engine.

    Maintains the sorted list of in-use position ids aligned with the token
    sequence. ``insert_between`` returns a fresh id strictly between
    neighbours, or None if the gap is exhausted (caller must defragment —
    paper: "akin to defragmentation").
    """

    def __init__(self, n: int, pool_size: int):
        self.pool_size = int(pool_size)
        self.positions: list[int] = [int(p) for p in spread_positions(n, pool_size)]
        self.defrag_count = 0

    def __len__(self) -> int:
        return len(self.positions)

    def insert_at(self, i: int) -> int | None:
        """Allocate an id for a token inserted at sequence index i (before the
        current i-th token). Returns the id, or None if no gap remains."""
        lo = self.positions[i - 1] if i > 0 else -1
        hi = self.positions[i] if i < len(self.positions) else self.pool_size
        if hi - lo <= 1:
            return None
        mid = (lo + hi) // 2
        self.positions.insert(i, mid)
        return mid

    def delete_at(self, i: int) -> int:
        return self.positions.pop(i)

    def defragment(self) -> list[int]:
        """Re-spread all ids evenly. Invalidates cached activations (every
        position embedding changes) — the engine counts this as a full pass."""
        self.positions = [int(p) for p in spread_positions(len(self.positions), self.pool_size)]
        self.defrag_count += 1
        return self.positions
