"""Sampled absolute positional embeddings (paper §3.3, App. B).

Training samples a random *ordered* subset of a large positional-embedding
pool per document, so the network learns to use only the relative order of
position ids, not their absolute values. At serving time this lets us assign
*gapped* position ids so that token insertion gets a fresh id between its
neighbours without shifting anyone else — the key to reusing activations
across insert/delete edits.
"""
from __future__ import annotations

import bisect

import jax
import jax.numpy as jnp
import numpy as np


def sample_positions(key: jax.Array, n: int, pool_size: int) -> jax.Array:
    """Sample a sorted n-subset of [0, pool_size) (training mode)."""
    if n > pool_size:
        raise ValueError(f"n={n} > pool_size={pool_size}")
    # Gumbel top-k trick for sampling without replacement, then sort.
    g = jax.random.gumbel(key, (pool_size,))
    _, idx = jax.lax.top_k(g, n)
    return jnp.sort(idx).astype(jnp.int32)


def sample_positions_batch(key: jax.Array, batch: int, n: int, pool_size: int) -> jax.Array:
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: sample_positions(k, n, pool_size))(keys)


def spread_positions(n: int, pool_size: int) -> np.ndarray:
    """Deterministic serving-time initial assignment: spread ids evenly so
    every adjacent pair has a gap ~ pool_size/n for future insertions."""
    return (np.arange(n, dtype=np.int64) * pool_size // max(n, 1)).astype(np.int64)


def spread_positions_gapped(n: int, pool_size: int) -> np.ndarray:
    """Even spread leaving a gap at BOTH boundaries — id_i = (i+1)·pool/(n+1)
    — so inserting before the first or after the last token still finds a
    fresh id. This is the allocator's layout (initial and post-defrag);
    ``spread_positions`` (front-anchored) remains for padded-buffer layouts
    where slot 0 must stay addressable at id 0."""
    if n >= pool_size:
        raise ValueError(f"pool of {pool_size} cannot spread {n} gapped ids")
    return ((np.arange(1, n + 1, dtype=np.int64) * pool_size)
            // (n + 1)).astype(np.int64)


class PositionAllocator:
    """Host-side position-id allocator for the online editing engine.

    Maintains the sorted list of in-use position ids aligned with the token
    sequence. ``insert_between`` returns a fresh id strictly between
    neighbours, or None if the gap is exhausted (caller must defragment —
    paper: "akin to defragmentation").
    """

    def __init__(self, n: int, pool_size: int):
        self.pool_size = int(pool_size)
        self.positions: list[int] = self._spread(n)
        self.defrag_count = 0

    def _spread(self, n: int) -> list[int]:
        """Boundary-gapped spread; dense 0..n-1 when the pool is full."""
        if n < self.pool_size:
            return [int(p) for p in spread_positions_gapped(n, self.pool_size)]
        return [int(p) for p in spread_positions(n, self.pool_size)]

    def __len__(self) -> int:
        return len(self.positions)

    # --------------------------------------------------- snapshot / restore
    # Device-friendly views: the jit serving path keeps position ids resident
    # on-device inside its slot buffers, so the host allocator must be able
    # to export its state as a dense int32 array (to build device inputs and
    # to checkpoint before a speculative bucket take) and re-adopt one (to
    # roll back after a failed dispatch).

    def snapshot(self) -> np.ndarray:
        """The in-use ids, sequence-ordered, as an int32 array."""
        return np.asarray(self.positions, np.int32)

    def restore(self, ids) -> None:
        """Adopt a previously snapshotted id sequence (rollback path)."""
        ids = [int(p) for p in np.asarray(ids).reshape(-1)]
        if any(b <= a for a, b in zip(ids, ids[1:])):
            raise ValueError("position ids must be strictly increasing")
        if ids and not (0 <= ids[0] and ids[-1] < self.pool_size):
            raise ValueError(
                f"ids out of pool range [0, {self.pool_size})")
        self.positions = ids

    # --------------------------------------------------------- gap queries

    def gap_at(self, i: int) -> int:
        """Number of free ids strictly between the would-be neighbours of an
        insertion at sequence index i. 0 means ``insert_at(i)`` would fail —
        gap exhaustion, the caller must defragment (a counted full pass)."""
        lo = self.positions[i - 1] if i > 0 else -1
        hi = self.positions[i] if i < len(self.positions) else self.pool_size
        return max(hi - lo - 1, 0)

    def can_insert_at(self, i: int) -> bool:
        return self.gap_at(i) > 0

    def min_gap(self) -> int:
        """The tightest insertion gap anywhere (including both boundaries).
        0 signals that *some* insertion point is already exhausted."""
        return min(self.gap_at(i) for i in range(len(self.positions) + 1))

    def insert_at(self, i: int) -> int | None:
        """Allocate an id for a token inserted at sequence index i (before the
        current i-th token). Returns the id, or None if no gap remains."""
        lo = self.positions[i - 1] if i > 0 else -1
        hi = self.positions[i] if i < len(self.positions) else self.pool_size
        if hi - lo <= 1:
            return None
        mid = (lo + hi) // 2
        self.positions.insert(i, mid)
        return mid

    def delete_at(self, i: int) -> int:
        return self.positions.pop(i)

    def defragment(self) -> list[int]:
        """Re-spread all ids evenly (gaps at both boundaries). Invalidates
        cached activations (every position embedding changes) — the engine
        counts this as a full pass."""
        self.positions = self._spread(len(self.positions))
        self.defrag_count += 1
        return self.positions
