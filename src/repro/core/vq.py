"""Multi-head vector quantization (paper §3 eq. 1, §4).

The paper appends a VQ layer to the self-attention output: each output vector
is split into ``n_heads`` chunks; each chunk is matched against a per-head
codebook of ``codebook_size`` vectors (64 in the paper), so the effective
codebook size is ``codebook_size ** n_heads``.

Training uses a Gumbel-Softmax straight-through pseudo-gradient (paper §4,
"a variant of the Gumbel-Softmax estimator" of Jang et al. 2017) plus a
commitment term (van den Oord et al. 2017).

Assignment uses the inner-product form of the Euclidean distance (App. A.2):

    argmin_i ||x - c_i||^2 == argmax_i (x^T c_i - ||c_i||^2 / 2)

which turns the distance computation into a single MXU matmul (see
``repro.kernels.vq_assign`` for the Pallas kernel of this exact expression).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import pytree_dataclass, static_field


@pytree_dataclass
class VQParams:
    # [n_heads, codebook_size, d_head]
    codebook: jax.Array


@pytree_dataclass
class VQConfig:
    n_heads: int = static_field(default=2)
    codebook_size: int = static_field(default=64)
    commitment_beta: float = static_field(default=0.25)
    # Gumbel-softmax temperature used during training.
    temperature: float = static_field(default=1.0)


def init(key: jax.Array, d_model: int, cfg: VQConfig, dtype=jnp.float32) -> VQParams:
    if d_model % cfg.n_heads != 0:
        raise ValueError(f"d_model={d_model} not divisible by vq heads={cfg.n_heads}")
    d_head = d_model // cfg.n_heads
    # Match the typical scale of normalized transformer activations.
    codebook = jax.random.normal(key, (cfg.n_heads, cfg.codebook_size, d_head)) * 0.5
    return VQParams(codebook=codebook.astype(dtype))


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    *lead, d = x.shape
    return x.reshape(*lead, n_heads, d // n_heads)


def _merge_heads(x: jax.Array) -> jax.Array:
    *lead, h, dh = x.shape
    return x.reshape(*lead, h * dh)


def scores(params: VQParams, x: jax.Array) -> jax.Array:
    """Negative-distance scores per head: [..., n_heads, codebook_size].

    score[i] = x^T c_i - ||c_i||^2 / 2  (monotone in -||x - c_i||^2).
    """
    h = params.codebook.shape[0]
    xh = _split_heads(x, h)  # [..., h, dh]
    bias = -0.5 * jnp.sum(
        params.codebook.astype(jnp.float32) ** 2, axis=-1
    )  # [h, q]
    dots = jnp.einsum(
        "...hd,hqd->...hq",
        xh.astype(jnp.float32),
        params.codebook.astype(jnp.float32),
    )
    return dots + bias


def assign(params: VQParams, x: jax.Array) -> jax.Array:
    """Nearest-codebook indices per head: int32 [..., n_heads]."""
    return jnp.argmax(scores(params, x), axis=-1).astype(jnp.int32)


def lookup(params: VQParams, idx: jax.Array) -> jax.Array:
    """Gather codebook vectors: idx [..., n_heads] -> [..., d_model]."""
    # codebook: [h, q, dh]; idx: [..., h]
    gathered = jnp.take_along_axis(
        params.codebook[None],  # [1, h, q, dh] broadcast over leading dims
        idx.reshape(-1, idx.shape[-1])[:, :, None, None],
        axis=2,
    )  # [N, h, 1, dh]
    flat = gathered[:, :, 0, :].reshape(-1, params.codebook.shape[0] * params.codebook.shape[2])
    return flat.reshape(*idx.shape[:-1], -1)


# dispatch hard quantization to the Pallas kernel (repro.kernels.vq_assign).
# Default off on CPU (interpret mode); a TPU deployment flips this on.
USE_PALLAS = False


def quantize(params: VQParams, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Hard quantization (inference). Returns (x_q, idx)."""
    if USE_PALLAS:
        from repro.kernels.vq_assign import vq_assign

        idx, x_q = vq_assign(x, params.codebook)
        return x_q.astype(x.dtype), idx
    idx = assign(params, x)
    return lookup(params, idx).astype(x.dtype), idx


def forward_train(
    params: VQParams,
    x: jax.Array,
    cfg: VQConfig,
    rng: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Training-mode VQ with Gumbel-softmax straight-through estimator.

    Returns (x_q_ste, idx, aux_loss). ``x_q_ste`` carries gradients to both
    the input (straight-through) and the codebook (via the soft assignment).
    """
    s = scores(params, x)  # [..., h, q]
    if rng is not None:
        gumbel = jax.random.gumbel(rng, s.shape, dtype=s.dtype)
        logits = (s + gumbel) / cfg.temperature
    else:
        logits = s / cfg.temperature
    soft = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    hard = jax.nn.one_hot(idx, s.shape[-1], dtype=soft.dtype)
    # Straight-through on the assignment weights.
    w = hard + soft - jax.lax.stop_gradient(soft)
    xq_h = jnp.einsum("...hq,hqd->...hd", w, params.codebook.astype(w.dtype))
    x_q = _merge_heads(xq_h).astype(x.dtype)
    # Commitment: pull encoder outputs toward their codes.
    hard_q = jax.lax.stop_gradient(lookup(params, idx).astype(jnp.float32))
    commit = jnp.mean((x.astype(jnp.float32) - hard_q) ** 2)
    # Codebook loss: pull codes toward (stopped) encoder outputs.
    codebook_loss = jnp.mean(
        (_merge_heads(xq_h).astype(jnp.float32) - jax.lax.stop_gradient(x.astype(jnp.float32))) ** 2
    )
    aux = cfg.commitment_beta * commit + codebook_loss
    # Straight-through on values as well (gradient flows to x unchanged).
    x_st = x + jax.lax.stop_gradient(x_q - x)
    return x_st, idx, aux


def combined_code(idx: jax.Array, codebook_size: int) -> jax.Array:
    """Combine per-head indices [..., h] into a single int32 code.

    With h heads of q entries the effective code space is q**h (paper §4).
    Requires q**h < 2**31 (h<=4 with q=64 -> 16.7M, fine).
    """
    h = idx.shape[-1]
    code = idx[..., 0].astype(jnp.int32)
    for i in range(1, h):
        code = code * codebook_size + idx[..., i].astype(jnp.int32)
    return code


def split_code(code: jax.Array, codebook_size: int, n_heads: int) -> jax.Array:
    """Inverse of combined_code: [...,] -> [..., h]."""
    parts = []
    c = code
    for _ in range(n_heads):
        parts.append(c % codebook_size)
        c = c // codebook_size
    return jnp.stack(parts[::-1], axis=-1).astype(jnp.int32)
