from repro.data.synthetic import SyntheticCorpus, lm_batches
from repro.data.edit_stream import EditStream, revision_pairs
