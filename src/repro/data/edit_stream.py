"""Edit-stream generator — the offline stand-in for the paper's scraped
Wikipedia revision histories (§4).

Produces (base document, revision) pairs with a controlled edit fraction and
bursty (clustered) edit locations, plus atomic-edit streams for the online
experiment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.core.edits import Edit, edit_script, random_atomic_edit, random_revision
from repro.data.synthetic import SyntheticCorpus


@dataclass
class EditStream:
    corpus: SyntheticCorpus
    doc_len: int = 512
    seed: int = 0

    def base_document(self, i: int) -> np.ndarray:
        return self.corpus.document(self.doc_len, 50_000 + i)

    def atomic_edits(self, doc_id: int, n_edits: int) -> Iterator[Edit]:
        """A stream of single-token edits to one document (online case)."""
        rng = np.random.default_rng((self.seed, doc_id))
        tokens = list(self.base_document(doc_id))
        for _ in range(n_edits):
            e = random_atomic_edit(rng, tokens, self.corpus.vocab)
            yield e
            from repro.core.edits import apply_edit

            tokens = apply_edit(tokens, e)

    def revision(self, doc_id: int, edit_fraction: float) -> tuple[np.ndarray, np.ndarray]:
        """(old, new) revision pair with ~edit_fraction of tokens modified."""
        rng = np.random.default_rng((self.seed, 1, doc_id))
        old = self.base_document(doc_id)
        new = np.asarray(random_revision(rng, old, self.corpus.vocab, edit_fraction))
        return old, new


@dataclass
class TrafficGenerator:
    """Seeded serving traffic for the load benchmarks (async_load, fleet_load).

    Models what a fleet of editor sessions actually does to a serving tier:

    * **zipf document popularity** — a few hot documents absorb most
      sessions (that is what makes a hot tier and sticky routing matter);
    * **Poisson-ish session arrival/departure** — sessions open a document,
      edit in bursts, and close with probability ``p_close``, so the open
      document set churns over the run;
    * **typing bursts vs revise bursts** — a typing burst is a run of
      inserts at an advancing cursor (the append-heavy best case); a revise
      burst is replaces/deletes clustered around a point (the bursty
      Wikipedia-style worst case, cf. ``random_revision``).

    Everything is derived from ``seed`` so concurrent drivers and their
    sequential oracles replay identical streams. Ops are emitted against an
    evolving per-document reference, so each (kind, pos, tok) is valid at
    its application time.
    """

    vocab: int
    n_docs: int = 8
    doc_len: int = 32
    seed: int = 0
    zipf_a: float = 1.3
    p_typing: float = 0.6

    def __post_init__(self):
        ranks = np.arange(1, self.n_docs + 1, dtype=np.float64)
        w = ranks ** -self.zipf_a
        self.popularity = w / w.sum()

    def base_document(self, doc_idx: int) -> list[int]:
        rng = np.random.default_rng((self.seed, 11, doc_idx))
        return [int(t) for t in rng.integers(0, self.vocab, self.doc_len)]

    def burst_ops(self, rng: np.random.Generator, ref: list,
                  n_edits: int) -> list[tuple]:
        """One burst of exactly ``n_edits`` ops against (and mutating)
        ``ref``; each op is ``(kind, pos, tok)``."""
        ops: list[tuple] = []
        if rng.random() < self.p_typing:  # typing: inserts at a cursor
            cur = int(rng.integers(len(ref) + 1))
            for _ in range(n_edits):
                tok = int(rng.integers(self.vocab))
                ref.insert(cur, tok)
                ops.append(("insert", cur, tok))
                cur += 1
            return ops
        center = int(rng.integers(len(ref)))  # revise: clustered churn
        for _ in range(n_edits):
            kind = str(rng.choice(["replace", "delete", "insert"],
                                  p=[0.6, 0.2, 0.2]))
            if kind == "delete" and len(ref) <= 6:
                kind = "replace"
            pos = min(max(center + int(rng.integers(-3, 4)), 0),
                      len(ref) - (0 if kind == "insert" else 1))
            tok = int(rng.integers(self.vocab))
            if kind == "insert":
                ref.insert(pos, tok)
            elif kind == "delete":
                del ref[pos]
            else:
                ref[pos] = tok
            ops.append((kind, pos, tok))
            center = min(pos, max(len(ref) - 1, 0))
        return ops

    def session_ops(self, doc_idx: int, n_edits: int,
                    ref: list) -> list[tuple]:
        """A single session's seeded op stream for one document: exactly
        ``n_edits`` ops in alternating typing/revise bursts, mutating
        ``ref`` as they go (the async_load per-client stream)."""
        rng = np.random.default_rng((self.seed, 23, doc_idx))
        ops: list[tuple] = []
        while len(ops) < n_edits:
            burst = 1 + int(rng.poisson(2.0))
            ops.extend(self.burst_ops(rng, ref,
                                      min(burst, n_edits - len(ops))))
        return ops

    def fleet_events(self, n_sessions: int, mean_burst: float = 3.0,
                     bursts_per_session: int = 2, n_new: int = 4,
                     p_close: float = 0.35) -> tuple[list[tuple], dict]:
        """An interleaved fleet-wide event schedule.

        Returns ``(events, final_refs)``: events are, in order,
        ``("open", doc, tokens)`` / ``("edit", doc, (kind, pos, tok))`` /
        ``("suggest", doc, n_new)`` / ``("close", doc)``; ``final_refs``
        maps every document ever touched to its token list after the last
        event (documents closed by a departure re-open with their retained
        tokens on the next session, like a real editor reconnecting).
        """
        rng = np.random.default_rng((self.seed, 37))
        events: list[tuple] = []
        refs: dict[str, list] = {}
        is_open: dict[str, bool] = {}
        for _ in range(n_sessions):
            idx = int(rng.choice(self.n_docs, p=self.popularity))
            doc = f"doc{idx}"
            if doc not in refs:
                refs[doc] = self.base_document(idx)
            if not is_open.get(doc, False):
                events.append(("open", doc, list(refs[doc])))
                is_open[doc] = True
            for _ in range(bursts_per_session):
                n = 1 + int(rng.poisson(max(mean_burst - 1.0, 0.0)))
                for op in self.burst_ops(rng, refs[doc], n):
                    events.append(("edit", doc, op))
                events.append(("suggest", doc, n_new))
            if rng.random() < p_close:  # Poisson-ish departure
                events.append(("close", doc))
                is_open[doc] = False
        return events, refs


def revision_pairs(
    stream: EditStream, n_pairs: int, fractions=(0.002, 0.005, 0.01, 0.02, 0.05, 0.1)
) -> Iterator[tuple[np.ndarray, np.ndarray, list[Edit], float]]:
    """Yields (old, new, edit_script, fraction) like the paper's scraped
    Wikipedia pairs — fraction is drawn log-uniformly from ``fractions``."""
    rng = np.random.default_rng(stream.seed + 99)
    for i in range(n_pairs):
        frac = float(fractions[rng.integers(len(fractions))])
        old, new = stream.revision(i, frac)
        yield old, new, edit_script(old, new), frac
