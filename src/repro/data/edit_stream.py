"""Edit-stream generator — the offline stand-in for the paper's scraped
Wikipedia revision histories (§4).

Produces (base document, revision) pairs with a controlled edit fraction and
bursty (clustered) edit locations, plus atomic-edit streams for the online
experiment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.core.edits import Edit, edit_script, random_atomic_edit, random_revision
from repro.data.synthetic import SyntheticCorpus


@dataclass
class EditStream:
    corpus: SyntheticCorpus
    doc_len: int = 512
    seed: int = 0

    def base_document(self, i: int) -> np.ndarray:
        return self.corpus.document(self.doc_len, 50_000 + i)

    def atomic_edits(self, doc_id: int, n_edits: int) -> Iterator[Edit]:
        """A stream of single-token edits to one document (online case)."""
        rng = np.random.default_rng((self.seed, doc_id))
        tokens = list(self.base_document(doc_id))
        for _ in range(n_edits):
            e = random_atomic_edit(rng, tokens, self.corpus.vocab)
            yield e
            from repro.core.edits import apply_edit

            tokens = apply_edit(tokens, e)

    def revision(self, doc_id: int, edit_fraction: float) -> tuple[np.ndarray, np.ndarray]:
        """(old, new) revision pair with ~edit_fraction of tokens modified."""
        rng = np.random.default_rng((self.seed, 1, doc_id))
        old = self.base_document(doc_id)
        new = np.asarray(random_revision(rng, old, self.corpus.vocab, edit_fraction))
        return old, new


def revision_pairs(
    stream: EditStream, n_pairs: int, fractions=(0.002, 0.005, 0.01, 0.02, 0.05, 0.1)
) -> Iterator[tuple[np.ndarray, np.ndarray, list[Edit], float]]:
    """Yields (old, new, edit_script, fraction) like the paper's scraped
    Wikipedia pairs — fraction is drawn log-uniformly from ``fractions``."""
    rng = np.random.default_rng(stream.seed + 99)
    for i in range(n_pairs):
        frac = float(fractions[rng.integers(len(fractions))])
        old, new = stream.revision(i, frac)
        yield old, new, edit_script(old, new), frac
