"""Synthetic language-model corpus (offline stand-in for the Pile, paper §4).

A second-order Markov chain over a Zipfian vocabulary with topic blocks:
documents carry enough local structure (bigram dependencies, repeated topical
words) that a small transformer measurably learns it, while generation stays
fast and deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class SyntheticCorpus:
    vocab: int
    seed: int = 0
    n_topics: int = 16
    topic_words: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Zipfian unigram distribution
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # per-topic boosted word sets
        self.topics = rng.integers(0, self.vocab, size=(self.n_topics, self.topic_words))
        # bigram successor table: each token has a handful of likely successors
        self.succ = rng.integers(0, self.vocab, size=(self.vocab, 4))

    def document(self, length: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, seed))
        topic = rng.integers(self.n_topics)
        words = self.topics[topic]
        out = np.empty(length, np.int64)
        prev = int(rng.choice(words))
        for i in range(length):
            r = rng.random()
            if r < 0.45:  # follow bigram structure
                prev = int(self.succ[prev, rng.integers(4)])
            elif r < 0.8:  # topical word
                prev = int(words[rng.integers(self.topic_words)])
            else:  # Zipf background
                prev = int(rng.choice(self.vocab, p=self.unigram))
            out[i] = prev
        return out

    def classification_doc(self, length: int, seed: int) -> tuple[np.ndarray, int]:
        """Binary 'sentiment' task: label = which of two topic groups dominates
        (IMDB stand-in for the accuracy-parity experiment)."""
        rng = np.random.default_rng((self.seed, 7, seed))
        label = int(rng.integers(2))
        # two disjoint "sentiment lexicons" in the token space
        group = np.arange(64) + (100 if label == 0 else 200)
        doc = self.document(length, seed + 10_000)
        # plant label-revealing words densely enough for few-step fine-tunes
        n_plant = max(4, length // 6)
        idx = rng.choice(length, n_plant, replace=False)
        doc[idx] = group[rng.integers(0, len(group), n_plant)]
        return doc, label


def lm_batches(
    corpus: SyntheticCorpus,
    *,
    batch: int,
    seq_len: int,
    steps: int,
    seed: int = 0,
    pos_pool: Optional[int] = None,
) -> Iterator[dict]:
    """Yields {tokens [b, n], positions? [b, n]} batches. When ``pos_pool`` is
    set, positions are sampled ordered subsets (paper §3.3 training scheme)."""
    rng = np.random.default_rng(seed)
    for s in range(steps):
        toks = np.stack(
            [corpus.document(seq_len, seed * 100_000 + s * batch + i) for i in range(batch)]
        )
        out = {"tokens": toks}
        if pos_pool:
            pos = np.sort(
                np.stack(
                    [rng.choice(pos_pool, seq_len, replace=False) for _ in range(batch)]
                ),
                axis=-1,
            ).astype(np.int32)
            out["positions"] = pos
        yield out
