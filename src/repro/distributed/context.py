"""Ambient sharding context.

Model code calls ``constrain(x, "batch", None, "model")`` with *logical* axis
names; when a ShardingCtx is active these become with_sharding_constraint
calls on the production mesh, and when no context is set (unit tests, eager
CPU runs) they are no-ops. This keeps the model definitions mesh-agnostic.

Logical axes:
  batch  -> all data-parallel mesh axes ("pod", "data") when present
  seq    -> "data" (context/sequence parallelism, used for long-context decode)
  model  -> "model" (tensor parallelism: heads, ffn hidden, vocab, experts)
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclass
class ShardingCtx:
    mesh: Mesh

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        names = self.mesh.axis_names
        if logical == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
            return axes if axes else None
        if logical == "seq":
            return "data" if "data" in names else None
        if logical == "model":
            return "model" if "model" in names else None
        if logical == "seq_model":
            # context parallelism ON the tensor axis: used when head counts
            # don't divide the model axis (hymba 25H, phi4 24H, internvl 14H)
            # so head sharding would silently replicate (§Perf iteration 3)
            return "model" if "model" in names else None
        raise ValueError(f"unknown logical axis {logical}")

    def spec(self, *logical_axes, dims: Optional[tuple] = None) -> P:
        """Resolve logical axes with two safeguards: a mesh axis may appear
        only once per spec (first use wins — batch=1 decode wants both
        "batch" and "seq" on "data"); and when ``dims`` is given, axes whose
        dimension does not divide the mesh-axis size resolve to None (so a
        batch-1 tensor never claims the data axis and the seq axis can)."""
        used: set = set()
        out = []
        for i, a in enumerate(logical_axes):
            r = self.resolve(a)
            flat = r if isinstance(r, tuple) else (r,)
            if r is not None and dims is not None:
                size = 1
                for f in flat:
                    size *= self.mesh.shape[f]
                if dims[i] % size != 0:
                    r = None
            if r is None or any(f in used for f in flat):
                out.append(None)
            else:
                used.update(flat)
                out.append(r)
        return P(*out)


def set_ctx(ctx: Optional[ShardingCtx]) -> None:
    _state.ctx = ctx


def get_ctx() -> Optional[ShardingCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = get_ctx()
    set_ctx(ShardingCtx(mesh))
    try:
        yield get_ctx()
    finally:
        set_ctx(prev)


def shard_map_compat(f, *, mesh: Mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: newer releases expose it as
    ``jax.shard_map`` (with ``check_vma``), older ones as
    ``jax.experimental.shard_map.shard_map`` (with ``check_rep``). Both
    checks are disabled — serving and MoE shards close over replicated
    weight stacks, which the replication checker cannot always prove."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """Apply a sharding constraint using logical axis names (no-op w/o ctx)."""
    ctx = get_ctx()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"constrain: {len(logical_axes)} axes for rank-{x.ndim}")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, ctx.spec(*logical_axes, dims=x.shape))
    )
