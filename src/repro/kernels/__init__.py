# TPU Pallas kernels for the paper's compute hot-spots:
#   vq_assign        — multi-head nearest-codebook assignment (App. A.2 trick:
#                      one MXU matmul + row argmax + one-hot gather-matmul)
#   gated_attention  — streaming σ(QK^T)V (paper eq. 1). σ is element-wise, so
#                      KV tiles accumulate independently: no online-softmax
#                      running max / rescale pass — cheaper than flash-softmax
#                      on TPU (DESIGN.md §3).
# Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper), ref.py (pure-jnp oracle used by the test sweeps).
