from repro.kernels.fused_step.ops import (
    delta_gate, fused_patch_assign, fused_patch_assign_batched,
)
from repro.kernels.fused_step.ref import delta_gate_ref, fused_patch_assign_ref
