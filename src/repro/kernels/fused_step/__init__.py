from repro.kernels.fused_step.ops import (
    fused_patch_assign, fused_patch_assign_batched,
)
from repro.kernels.fused_step.ref import fused_patch_assign_ref
