"""Pallas TPU kernel: the fused incremental edit step (DESIGN.md §9).

One launch per layer replaces the old per-op chain (column-patch kernel →
host-side T accumulate → requantize einsums → argmax): per (row-block,
vq-head) grid cell the kernel

  1. applies the old-minus/new-plus attention column patch for the
     ``heads_per_vq`` attention heads feeding this vq head:

         ΔT[i, h, :] = Σ_c gelu(q[i,h]·k_new[c,h]·scale) vc_new[c,h,:]
                     − Σ_c gelu(q[i,h]·k_old[c,h]·scale) vc_old[c,h,:]

     (two MXU matmuls per head, exactly the ``incr_patch`` body);
  2. accumulates ``T = T_base + ΔT`` per head and writes it back;
  3. re-quantizes in score space: ``s = Σ_heads T / counts + vq_bias``,
     ``codes = argmax_Q s`` (VPU reduce) — the ``vq_assign`` trick without
     a second launch or an HBM round-trip of T.

Changed-column gating, causal structure, row validity and dirty-row
exclusion are all folded into one [rows, C] mask on the host side of the
same jit — the kernel body only ever multiplies by it, so its compiled
shape is blind to WHICH rows/columns are live.

Raggedness: the grid iterates over PADDED row blocks of a capacity-class
buffer; rows whose ``valid`` bit is off (free slots, the padding beyond a
document's logical capacity) have an all-zero mask row and ``counts``
clamped to 1, so one compiled step serves every logical ``n_cap`` inside
the class (``repro.common.bucketing.capacity_class``).

Head-group blocking: stacked weights order attention heads as
``h = hh * heads_per_vq + j`` (see ``_weights_from_params``'s
``cb_per_head`` reshape), so blocking the head axis by ``heads_per_vq`` at
block index ``hh`` hands each grid cell exactly the heads its vq head
sums over.

``delta_gate_kernel`` is the sigma-delta companion launch (DESIGN.md §10):
a per-row L∞ reduce + threshold compare deciding which freshly recomputed
rows propagate downstream. The resulting keep bits flow back into the
NEXT layer's engine-built mask, so the thresholded gating mode costs one
tiny extra launch per layer and zero changes to the fused patch body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, kn_ref, ko_ref, vcn_ref, vco_ref, mask_ref, tb_ref,
            counts_ref, bias_ref, t_ref, codes_ref, *, scale: float, g: int):
    # q_ref: [BR, g, dh]; kn/ko: [g, C, dh]; vcn/vco: [g, C, Q];
    # mask: [BR, C]; tb: [BR, g, Q]; counts: [BR, 1]; bias: [1, Q];
    # t: [BR, g, Q]; codes: [BR, 1]
    mask = mask_ref[...].astype(jnp.float32)  # [BR, C]
    acc = None
    for j in range(g):
        q = q_ref[:, j, :]  # [BR, dh]

        def contrib(k_ref, vc_ref, sign):
            s = jax.lax.dot_general(
                q, k_ref[j], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale  # [BR, C]
            w = jax.nn.gelu(s, approximate=True) * mask
            return sign * jax.lax.dot_general(
                w, vc_ref[j].astype(jnp.float32), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # [BR, Q]

        Tj = (tb_ref[:, j, :].astype(jnp.float32)
              + contrib(kn_ref, vcn_ref, 1.0) + contrib(ko_ref, vco_ref, -1.0))
        t_ref[:, j, :] = Tj.astype(t_ref.dtype)
        acc = Tj if acc is None else acc + Tj
    scores = acc / counts_ref[...] + bias_ref[0][None, :]  # [BR, Q]
    codes_ref[:, 0] = jnp.argmax(scores, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("heads_per_vq", "block_r", "interpret"))
def fused_step_kernel(
    q: jax.Array,  # [n, H, dh] every row's cached queries
    k_new: jax.Array,  # [H, C, dh] dirty-slot key buffer (new values)
    k_old: jax.Array,  # [H, C, dh] old values
    vc_new: jax.Array,  # [H, C, Q] value·codebook products (new)
    vc_old: jax.Array,  # [H, C, Q]
    mask: jax.Array,  # [n, C] {0,1}: col gating & causal & row_valid & ~dirty
    T_base: jax.Array,  # [n, H, Q] scores with dirty rows pre-recomputed
    counts: jax.Array,  # [n] f32 attended-column counts (clamped >= 1)
    vq_bias: jax.Array,  # [hq, Q]
    *,
    heads_per_vq: int,
    block_r: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (T_all [n, H, Q] f32, codes [n, hq] int32)."""
    n, H, dh = q.shape
    C = k_new.shape[1]
    Q = vc_new.shape[-1]
    g = heads_per_vq
    hq = H // g
    scale = dh ** -0.5
    counts = counts.astype(jnp.float32).reshape(n, 1)
    pad = (-n) % block_r
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
        T_base = jnp.pad(T_base, ((0, pad), (0, 0), (0, 0)))
        # pad counts with 1 so the padded rows' score divide stays finite
        counts = jnp.pad(counts, ((0, pad), (0, 0)), constant_values=1.0)
    Np = n + pad
    grid = (Np // block_r, hq)
    T_all, codes = pl.pallas_call(
        functools.partial(_kernel, scale=scale, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, g, dh), lambda i, h: (i, h, 0)),
            pl.BlockSpec((g, C, dh), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((g, C, dh), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((g, C, Q), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((g, C, Q), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((block_r, C), lambda i, h: (i, 0)),
            pl.BlockSpec((block_r, g, Q), lambda i, h: (i, h, 0)),
            pl.BlockSpec((block_r, 1), lambda i, h: (i, 0)),
            pl.BlockSpec((1, Q), lambda i, h: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, g, Q), lambda i, h: (i, h, 0)),
            pl.BlockSpec((block_r, 1), lambda i, h: (i, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, H, Q), jnp.float32),
            jax.ShapeDtypeStruct((Np, hq), jnp.int32),
        ],
        interpret=interpret,
    )(q, k_new, k_old, vc_new, vc_old, mask, T_base, counts, vq_bias)
    return T_all[:n], codes[:n]


@functools.partial(jax.jit,
                   static_argnames=("heads_per_vq", "block_r", "interpret"))
def fused_step_kernel_batched(
    q: jax.Array,  # [B, n, H, dh]
    k_new: jax.Array,  # [B, H, C, dh]
    k_old: jax.Array,  # [B, H, C, dh]
    vc_new: jax.Array,  # [B, H, C, Q]
    vc_old: jax.Array,  # [B, H, C, Q]
    mask: jax.Array,  # [B, n, C]
    T_base: jax.Array,  # [B, n, H, Q]
    counts: jax.Array,  # [B, n]
    vq_bias: jax.Array,  # [hq, Q] (shared across the batch)
    *,
    heads_per_vq: int,
    block_r: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched-serving variant: same fused body over a grid with a leading
    *batch* dimension — one (document, row-block, vq-head) cell per grid
    point, so B documents' whole edit steps run as one ``pallas_call`` per
    layer. The vq_bias block is batch-invariant and stays resident.
    Returns (T_all [B, n, H, Q] f32, codes [B, n, hq] int32)."""
    B, n, H, dh = q.shape
    C = k_new.shape[2]
    Q = vc_new.shape[-1]
    g = heads_per_vq
    hq = H // g
    scale = dh ** -0.5
    counts = counts.astype(jnp.float32).reshape(B, n, 1)
    pad = (-n) % block_r
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad), (0, 0)))
        T_base = jnp.pad(T_base, ((0, 0), (0, pad), (0, 0), (0, 0)))
        counts = jnp.pad(counts, ((0, 0), (0, pad), (0, 0)),
                         constant_values=1.0)
    Np = n + pad
    grid = (B, Np // block_r, hq)
    T_all, codes = pl.pallas_call(
        functools.partial(_kernel, scale=scale, g=g),
        grid=grid,
        in_specs=[
            # None squeezes the batch dim so the unbatched kernel body is
            # reused verbatim — the batch lives purely in the grid.
            pl.BlockSpec((None, block_r, g, dh), lambda b, i, h: (b, i, h, 0)),
            pl.BlockSpec((None, g, C, dh), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, g, C, dh), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, g, C, Q), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, g, C, Q), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, block_r, C), lambda b, i, h: (b, i, 0)),
            pl.BlockSpec((None, block_r, g, Q), lambda b, i, h: (b, i, h, 0)),
            pl.BlockSpec((None, block_r, 1), lambda b, i, h: (b, i, 0)),
            pl.BlockSpec((1, Q), lambda b, i, h: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_r, g, Q), lambda b, i, h: (b, i, h, 0)),
            pl.BlockSpec((None, block_r, 1), lambda b, i, h: (b, i, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Np, H, Q), jnp.float32),
            jax.ShapeDtypeStruct((B, Np, hq), jnp.int32),
        ],
        interpret=interpret,
    )(q, k_new, k_old, vc_new, vc_old, mask, T_base, counts, vq_bias)
    return T_all[:, :n], codes[:, :n]


def _gate_kernel(xn_ref, xo_ref, keep_ref, *, threshold: float):
    # xn/xo: [BR, d]; keep: [BR, 1] int32 {0, 1}
    diff = jnp.max(jnp.abs(xn_ref[...].astype(jnp.float32)
                           - xo_ref[...].astype(jnp.float32)),
                   axis=-1, keepdims=True)  # [BR, 1]
    keep_ref[...] = (diff > threshold).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("threshold", "block_r", "interpret"))
def delta_gate_kernel(
    x_new: jax.Array,  # [r, d] freshly recomputed next-layer rows
    x_old: jax.Array,  # [r, d] the rows' last-TRANSMITTED values
    *,
    threshold: float,
    block_r: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Per-row sigma-delta gate: ``keep[i] = max_d |x_new[i] − x_old[i]| >
    threshold`` (DESIGN.md §10). Returns ``keep [r] bool``.

    ``threshold`` is a compile-time constant — engines carry one Python
    float per instance, so the jit key matches the engine's identity key.
    L∞ and the strict compare are order-insensitive (max is associative and
    exact), so this kernel, its interpret-mode run and the inline jnp
    expression all produce bitwise-identical keep bits."""
    r, d = x_new.shape
    pad = (-r) % block_r
    if pad:
        # padded rows diff zero-against-zero: 0 > threshold is False, and
        # the slice below drops them anyway
        x_new = jnp.pad(x_new, ((0, pad), (0, 0)))
        x_old = jnp.pad(x_old, ((0, pad), (0, 0)))
    Rp = r + pad
    keep = pl.pallas_call(
        functools.partial(_gate_kernel, threshold=float(threshold)),
        grid=(Rp // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        interpret=interpret,
    )(x_new, x_old)
    return keep[:r, 0].astype(bool)
