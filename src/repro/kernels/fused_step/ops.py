"""Jit'd public wrapper for the fused incremental edit step.

The engine hands this the fully-folded per-(row, column) mask (changed
columns & causal order & row validity & dirty-row exclusion), the score
buffer with the dirty rows' full recompute already scattered in, and the
per-row attended-column counts; the kernel does the rest in one launch per
layer. Falls back to interpret mode off-TPU (bit-identical math, Python
execution of the kernel body) so the whole stack runs on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_step.fused_step import fused_step_kernel


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def fused_patch_assign(q, k_new, k_old, vc_new, vc_old, mask, T_base, counts,
                       vq_bias, *, heads_per_vq: int, block_r: int = 128):
    """q: [n, H, dh]; k_*: [H, C, dh]; vc_*: [H, C, Q]; mask: [n, C];
    T_base: [n, H, Q]; counts: [n]; vq_bias: [hq, Q].
    Returns (T_all [n, H, Q] f32, codes [n, hq] int32) where
    ``T_all = T_base + ΔT`` (masked old-minus/new-plus column patch) and
    ``codes`` re-quantizes T_all in score space — one kernel launch.

    The mask must already fold EVERY gate: live-column occupancy, causal
    position order, row validity, and a zero row for every dirty row whose
    ``T_base`` entry holds a fresh full recompute (the patch must not touch
    those)."""
    return fused_step_kernel(
        q, k_new, k_old, vc_new, vc_old, mask.astype(jnp.float32), T_base,
        counts, vq_bias, heads_per_vq=heads_per_vq, block_r=block_r,
        interpret=not _on_tpu(),
    )


def fused_patch_assign_batched(q, k_new, k_old, vc_new, vc_old, mask, T_base,
                               counts, vq_bias, *, heads_per_vq: int,
                               block_r: int = 128):
    """Batched serving: every per-document argument gains a leading [B]
    axis (vq_bias stays shared) and the grid gains a batch dimension.
    Returns (T_all [B, n, H, Q] f32, codes [B, n, hq] int32).

    Direct entry point for callers holding stacked buffers; the vmapped
    engine route (``BatchedJitEngine`` with ``use_fused_kernel=True``)
    reaches the same batched grid through the pallas batching rule applied
    to the unbatched ``fused_patch_assign``."""
    from repro.kernels.fused_step.fused_step import fused_step_kernel_batched

    return fused_step_kernel_batched(
        q, k_new, k_old, vc_new, vc_old, mask.astype(jnp.float32), T_base,
        counts, vq_bias, heads_per_vq=heads_per_vq, block_r=block_r,
        interpret=not _on_tpu(),
    )


def delta_gate(x_new, x_old, threshold: float, *, block_r: int = 128):
    """Sigma-delta propagation gate (DESIGN.md §10): per-row L∞ change
    ``max_d |x_new − x_old|`` compared strictly against ``threshold``.
    x_new/x_old: [r, d]; returns keep [r] bool — True means the row drifted
    past the threshold from its last-transmitted value and must propagate.

    The engine feeds the keep bits into the NEXT layer's folded mask (the
    thresholded-gating mode of the fused step), so suppression costs one
    small extra launch per layer and the fused patch body is unchanged.
    Bitwise-equal to ``delta_gate_ref`` on every shape: max and > are
    order-insensitive, unlike the ΔT accumulation."""
    from repro.kernels.fused_step.fused_step import delta_gate_kernel

    return delta_gate_kernel(x_new, x_old, threshold=float(threshold),
                             block_r=block_r, interpret=not _on_tpu())
