"""Pure-jnp oracle for the fused edit-step kernel: the unfused
``incr_patch_ref``-style column patch chained with the inline requantize
the jit engine used before fusion. Parity: T bit-close (the kernel
accumulates per-head partial sums in a different order), codes exact on
non-degenerate inputs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.incr_patch.ref import incr_patch_ref


def fused_patch_assign_ref(q, k_new, k_old, vc_new, vc_old, mask, T_base,
                           counts, vq_bias) -> tuple[jax.Array, jax.Array]:
    """Same signature as ``fused_patch_assign`` minus the static config
    (``heads_per_vq`` is inferred from ``vq_bias``).
    Returns (T_all [n, H, Q] f32, codes [n, hq] int32)."""
    # incr_patch_ref takes k_*/vc_* in [H, C, *] layout, like the kernel
    dT = incr_patch_ref(q, k_new, k_old, vc_new, vc_old,
                        mask.astype(jnp.float32))
    T_all = T_base.astype(jnp.float32) + dT
    n, H, Q = T_all.shape
    hq = vq_bias.shape[0]
    g = H // hq
    s = T_all.reshape(n, hq, g, Q).sum(2)
    s = s / counts.astype(jnp.float32)[:, None, None] + vq_bias[None]
    codes = jnp.argmax(s, axis=-1).astype(jnp.int32)
    return T_all, codes


def delta_gate_ref(x_new, x_old, threshold: float) -> jax.Array:
    """NumPy/jnp oracle for ``delta_gate`` (DESIGN.md §10): keep a row iff
    its L∞ change STRICTLY exceeds the threshold. Parity with the kernel is
    bitwise — max/abs/> are order-insensitive — so the inline engine path
    and the fused path share exact gating semantics."""
    x_new = jnp.asarray(x_new, jnp.float32)
    x_old = jnp.asarray(x_old, jnp.float32)
    return jnp.max(jnp.abs(x_new - x_old), axis=-1) > threshold
