from repro.kernels.gated_attention.ops import gated_attention
from repro.kernels.gated_attention.ref import gated_attention_ref
