"""Pallas TPU kernel: streaming σ(QKᵀ)V attention (paper eq. 1).

The flash-attention structure without its hardest part: because the paper
replaces softmax with an element-wise GELU, each KV tile's contribution

    O_blk = gelu(Q_blk K_tileᵀ · scale) V_tile

is an *independent partial sum*. The kernel therefore:

  * needs NO running row-max and NO accumulator rescale (one VPU pass and
    one multiply per tile cheaper than flash-softmax);
  * keeps a single f32 accumulator tile in VMEM and normalizes once at the
    end by the attended count (q_idx+1, closed form for causal masks).

Grid: (batch*heads, q_blocks, kv_blocks) — TPU iterates the last axis
sequentially, so the accumulation into ``o_ref`` across kv blocks is the
standard Pallas reduction idiom (init at kv==0, finalize at the last block).
Causal skipping: kv blocks strictly above the diagonal write nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # causal: this kv block participates iff its first row <= q block's last row
    @pl.when(ki * bk <= qi * bq + bq - 1)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)  # [bq, dh]
        k = k_ref[0].astype(jnp.float32)  # [bk, dh]
        v = v_ref[0].astype(jnp.float32)  # [bk, dv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (k_idx <= q_idx) & (k_idx < nk)
        w = jax.nn.gelu(s, approximate=True) * mask.astype(jnp.float32)
        o_ref[0] += jax.lax.dot_general(
            w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)

    # final kv block: normalize by the attended count (causal: q_idx + 1)
    @pl.when(ki == nkb - 1)
    def _finalize():
        q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        cnt = jnp.minimum(q_idx + 1, nk).astype(jnp.float32)
        o_ref[0] = (o_ref[0] / cnt).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def gated_attention_kernel(
    q: jax.Array,  # [BH, nq, dh]
    k: jax.Array,  # [BH, nk, dh]
    v: jax.Array,  # [BH, nk, dv]
    *,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Causal σ-attention. Returns [BH, nq, dv] in f32."""
    BH, nq, dh = q.shape
    nk = k.shape[1]
    dv = v.shape[-1]
    scale = dh ** -0.5
    block_q = min(block_q, nq)
    block_k = min(block_k, nk)
    pad_q = (-nq) % block_q
    pad_k = (-nk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    grid = (BH, (nq + pad_q) // block_q, (nk + pad_k) // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=block_q, bk=block_k, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq + pad_q, dv), jnp.float32),
        interpret=interpret,
    )(q, k, v)
    return out[:, :nq]
