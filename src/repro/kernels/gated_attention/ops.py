"""Jit'd public wrapper for the gated σ-attention kernel.

Accepts the model-layout tensors [b, n, H, dh] (GQA repeat applied here) and
returns [b, n, H*dh], matching ``repro.models.attention.full_attention`` with
``softmax=False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gated_attention.gated_attention import gated_attention_kernel


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def gated_attention(
    q: jax.Array,  # [b, nq, H, dh]
    k: jax.Array,  # [b, nk, Hkv, dh]
    v: jax.Array,  # [b, nk, Hkv, dh]
    *,
    block_q: int = 256,
    block_k: int = 256,
) -> jax.Array:
    b, nq, H, dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    fold = lambda a: jnp.moveaxis(a, 2, 1).reshape(b * H, a.shape[1], a.shape[-1])
    out = gated_attention_kernel(
        fold(q), fold(k), fold(v),
        block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
    )  # [b*H, nq, dh]
    out = out.reshape(b, H, nq, dh)
    return jnp.moveaxis(out, 1, 2).reshape(b, nq, H * dh)
