"""Pure-jnp oracle for the gated (σ) attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal σ-attention, count-normalized. q/k: [BH, n, dh]; v: [BH, n, dv].
    Returns [BH, nq, dv] f32."""
    BH, nq, dh = q.shape
    nk = k.shape[1]
    scale = dh ** -0.5
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(nk)[None, :] <= jnp.arange(nq)[:, None]
    w = jax.nn.gelu(s, approximate=True) * mask[None].astype(jnp.float32)
    cnt = jnp.minimum(jnp.arange(nq) + 1, nk).astype(jnp.float32)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)) / cnt[None, :, None]
