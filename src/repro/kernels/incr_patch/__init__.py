from repro.kernels.incr_patch.ops import incr_patch
from repro.kernels.incr_patch.ref import incr_patch_ref
