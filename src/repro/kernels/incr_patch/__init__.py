from repro.kernels.incr_patch.ops import incr_patch, incr_patch_batched
from repro.kernels.incr_patch.ref import incr_patch_ref
