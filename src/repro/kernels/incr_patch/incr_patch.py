"""Pallas TPU kernel: the incremental attention *column patch* (App. A.1).

When C columns (edited keys/values) change under σ-attention, every later row
i receives

    ΔT[i, h, :] = Σ_c gelu(q[i,h]·k_new[c,h]·scale) vc_new[c,h,:]
                − Σ_c gelu(q[i,h]·k_old[c,h]·scale) vc_old[c,h,:]

The TPU adaptation (DESIGN.md §3): edits are bucketed into fixed-capacity
*dirty-slot* buffers (C = power of two), rows are gathered into dense blocks,
and the patch is two MXU matmuls per (row-block, head) grid cell:

    s  = q_blk @ k_colsᵀ          [BR, C]   (MXU)
    w  = gelu(s·scale) ⊙ mask     [BR, C]   (VPU)
    ΔT = w @ vc_cols              [BR, Q]   (MXU)

computed for (k_new, vc_new) minus (k_old, vc_old) in one pass. Host code
gathers the dirty rows/columns and scatters ΔT back — both are static-shape
ops on TPU thanks to the capacity bucketing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, kn_ref, ko_ref, vcn_ref, vco_ref, mask_ref, out_ref, *,
            scale: float):
    # q_ref: [BR, 1, dh]; kn/ko: [1, C, dh]; vcn/vco: [1, C, Q];
    # mask: [BR, C]; out: [BR, 1, Q]
    q = q_ref[:, 0, :]  # [BR, dh]
    mask = mask_ref[...].astype(jnp.float32)

    def contrib(k_ref, vc_ref, sign):
        s = jax.lax.dot_general(
            q, k_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [BR, C]
        w = jax.nn.gelu(s, approximate=True) * mask
        return sign * jax.lax.dot_general(
            w, vc_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [BR, Q]

    out_ref[:, 0, :] = (contrib(kn_ref, vcn_ref, 1.0)
                        + contrib(ko_ref, vco_ref, -1.0)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def incr_patch_kernel(
    q: jax.Array,  # [R, H, dh] gathered rows-to-patch
    k_new: jax.Array,  # [H, C, dh] dirty-slot key buffer (new values)
    k_old: jax.Array,  # [H, C, dh] old values
    vc_new: jax.Array,  # [H, C, Q] value·codebook products (new)
    vc_old: jax.Array,  # [H, C, Q]
    mask: jax.Array,  # [R, C] {0,1}: causal col<=row & slot-occupied
    *,
    block_r: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns ΔT [R, H, Q] f32."""
    R, H, dh = q.shape
    C = k_new.shape[1]
    Q = vc_new.shape[-1]
    scale = dh ** -0.5
    pad = (-R) % block_r
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    Rp = R + pad
    grid = (Rp // block_r, H)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, 1, dh), lambda i, h: (i, h, 0)),
            pl.BlockSpec((1, C, dh), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((1, C, dh), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((1, C, Q), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((1, C, Q), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((block_r, C), lambda i, h: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 1, Q), lambda i, h: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, H, Q), jnp.float32),
        interpret=interpret,
    )(q, k_new, k_old, vc_new, vc_old, mask)
    return out[:R]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def incr_patch_kernel_batched(
    q: jax.Array,  # [B, R, H, dh] per-document gathered rows-to-patch
    k_new: jax.Array,  # [B, H, C, dh]
    k_old: jax.Array,  # [B, H, C, dh]
    vc_new: jax.Array,  # [B, H, C, Q]
    vc_old: jax.Array,  # [B, H, C, Q]
    mask: jax.Array,  # [B, R, C]
    *,
    block_r: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Batched-serving variant: the same column-patch kernel body over a grid
    with a leading *batch* dimension — one (document, row-block, head) cell
    per grid point, so B documents' dirty-slot patches run as one
    ``pallas_call``. Returns ΔT [B, R, H, Q] f32."""
    B, R, H, dh = q.shape
    C = k_new.shape[2]
    Q = vc_new.shape[-1]
    scale = dh ** -0.5
    pad = (-R) % block_r
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad), (0, 0)))
    Rp = R + pad
    grid = (B, Rp // block_r, H)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            # None squeezes the batch dim so the unbatched kernel body is
            # reused verbatim — the batch lives purely in the grid.
            pl.BlockSpec((None, block_r, 1, dh), lambda b, i, h: (b, i, h, 0)),
            pl.BlockSpec((None, 1, C, dh), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, 1, C, dh), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, 1, C, Q), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, 1, C, Q), lambda b, i, h: (b, h, 0, 0)),
            pl.BlockSpec((None, block_r, C), lambda b, i, h: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_r, 1, Q),
                               lambda b, i, h: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Rp, H, Q), jnp.float32),
        interpret=interpret,
    )(q, k_new, k_old, vc_new, vc_old, mask)
    return out[:, :R]
