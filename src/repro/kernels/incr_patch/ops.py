"""Jit'd wrapper: gather dirty rows, run the Pallas column-patch, return ΔT.

Capacity bucketing: the dirty-column buffers come in power-of-two capacities
(slots beyond the actual edit count are masked out), so every bucket size is
a distinct static compile — the standard serving-system bucketing pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.incr_patch.incr_patch import incr_patch_kernel


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def bucket_capacity(n: int, minimum: int = 8) -> int:
    from repro.common.bucketing import next_pow2

    return next_pow2(n, minimum)


def incr_patch(q, k_new, k_old, vc_new, vc_old, mask, *, row_valid=None,
               block_r: int = 128):
    """q: [R, H, dh]; k_*: [H, C, dh]; vc_*: [H, C, Q]; mask: [R, C] bool.
    Returns ΔT [R, H, Q] f32 = new-contribution − old-contribution.

    ``row_valid`` ([R] bool/float, optional) is the slot-buffer valid-row
    mask: rows whose slot is free or deleted receive a zero patch. It is
    folded into the per-(row, column) mask before the kernel launch, so the
    kernel body (and its compiled shape) is unchanged."""
    mask = mask.astype(jnp.float32)
    if row_valid is not None:
        mask = mask * row_valid.astype(jnp.float32)[:, None]
    return incr_patch_kernel(
        q, k_new, k_old, vc_new, vc_old, mask,
        block_r=block_r, interpret=not _on_tpu(),
    )


def incr_patch_batched(q, k_new, k_old, vc_new, vc_old, mask, *,
                       row_valid=None, block_r: int = 128):
    """Batched serving: every argument gains a leading document axis
    (q: [B, R, H, dh]; k_*: [B, H, C, dh]; vc_*: [B, H, C, Q];
    mask: [B, R, C]; row_valid: [B, R]) and the kernel grid gains a batch
    dimension. Returns ΔT [B, R, H, Q] f32.

    This is the *direct* entry point for callers that already hold stacked
    per-document buffers (TPU serving loops built without vmap). The vmapped
    engine route (``BatchedJitEngine`` with ``use_patch_kernel=True``)
    reaches the same batched grid through the pallas batching rule applied
    to the unbatched ``incr_patch``; both are parity-tested per document."""
    from repro.kernels.incr_patch.incr_patch import incr_patch_kernel_batched

    mask = mask.astype(jnp.float32)
    if row_valid is not None:
        mask = mask * row_valid.astype(jnp.float32)[:, :, None]
    return incr_patch_kernel_batched(
        q, k_new, k_old, vc_new, vc_old, mask,
        block_r=block_r, interpret=not _on_tpu(),
    )
