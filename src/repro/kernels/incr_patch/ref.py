"""Pure-jnp oracle for the incremental column-patch kernel (and the math the
NumPy engine performs in ``IncrementalEngine.apply_replaces`` step 2a)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def incr_patch_ref(q, k_new, k_old, vc_new, vc_old, mask) -> jax.Array:
    """q: [R, H, dh]; k_*: [H, C, dh]; vc_*: [H, C, Q]; mask: [R, C].
    Returns ΔT [R, H, Q] f32."""
    dh = q.shape[-1]
    scale = dh ** -0.5

    def contrib(k, vc, sign):
        s = jnp.einsum("rhd,hcd->rhc", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        w = jax.nn.gelu(s, approximate=True) * mask[:, None, :]
        return sign * jnp.einsum("rhc,hcq->rhq", w, vc.astype(jnp.float32))

    return contrib(k_new, vc_new, 1.0) + contrib(k_old, vc_old, -1.0)
