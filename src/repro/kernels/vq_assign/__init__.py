from repro.kernels.vq_assign.ops import vq_assign, vq_assign_batched
from repro.kernels.vq_assign.ref import vq_assign_ref
