"""Jit'd public wrapper: quantize attention outputs with the Pallas kernel.

Falls back to interpret mode off-TPU (bit-identical math, Python execution of
the kernel body) so the whole stack runs on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.vq_assign.vq_assign import vq_assign_kernel


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def vq_assign(
    x: jax.Array,  # [..., d] attention outputs
    codebook: jax.Array,  # [hq, Q, dv] with hq*dv == d
    *,
    block_n: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Returns (idx [..., hq] int32, x_q [..., d])."""
    hq, Q, dv = codebook.shape
    *lead, d = x.shape
    assert hq * dv == d, (codebook.shape, d)
    xh = x.reshape(-1, hq, dv)
    idx, xq = vq_assign_kernel(xh, codebook, block_n=block_n,
                               interpret=not _on_tpu())
    return idx.reshape(*lead, hq), xq.reshape(*lead, d).astype(x.dtype)


def vq_assign_batched(
    x: jax.Array,  # [B, N, d] a batch of documents' attention outputs
    codebook: jax.Array,  # [hq, Q, dv] with hq*dv == d
    *,
    block_n: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Batched serving: quantize B documents in one kernel launch whose grid
    has a leading batch dimension (the codebook block is batch-invariant).
    Returns (idx [B, N, hq] int32, x_q [B, N, d])."""
    from repro.kernels.vq_assign.vq_assign import vq_assign_kernel_batched

    hq, Q, dv = codebook.shape
    B, N, d = x.shape
    assert hq * dv == d, (codebook.shape, d)
    xh = x.reshape(B, N, hq, dv)
    idx, xq = vq_assign_kernel_batched(xh, codebook, block_n=block_n,
                                       interpret=not _on_tpu())
    return idx, xq.reshape(B, N, d).astype(x.dtype)
