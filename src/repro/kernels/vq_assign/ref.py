"""Pure-jnp oracle for the vq_assign kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vq_assign_ref(xh: jax.Array, codebook: jax.Array) -> tuple[jax.Array, jax.Array]:
    """xh: [N, hq, dv]; codebook: [hq, Q, dv] -> (idx [N, hq], xq [N, hq, dv])."""
    bias = -0.5 * jnp.sum(codebook.astype(jnp.float32) ** 2, axis=-1)  # [hq, Q]
    scores = (
        jnp.einsum("nhd,hqd->nhq", xh.astype(jnp.float32), codebook.astype(jnp.float32))
        + bias[None]
    )
    idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    xq = jnp.take_along_axis(
        codebook[None].astype(jnp.float32),
        idx[:, :, None, None],
        axis=2,
    )[:, :, 0, :]
    return idx, xq.astype(xh.dtype)
