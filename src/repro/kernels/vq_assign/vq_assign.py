"""Pallas TPU kernel: multi-head VQ nearest-codebook assignment + lookup.

Implements the paper's App. A.2 inner-product form on the MXU:

    argmin_c ||x - C_c||^2  ==  argmax_c (x·C_c - ||C_c||^2/2)

Per (token-block, vq-head) grid cell:
  1. scores = x_blk @ C_hᵀ + bias_h           — one [BN, dv]x[dv, Q] MXU matmul
  2. idx    = row argmax over Q                — VPU reduce
  3. x_q    = onehot(idx) @ C_h                — gather as a second MXU matmul
     (TPU-native: avoids a hostile dynamic-gather, and Q=64/128 is one lane
     tile wide)

VMEM: x block BN×dv (bf16/f32), the head's whole codebook Q×dv, scores BN×Q.
With BN=256, dv≤512, Q≤256 everything sits well under ~2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, cb_ref, bias_ref, idx_ref, xq_ref):
    # x_ref: [BN, 1, dv]; cb_ref: [1, Q, dv]; bias_ref: [1, Q]
    x = x_ref[:, 0, :].astype(jnp.float32)  # [BN, dv]
    cb = cb_ref[0].astype(jnp.float32)  # [Q, dv]
    scores = jax.lax.dot_general(
        x, cb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) + bias_ref[0][None, :]  # [BN, Q]
    idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)  # [BN]
    onehot = (
        idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    ).astype(jnp.float32)
    xq = jax.lax.dot_general(
        onehot, cb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [BN, dv]
    idx_ref[:, 0] = idx
    xq_ref[:, 0, :] = xq.astype(xq_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def vq_assign_kernel(
    xh: jax.Array,  # [N, hq, dv] tokens split by vq head
    codebook: jax.Array,  # [hq, Q, dv]
    *,
    block_n: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (idx [N, hq] int32, xq [N, hq, dv])."""
    N, hq, dv = xh.shape
    Q = codebook.shape[1]
    bias = -0.5 * jnp.sum(codebook.astype(jnp.float32) ** 2, axis=-1)  # [hq, Q]
    pad = (-N) % block_n
    if pad:
        xh = jnp.pad(xh, ((0, pad), (0, 0), (0, 0)))
    Np = N + pad
    grid = (Np // block_n, hq)
    idx, xq = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1, dv), lambda i, h: (i, h, 0)),
            pl.BlockSpec((1, Q, dv), lambda i, h: (h, 0, 0)),
            pl.BlockSpec((1, Q), lambda i, h: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, h: (i, h)),
            pl.BlockSpec((block_n, 1, dv), lambda i, h: (i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, hq), jnp.int32),
            jax.ShapeDtypeStruct((Np, hq, dv), xh.dtype),
        ],
        interpret=interpret,
    )(xh, codebook, bias)
    return idx[:N], xq[:N]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def vq_assign_kernel_batched(
    xh: jax.Array,  # [B, N, hq, dv] per-document tokens split by vq head
    codebook: jax.Array,  # [hq, Q, dv] (shared across the batch)
    *,
    block_n: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched-serving variant: same assignment kernel body over a grid with
    a leading *batch* dimension (one (document, token-block, vq-head) cell
    per grid point). The codebook block is batch-invariant, so it stays
    resident in VMEM across the batch axis.
    Returns (idx [B, N, hq] int32, xq [B, N, hq, dv])."""
    B, N, hq, dv = xh.shape
    Q = codebook.shape[1]
    bias = -0.5 * jnp.sum(codebook.astype(jnp.float32) ** 2, axis=-1)  # [hq, Q]
    pad = (-N) % block_n
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Np = N + pad
    grid = (B, Np // block_n, hq)
    idx, xq = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            # None squeezes the batch dim: the unbatched kernel body is
            # reused verbatim, the batch lives purely in the grid.
            pl.BlockSpec((None, block_n, 1, dv), lambda b, i, h: (b, i, h, 0)),
            pl.BlockSpec((1, Q, dv), lambda b, i, h: (h, 0, 0)),
            pl.BlockSpec((1, Q), lambda b, i, h: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_n, 1), lambda b, i, h: (b, i, h)),
            pl.BlockSpec((None, block_n, 1, dv), lambda b, i, h: (b, i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Np, hq), jnp.int32),
            jax.ShapeDtypeStruct((B, Np, hq, dv), xh.dtype),
        ],
        interpret=interpret,
    )(xh, codebook, bias)
    return idx[:, :N], xq[:, :N]
