import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count on first init. That also rules out `from __future__` here.
"""Multi-pod dry-run: ``.lower().compile()`` every (architecture × input
shape × mesh) combination on placeholder devices, and extract the roofline
inputs from the compiled artifacts.

Per combination this produces:

  * the *sharding-correctness proof*: the full scanned model train/prefill/
    serve step compiles on the (16,16) single-pod mesh and the (2,16,16)
    multi-pod mesh;
  * ``memory_analysis()`` (per-device bytes) and ``cost_analysis()`` of that
    compile;
  * compositional FLOPs / bytes / collective-bytes: XLA's cost analysis
    counts a ``while`` (lax.scan) body ONCE regardless of trip count
    (verified empirically), so per-stage layer bodies and the trunk are each
    lowered and compiled separately on the same mesh and combined as

        total = trunk + Σ_stages repeat_i × body_i          (§Roofline)

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_arch_names, get_config
from repro.configs.base import ArchConfig
from repro.distributed.context import use_mesh
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import batch_shardings, cache_shardings, param_shardings
from repro.launch.specs import SHAPES, ShapeCfg, decode_token_specs, input_specs, shape_supported
from repro.models import transformer as T
from repro.serving.decode import make_serve_step
from repro.training import make_schedule, make_train_step, train_state_init

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


# --------------------------------------------------------------- step builders


def _loss_like(cfg: ArchConfig):
    sched = make_schedule(peak_lr=3e-4, warmup_steps=100, total_steps=10_000)
    return make_train_step(cfg, sched)


def _prefill_fn(cfg: ArchConfig):
    def prefill(params, batch):
        logits, _ = T.forward(
            params, cfg, batch["tokens"], batch.get("positions"),
            patch_embeds=batch.get("patch_embeds"),
        )
        return logits[:, -1]  # next-token logits for the batch

    return prefill


# --------------------------------------------------------------- lowering


def _compile_and_stats(lowered) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    dt = time.time() - t0
    ca = compiled.cost_analysis() or {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_stats(hlo)
    return {
        "compile_s": round(dt, 2),
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": int(coll.total_bytes),
        "collectives": coll.summary(),
        "memory": mem,
    }


def _state_specs(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(partial(train_state_init, cfg=cfg, dtype=dtype),
                          jax.random.PRNGKey(0))


def lower_full(cfg: ArchConfig, shape: ShapeCfg, mesh) -> dict:
    """The sharding-correctness proof: the complete scanned step."""
    with use_mesh(mesh):
        if shape.kind == "train":
            state_sds = _state_specs(cfg)
            batch_sds = input_specs(cfg, shape)
            in_sh = (param_shardings(state_sds, mesh), batch_shardings(batch_sds, mesh))
            step = _loss_like(cfg)
            lowered = jax.jit(step, in_shardings=in_sh).lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            params_sds = jax.eval_shape(
                partial(T.init_params, cfg=cfg, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
            )
            batch_sds = input_specs(cfg, shape)
            in_sh = (param_shardings(params_sds, mesh), batch_shardings(batch_sds, mesh))
            lowered = jax.jit(_prefill_fn(cfg), in_shardings=in_sh).lower(
                params_sds, batch_sds
            )
        else:  # decode
            params_sds = jax.eval_shape(
                partial(T.init_params, cfg=cfg, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
            )
            caches_sds = jax.eval_shape(
                partial(T.init_caches, cfg, shape.global_batch, shape.seq_len,
                        dtype=jnp.bfloat16)
            )
            tok = decode_token_specs(cfg, shape)
            in_sh = (
                param_shardings(params_sds, mesh),
                cache_shardings(caches_sds, mesh, batch=shape.global_batch),
                batch_shardings({"tokens": tok["tokens"]}, mesh)["tokens"],
                batch_shardings({"positions": tok["positions"]}, mesh)["positions"],
            )
            step = make_serve_step(cfg)
            # donate the caches: the decode loop always overwrites them, and
            # without aliasing every one-token update costs a whole-cache
            # copy (§Perf iteration 6)
            lowered = jax.jit(step, in_shardings=in_sh, donate_argnums=(1,)).lower(
                params_sds, caches_sds, tok["tokens"], tok["positions"]
            )
        return _compile_and_stats(lowered)


# ------------------------------------------------- compositional roofline


def _stage_param_sds(cfg: ArchConfig, dtype=jnp.bfloat16):
    params_sds = jax.eval_shape(
        partial(T.init_params, cfg=cfg, dtype=dtype), jax.random.PRNGKey(0)
    )
    return params_sds


def _one_stage_body(cfg: ArchConfig, si: int, *, train: bool):
    pattern, _ = cfg.stages[si]

    def body(sp, x, positions):
        aux = jnp.zeros((), jnp.float32)
        for pi, layer in enumerate(pattern):
            x, a = T._layer_fwd(
                sp[pi], cfg, layer, x, positions, train=train,
                vq_rng=jax.random.PRNGKey(0) if train else None,
            )
            aux = aux + a
        return x, aux

    if not train:
        return body

    def train_body(sp, x, positions):
        def loss(sp_, x_):
            import os as _os
            if _os.environ.get("REMAT_POLICY", "full") == "dots":
                ckpt = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                )
            else:
                ckpt = jax.checkpoint(body)
            y, aux = ckpt(sp_, x_, positions)
            return jnp.sum(y.astype(jnp.float32)) + aux

        g_sp, g_x = jax.grad(loss, argnums=(0, 1))(sp, x)
        return g_sp, g_x

    return train_body


def _decode_stage_body(cfg: ArchConfig, si: int):
    pattern, _ = cfg.stages[si]

    def body(sp, cache, x, positions):
        new = []
        for pi, layer in enumerate(pattern):
            x, mc = T._layer_decode(sp[pi], cfg, layer, x, cache[pi], positions)
            new.append(mc)
        return x, tuple(new)

    return body


def _trunk_fns(cfg: ArchConfig, shape: ShapeCfg):
    """Embedding + head (+ loss/opt for train) without any layers."""
    if shape.kind == "train":

        def trunk(params, batch):
            b = batch["tokens"].shape[0]
            n = batch["tokens"].shape[1]
            pos = batch.get("positions")
            if pos is None:
                pos = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
            x = T.embed_tokens(params["embed"], cfg, batch["tokens"], pos)
            if cfg.input_mode == "vlm":
                x = T.merge_vision(params["embed"], batch["patch_embeds"], x)

            def loss(p, x_):
                logits = T._head(p, cfg, x_)
                from repro.training.losses import next_token_loss

                return next_token_loss(logits[:, -batch["tokens"].shape[1]:],
                                       batch["tokens"])

            l, (gp, gx) = jax.value_and_grad(loss, argnums=(0, 1))(params, x)
            return l, gp["final_norm"], gx

        return trunk

    def trunk(params, batch):
        b = batch["tokens"].shape[0]
        n = batch["tokens"].shape[1]
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
        x = T.embed_tokens(params["embed"], cfg, batch["tokens"], pos)
        if cfg.input_mode == "vlm" and "patch_embeds" in batch:
            x = T.merge_vision(params["embed"], batch["patch_embeds"], x)
        logits = T._head(params, cfg, x)
        return logits[:, -1]

    return trunk


def lower_roofline(cfg: ArchConfig, shape: ShapeCfg, mesh) -> dict:
    """Compositional FLOPs/bytes/collectives: trunk + Σ repeat × stage body."""
    total = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0}
    parts = {}
    with use_mesh(mesh):
        params_sds = _stage_param_sds(cfg)
        p_sh = param_shardings(params_sds, mesh)
        b, n = shape.global_batch, shape.seq_len
        if cfg.input_mode == "vlm" and shape.kind != "decode":
            n_x = n  # patches already folded into the sequence for bodies
        else:
            n_x = n
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if shape.kind == "decode":
            x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
            pos_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        else:
            x_sds = jax.ShapeDtypeStruct((b, n_x, cfg.d_model), jnp.bfloat16)
            pos_sds = jax.ShapeDtypeStruct((b, n_x), jnp.int32)
        n_data = 1
        for a in data_axes:
            n_data *= mesh.shape[a]
        # between layers the residual stream is sequence-sharded on "model"
        # (§Perf iteration 5), so stage bodies are lowered with that input
        # sharding — matches the steady state of the full scanned model
        n_model = mesh.shape.get("model", 1)
        seq_len_x = x_sds.shape[1]
        seq_spec = "model" if (shape.kind != "decode" and seq_len_x % n_model == 0) else None
        if b >= n_data and b % n_data == 0:
            x_spec = NamedSharding(mesh, P(data_axes, seq_spec, None))
            pos_spec = NamedSharding(mesh, P(data_axes, None))
        else:
            x_spec = NamedSharding(mesh, P(None, seq_spec, None))
            pos_spec = NamedSharding(mesh, P(None, None))

        # --- per-stage bodies ---
        for si, (pattern, repeat) in enumerate(cfg.stages):
            sp_sds = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                params_sds.params["stages"][si]
                if hasattr(params_sds, "params")
                else params_sds["stages"][si],
            )
            sp_sh = jax.tree.map(
                lambda s: NamedSharding(
                    mesh, P(*s.spec[1:])
                ),
                (p_sh.params["stages"][si] if hasattr(p_sh, "params")
                 else p_sh["stages"][si]),
            )
            if shape.kind == "decode":
                caches_sds = jax.eval_shape(
                    partial(T.init_caches, cfg, b, shape.seq_len, dtype=jnp.bfloat16)
                )
                c_sh_full = cache_shardings(caches_sds, mesh, batch=b)
                c_sds = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
                    caches_sds[si],
                )
                c_sh = jax.tree.map(
                    lambda s: NamedSharding(mesh, P(*s.spec[1:])), c_sh_full[si]
                )
                fn = _decode_stage_body(cfg, si)
                lowered = jax.jit(
                    fn, in_shardings=(sp_sh, c_sh, x_spec, pos_spec),
                    donate_argnums=(1,),  # §Perf iteration 6: alias caches
                ).lower(sp_sds, c_sds, x_sds, pos_sds)
            else:
                fn = _one_stage_body(cfg, si, train=(shape.kind == "train"))
                lowered = jax.jit(
                    fn, in_shardings=(sp_sh, x_spec, pos_spec)
                ).lower(sp_sds, x_sds, pos_sds)
            st = _compile_and_stats(lowered)
            parts[f"stage{si}(x{repeat})"] = st
            total["flops"] += repeat * st["flops"]
            total["bytes"] += repeat * st["bytes"]
            total["collective_bytes"] += repeat * st["collective_bytes"]

        # --- trunk ---
        if shape.kind == "decode":
            batch_sds = decode_token_specs(cfg, shape)
        else:
            batch_sds = input_specs(cfg, shape)
        trunk = _trunk_fns(cfg, shape if shape.kind == "train" else
                           ShapeCfg(shape.name, "prefill", shape.seq_len if
                                    shape.kind != "decode" else 1, b))
        b_sh = batch_shardings(batch_sds, mesh)
        lowered = jax.jit(
            trunk,
            in_shardings=(
                param_shardings(
                    params_sds.params if hasattr(params_sds, "params") else params_sds,
                    mesh,
                ),
                b_sh,
            ),
        ).lower(
            params_sds.params if hasattr(params_sds, "params") else params_sds,
            batch_sds,
        )
        st = _compile_and_stats(lowered)
        parts["trunk"] = st
        total["flops"] += st["flops"]
        total["bytes"] += st["bytes"]
        total["collective_bytes"] += st["collective_bytes"]
    return {"total": total, "parts": parts}


# ------------------------------------------------------------ model flops


def model_flops(cfg: ArchConfig, shape: ShapeCfg) -> float:
    """MODEL_FLOPS = 6·N_active·D (spec §Roofline)."""
    params_sds = jax.eval_shape(
        partial(T.init_params, cfg=cfg, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
    )
    n_total = 0
    n_moe_all = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_sds)[0]:
        size = 1
        for s in leaf.shape:
            size *= s
        names = [str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                 for p in path]
        if cfg.moe and any(n in ("w_gate", "w_up", "w_down") for n in names) and len(
            leaf.shape
        ) == 4:
            n_moe_all += size
        else:
            n_total += size
    n_active = n_total
    if cfg.moe and n_moe_all:
        n_active += n_moe_all * (cfg.moe.top_k / cfg.moe.n_experts)
    D = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * D


# ------------------------------------------------------------------ driver


def run_one(arch: str, shape_name: str, *, multi_pod: bool, roofline: bool) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        rec["full"] = lower_full(cfg, shape, mesh)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        return rec
    if roofline and not multi_pod:
        try:
            rl = lower_roofline(cfg, shape, mesh)
            # cost_analysis() reports the PARTITIONED (per-device) module
            # (verified empirically), so each term is per-device work over
            # per-chip rate — the roofline time of the parallel step.
            chips = 256
            t = rl["total"]
            terms = {
                "compute_s": t["flops"] / PEAK_FLOPS,
                "memory_s": t["bytes"] / HBM_BW,
                "collective_s": t["collective_bytes"] / ICI_BW,
            }
            terms["bottleneck"] = max(terms, key=lambda k: terms[k])
            mf = model_flops(cfg, shape)
            terms["model_flops"] = mf
            terms["useful_ratio"] = mf / (t["flops"] * chips) if t["flops"] else 0.0
            rec["roofline"] = {**rl, "terms": terms}
        except Exception as e:
            rec["roofline_error"] = f"{type(e).__name__}: {e}"
            rec["roofline_traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = all_arch_names()[:10] if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    out_f = open(args.out, "a") if args.out else None
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_one(arch, shape_name, multi_pod=mp,
                              roofline=args.roofline and not mp)
                rec["wall_s"] = round(time.time() - t0, 1)
                line = json.dumps(rec)
                print(f"[{rec['status']:>7}] {arch} {shape_name} {rec['mesh']} "
                      f"({rec['wall_s']}s)"
                      + (f" err={rec.get('error','')}" if rec["status"] == "error" else ""),
                      flush=True)
                if out_f:
                    out_f.write(line + "\n")
                    out_f.flush()
    if out_f:
        out_f.close()


if __name__ == "__main__":
    main()
