"""Parse collective traffic out of compiled HLO text (§Roofline).

``cost_analysis()`` reports FLOPs and bytes but not collective bytes; we
recover them by scanning the (post-SPMD-partitioning) HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instructions and summing their *operand* sizes (per the spec).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one instruction definition: %name = <type> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\s/]*?))\s+([\w\-]+)(?:\.\d+)?\(([^)]*)",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string, incl. tuples '(f32[2,3], bf16[4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    count_by_kind: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> dict:
        return {
            k: {"count": self.count_by_kind[k], "bytes": self.bytes_by_kind[k]}
            for k in sorted(self.bytes_by_kind)
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in the HLO module text.

    Operand shapes are resolved through a name->type map built from all
    instruction definitions (operand references carry no shapes inline).
    Instructions inside while-loop bodies appear once; scan trip counts are
    NOT multiplied in (we report per-HLO-occurrence bytes and scale by layer
    count analytically in the roofline — see benchmarks/roofline.py)."""
    types: dict[str, str] = {}
    pending: list[tuple[str, str]] = []  # (opcode, operand list str)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, operands = m.groups()
        types[name] = type_str
        base_op = opcode.split(".")[0]
        if base_op in _COLLECTIVES:
            pending.append((base_op, operands))
    stats = CollectiveStats()
    opnd_re = re.compile(r"%?([\w.\-]+)")
    for op, operands in pending:
        total = 0
        for token in operands.split(","):
            token = token.strip()
            m = opnd_re.match(token)
            if not m:
                continue
            opname = m.group(1)
            if opname in types:
                total += _shape_bytes(types[opname])
            else:
                # inline-typed operand, e.g. 'f32[8,16] %foo'
                total += _shape_bytes(token)
        stats.bytes_by_kind[op] += total
        stats.count_by_kind[op] += 1
    return stats


def top_ops_by_bytes(hlo_text: str, k: int = 25) -> list[tuple[str, int, int]]:
    """Rank opcodes by total (operand+output) bytes across the module —
    the dry-run 'profile' used by the §Perf hypothesis loop.
    Returns [(opcode, count, bytes)]."""
    types: dict[str, str] = {}
    per_op: dict[str, list[int]] = defaultdict(lambda: [0, 0])
    instrs = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, operands = m.groups()
        types[name] = type_str
        instrs.append((opcode.split(".")[0], type_str, operands))
    opnd_re = re.compile(r"%?([\w.\-]+)")
    for opcode, type_str, operands in instrs:
        total = _shape_bytes(type_str)
        for token in operands.split(","):
            token = token.strip()
            m = opnd_re.match(token)
            if m and m.group(1) in types:
                total += _shape_bytes(types[m.group(1)])
        per_op[opcode][0] += 1
        per_op[opcode][1] += total
    ranked = sorted(per_op.items(), key=lambda kv: -kv[1][1])[:k]
    return [(op, c, b) for op, (c, b) in ranked]


@dataclass
class LaunchStats:
    """Kernel-launch census of one compiled HLO module (the hot-path bench's
    fusion-win metric: fewer fusions + custom-calls per step = fewer device
    launches per edit)."""
    fusions: int = 0
    custom_calls: int = 0  # Pallas kernels and library calls land here
    collectives: int = 0
    instructions: int = 0

    @property
    def launches(self) -> int:
        """Device-program launches the module implies: every fusion and
        every custom-call is (at least) one kernel on the accelerator
        timeline. Elementwise ops outside fusions are compiled into the
        surrounding computation on CPU/TPU, so this is the stable,
        backend-portable count."""
        return self.fusions + self.custom_calls

    def summary(self) -> dict:
        return {"fusions": self.fusions, "custom_calls": self.custom_calls,
                "collectives": self.collectives,
                "instructions": self.instructions, "launches": self.launches}


def launch_stats(hlo_text: str) -> LaunchStats:
    """Count fusion/custom-call/collective instructions across the module.

    Operates on the same ``_INSTR_RE`` parse as ``collective_stats`` —
    post-optimization HLO (``compiled.as_text()``), where every residual
    op boundary is explicit. Deterministic for a fixed jax/XLA version:
    the hot-path bench gates on these counts with ``must_equal``-style
    identity, re-anchored when the compiler version moves."""
    st = LaunchStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        opcode = m.group(3).split(".")[0]
        st.instructions += 1
        if opcode == "fusion":
            st.fusions += 1
        elif opcode == "custom-call":
            st.custom_calls += 1
        elif opcode in _COLLECTIVES:
            st.collectives += 1
    return st


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort extraction of scan/while trip counts (for scaling
    per-iteration collective bytes to whole-model traffic)."""
    out = []
    for m in re.finditer(r"trip_count[=:\"]+(\d+)", hlo_text):
        out.append(int(m.group(1)))
    return out
