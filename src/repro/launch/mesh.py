"""Production mesh construction (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(n_devices: Optional[int] = None, *,
                      axis: str = "data") -> Mesh:
    """1-D data-parallel mesh for the batched serving stack (DESIGN.md §6).

    The batch (document) axis of every ``BatchedJitEngine`` dispatch is
    sharded over this mesh's single ``axis``; weights replicate. Defaults to
    every visible device so the same call is device-count-agnostic across a
    laptop (1 CPU device), CI with forced host devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``) and a real
    accelerator ring. Pass ``n_devices`` to use a prefix of the device list
    (the sharded-parity tests pin mesh sizes 1/2/4 this way).
    """
    devs = jax.devices()
    k = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= k <= len(devs):
        raise ValueError(
            f"serving mesh of {k} devices, but only {len(devs)} visible")
    return Mesh(np.asarray(devs[:k]), (axis,))
