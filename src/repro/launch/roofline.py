"""Analytic roofline model for the incremental edit step (§Roofline).

``launch/dryrun.py`` rooflines the *training* stack compositionally from
lowered stage bodies; serving needs the same discipline for the edit hot
path. This module prices what the incremental algorithm *must* do for one
``(B, n_cap, C, R)`` bucketed step — the useful work — so the gated
``benchmarks/hot_path.py`` can report how much of each compiled step's
XLA-measured FLOPs/bytes is algorithmically necessary vs padding + plumbing
(``achieved vs roofline``), and CI can hold the fraction.

The model counts only the matmul-shaped terms (projections, score dots,
value accumulations); elementwise work (gelu, masks, argmax) is O(of the
same shapes) and under the constant-factor noise floor of a roofline.

All functions are pure shape arithmetic — no jax imports, no tracing — so
the hot-path bench can price shapes it never compiles.
"""
from __future__ import annotations

from dataclasses import dataclass

# Single-chip peaks mirrored from launch/dryrun.py (TPU v5e-class): the
# bench reports ratios (achieved / roofline), which divide these out on
# same-chip comparisons, but absolute seconds need SOME peak to anchor to.
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s


def edit_step_flops(n_layers: int, meta: dict, n_cap: int, C: int,
                    R: int, d_ff: int = 0) -> float:
    """Useful FLOPs of ONE document's bucketed ``apply_edits`` step.

    Per layer the incremental algorithm (paper §3.2, DESIGN.md §3) does:

    * edited-slot refresh: C slots re-embed and re-project to q/k/v
      (2·C·d·3·H·dh) and re-code through the VQ value path (2·C·H·dh·Q);
    * column patch: every row's totals gain the new-minus-old contribution
      of the C patched columns — scores (2 dots) 2·2·n·C·H·dh and value
      accumulations 2·2·n·C·H·Q;
    * dirty-row recompute: R rows re-run full attention over n columns —
      scores 2·R·n·H·dh, accumulation 2·R·n·H·Q — then the row MLP
      (2·R·d·d_ff·2; ``d_ff`` defaults to 4·d when not given) and output
      projection (2·R·H·dh·d).

    ``n_cap`` stands in for ``n`` (the compiled step cannot see n_real):
    this IS the padding honesty of the model — a half-full capacity class
    doubles the reported roofline relative to its truly-useful work, and
    the achieved fraction says so.
    """
    d, H, dh, Q = meta["d"], meta["H"], meta["dh"], meta["Q"]
    d_ff = d_ff or 4 * d
    n = n_cap
    per_layer = (
        2 * C * d * 3 * H * dh        # edited-slot qkv projection
        + 2 * C * H * dh * Q          # edited-slot VQ value coding
        + 2 * 2 * n * C * H * dh      # patch scores (old + new columns)
        + 2 * 2 * n * C * H * Q       # patch value accumulation (old + new)
        + 2 * R * n * H * dh          # dirty-row scores
        + 2 * R * n * H * Q           # dirty-row value accumulation
        + 2 * R * d * d_ff * 2        # dirty-row MLP (in + out mats)
        + 2 * R * H * dh * d          # dirty-row output projection
    )
    return float(n_layers) * per_layer


def edit_step_bytes(n_layers: int, meta: dict, n_cap: int,
                    weight_bytes: int = 0) -> float:
    """Minimum HBM traffic of one step: read + write the document state
    (every leaf is gathered/scattered at least once by the patch) plus one
    read of the weight stacks (``weight_bytes``; pass the engine's real
    number, 0 to price state traffic alone)."""
    from repro.serving.jit_engine import state_nbytes_for

    return 2.0 * state_nbytes_for(n_cap, n_layers, meta) + float(weight_bytes)


@dataclass
class RooflineReport:
    """Analytic floor vs XLA-measured cost of one compiled step."""
    analytic_flops: float
    analytic_bytes: float
    xla_flops: float
    xla_bytes: float
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW

    @property
    def compute_s(self) -> float:
        return self.analytic_flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.analytic_bytes / self.hbm_bw

    @property
    def bottleneck(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"

    @property
    def useful_flop_fraction(self) -> float:
        """analytic / XLA-counted FLOPs: how much of the compiled step is
        algorithmically necessary (1.0 = no waste; small = the step spends
        its arithmetic on padding or redundant recompute)."""
        return self.analytic_flops / self.xla_flops if self.xla_flops else 0.0

    @property
    def useful_byte_fraction(self) -> float:
        return self.analytic_bytes / self.xla_bytes if self.xla_bytes else 0.0

    def summary(self) -> dict:
        return {
            "analytic_flops": self.analytic_flops,
            "analytic_bytes": self.analytic_bytes,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
            "useful_flop_fraction": round(self.useful_flop_fraction, 6),
            "useful_byte_fraction": round(self.useful_byte_fraction, 6),
            "bottleneck": self.bottleneck,
        }


def edit_step_roofline(n_layers: int, meta: dict, n_cap: int, C: int, R: int,
                       *, xla_flops: float, xla_bytes: float,
                       weight_bytes: int = 0, batch: int = 1,
                       d_ff: int = 0) -> RooflineReport:
    """Price a ``(B, n_cap, C, R)`` batched edit step against its analytic
    floor. ``xla_flops``/``xla_bytes`` come from the compiled step's
    ``cost_analysis()`` (whole-batch numbers); the analytic side scales the
    per-document model by ``batch`` and charges the weights once (they are
    shared across the batch)."""
    return RooflineReport(
        analytic_flops=batch * edit_step_flops(n_layers, meta, n_cap, C, R,
                                               d_ff=d_ff),
        analytic_bytes=(batch * edit_step_bytes(n_layers, meta, n_cap)
                        + float(weight_bytes)),
        xla_flops=float(xla_flops), xla_bytes=float(xla_bytes),
    )
