"""Serving launcher — the incremental writing-assistant loop.

Single-document op-count demo (the paper's measurement):
  PYTHONPATH=src python -m repro.launch.serve --arch vq-opt-125m --smoke \
      --doc-len 128 --edits 20

Tiered-fleet demo (ISSUE 5: more sessions than the device budget admits;
evicted documents rehydrate bit-exactly on their next touch):
  PYTHONPATH=src python -m repro.launch.serve --arch vq-opt-125m --smoke \
      --tiered --docs 8 --budget-docs 3 --doc-len 48 --edits 40

Async-fleet demo (ISSUE 6: concurrent sessions through the deadline-batching
front end; per-edit / per-suggestion latency SLOs printed at the end):
  PYTHONPATH=src python -m repro.launch.serve --arch vq-opt-125m --smoke \
      --async-fleet --docs 4 --doc-len 48 --edits 24 --delay-ms 8

Multi-replica fleet demo (ISSUE 10: subprocess replica workers behind the
document router, with a live cross-replica migration mid-run; aggregated
fleet stats table at the end):
  PYTHONPATH=src python -m repro.launch.serve --arch vq-opt-125m --smoke \
      --fleet 2 --docs 4 --doc-len 24 --edits 12
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.edits import random_atomic_edit
from repro.data import SyntheticCorpus
from repro.models import transformer as T
from repro.serving.engine import IncrementalServer


def run_single(args, cfg, params) -> None:
    server = IncrementalServer(jax.device_get(params), cfg)

    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
    doc = list(corpus.document(args.doc_len, 0))
    server.open_document("doc", doc)
    print(f"opened {len(doc)}-token document; streaming {args.edits} atomic edits")

    rng = np.random.default_rng(0)
    tokens = doc
    for i in range(args.edits):
        e = random_atomic_edit(rng, tokens, cfg.vocab)
        ops = server.apply_edit("doc", e)
        from repro.core.edits import apply_edit

        tokens = apply_edit(tokens, e)
        dense = server._dense_ops(len(tokens))
        print(f"edit {i:3d} {e.op:8s}@{e.pos:4d} ops={ops:>14,} "
              f"(from-scratch {dense:>14,} -> {dense/max(ops,1):6.1f}X)")
    s = server.stats
    print(f"\ntotals: edits={s.edits} defrags={s.defrags} "
          f"cumulative speedup={s.speedup:.1f}X")


def run_tiered(args, cfg, params) -> None:
    """A fleet bigger than the device budget: the batch server's tiered
    state store (DESIGN.md §7) evicts least-recently-touched sessions to
    host RAM / disk and rehydrates them transparently as the zipf-skewed
    edit stream touches them again."""
    from repro.common.bucketing import next_pow2
    from repro.serving.batch_server import BatchServer
    from repro.serving.jit_engine import state_nbytes_for_config

    # size the budget at the capacity the server will actually bucket to —
    # documents occupy next_pow2(doc_len) slots, not doc_len
    min_cap = next_pow2(max(64, args.doc_len))
    per = state_nbytes_for_config(cfg, min_cap)
    budget = int(args.budget_docs * per * 1.25)
    server = BatchServer(jax.device_get(params), cfg, edit_capacity=4,
                         row_capacity=64, max_batch=2,
                         min_doc_capacity=min_cap,
                         device_budget_bytes=budget,
                         host_budget_bytes=2 * per)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
    docs = {f"d{i}": list(corpus.document(args.doc_len, i))
            for i in range(args.docs)}
    server.open_documents(docs)
    per_mb = per / 2**20
    print(f"opened {args.docs} sessions of ~{per_mb:.1f} MiB state under a "
          f"{budget/2**20:.1f} MiB device budget "
          f"(~{args.budget_docs} resident documents)")

    rng = np.random.default_rng(1)
    w = 1.0 / np.arange(1, args.docs + 1) ** 1.2
    w /= w.sum()
    for i in range(args.edits):
        did = f"d{int(rng.choice(args.docs, p=w))}"
        tier = server.tier(did)
        pos = int(rng.integers(len(server.docs[did].slots)))
        server.submit_replace(did, pos, int(rng.integers(cfg.vocab)))
        server.flush()
        s = server.stats
        print(f"edit {i:3d} -> {did} (was {tier:4s})  tiers "
              f"hot={s.docs_hot} warm={s.docs_warm} cold={s.docs_cold}  "
              f"bytes hot={s.bytes_hot/2**20:5.1f}MiB "
              f"warm={s.bytes_warm/2**20:5.1f}MiB "
              f"cold={s.bytes_cold/2**20:5.1f}MiB")
    s = server.stats
    print(f"\ntotals: edits={s.edits_applied} evictions={s.evictions} "
          f"spills={s.spills} rehydrations={s.rehydrations} "
          f"hot_hit_rate={s.hot_hit_rate:.2f}")
    for did in list(server.docs):
        server.close_document(did)
    print(f"closed all sessions: bytes hot/warm/cold/suggest = "
          f"{s.bytes_hot}/{s.bytes_warm}/{s.bytes_cold}/{s.bytes_suggest}")


def run_async_fleet(args, cfg, params) -> None:
    """Concurrent sessions (one client thread each) through the deadline-
    batching async front end (DESIGN.md §8): each client types a burst of
    edits, then blocks on its refreshed suggestion; bursts admitted within
    one ``--delay-ms`` window coalesce into shared dispatch rounds."""
    import threading

    from repro.serving.async_server import AsyncBatchServer
    from repro.serving.batch_server import BatchServer

    server = BatchServer(jax.device_get(params), cfg, edit_capacity=4,
                         row_capacity=32, max_batch=max(2, args.docs),
                         min_doc_capacity=64)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
    docs = {f"d{i}": list(corpus.document(args.doc_len, i))
            for i in range(args.docs)}

    def client(asrv, did, seed):
        rng = np.random.default_rng(seed)
        tokens = list(docs[did])
        for burst in range(args.edits // 3):
            for _ in range(3):
                e = random_atomic_edit(rng, tokens, cfg.vocab)
                asrv.submit_edit(did, e)
                from repro.core.edits import apply_edit

                tokens = apply_edit(tokens, e)
            sugg = asrv.suggest(did, 8).result(600)
            print(f"  {did} burst {burst}: suggestion "
                  f"{[int(x) for x in sugg[:4]]}...")

    with AsyncBatchServer(server,
                          max_batch_delay_ms=args.delay_ms) as asrv:
        for t in [asrv.open_document(d, toks) for d, toks in docs.items()]:
            t.result(600)
        print(f"opened {args.docs} concurrent sessions "
              f"(deadline {args.delay_ms}ms, bucket {asrv.bucket_docs} docs)")
        threads = [threading.Thread(target=client, args=(asrv, d, 10 + i))
                   for i, d in enumerate(docs)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        a = asrv.stats
        print(f"\nrounds={a.rounds} (deadline={a.deadline_rounds} "
              f"full={a.full_rounds}) mean_edits_per_round="
              f"{a.mean_edits_per_round:.2f} failed={a.requests_failed}")
    s = server.stats
    for name, h in (("edit", s.edit_latency), ("suggest", s.suggest_latency)):
        print(f"{name:8s} latency: n={h.count} p50={h.p50:.1f}ms "
              f"p99={h.p99:.1f}ms max={h.max_ms:.1f}ms")


def run_fleet(args, cfg) -> None:
    """Replica workers behind the document router (DESIGN.md §11): sessions
    spread across subprocess replicas by load, one document live-migrates
    through the shared cold tier mid-run, and the router's aggregated
    stats — fleet throughput, latency percentiles, hot-hit rate — print as
    a table. Workers build their own parameters (same seed, bitwise-equal
    weights), so --ckpt does not apply here."""
    from repro.serving.fleet import FleetRouter

    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
    docs = {f"d{i}": [int(t) for t in corpus.document(args.doc_len, i)]
            for i in range(args.docs)}
    rng = np.random.default_rng(2)
    with FleetRouter(args.fleet, arch=args.arch, smoke=args.smoke,
                     max_batch_delay_ms=args.delay_ms) as fleet:
        print(f"booted {args.fleet} replica workers "
              f"(shared cold tier: {fleet.cold_dir})")
        for t in [fleet.open_document(d, toks) for d, toks in docs.items()]:
            t.result(600)
        placement = {d: fleet.owner_of(d) for d in docs}
        print("placement: " + "  ".join(
            f"{d}->r{r}" for d, r in sorted(placement.items())))
        for i in range(args.edits):
            did = f"d{int(rng.integers(args.docs))}"
            if i == args.edits // 2 and args.fleet > 1:
                dst = (fleet.owner_of(did) + 1) % args.fleet
                fleet.migrate(did, dst)
                print(f"edit {i:3d}: migrated {did} -> r{dst} "
                      "(bit-exact, via the shared cold tier)")
            toks = fleet.tokens(did).result(600)
            pos = int(rng.integers(len(toks)))
            fleet.submit_replace(did, pos,
                                 int(rng.integers(cfg.vocab))).result(600)
        sugg = fleet.suggest(did, 8).result(600)
        print(f"last suggestion for {did}: {[int(x) for x in sugg[:4]]}...")
        agg = fleet.stats(600)
        print("\nfleet totals:")
        rows = [("replicas alive", agg["replicas_alive"]),
                ("documents open", agg["docs_open"]),
                ("edits applied", agg["edits_applied"]),
                ("rounds (deadline)",
                 f"{agg['rounds']} ({agg['deadline_rounds']})"),
                ("migrations", agg["router"]["migrations"]),
                ("hot-hit rate", f"{agg['hot_hit_rate']:.2f}"),
                ("edit p50/p99 ms",
                 f"{agg['edit_latency']['p50_ms']:.1f} / "
                 f"{agg['edit_latency']['p99_ms']:.1f}"),
                ("suggest p50/p99 ms",
                 f"{agg['suggest_latency']['p50_ms']:.1f} / "
                 f"{agg['suggest_latency']['p99_ms']:.1f}")]
        for s in agg["per_replica"]:
            rows.append((f"{s['replica']} edits/docs",
                         f"{s['batch']['edits_applied']}/{s['docs_open']}"))
        width = max(len(k) for k, _ in rows)
        for k, v in rows:
            print(f"  {k:<{width}}  {v}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vq-opt-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--doc-len", type=int, default=128)
    ap.add_argument("--edits", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--tiered", action="store_true",
                    help="multi-session fleet under a device-memory budget")
    ap.add_argument("--docs", type=int, default=8,
                    help="(--tiered) sessions to open")
    ap.add_argument("--budget-docs", type=int, default=3,
                    help="(--tiered) device budget, in resident documents")
    ap.add_argument("--async-fleet", action="store_true",
                    help="concurrent sessions via the deadline-batching "
                         "async front end")
    ap.add_argument("--delay-ms", type=float, default=8.0,
                    help="(--async-fleet/--fleet) max_batch_delay_ms "
                         "dispatch deadline")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve through N subprocess replica workers behind "
                         "the document router (ISSUE 10)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    assert cfg.vqt is not None, "serve demo requires a VQT config (e.g. vq-opt-125m)"
    if args.fleet:
        run_fleet(args, cfg)  # replicas own their params (same seed)
        return
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import restore_pytree

        params = restore_pytree(args.ckpt, params)
    if args.tiered:
        run_tiered(args, cfg, params)
    elif args.async_fleet:
        run_async_fleet(args, cfg, params)
    else:
        run_single(args, cfg, params)


if __name__ == "__main__":
    main()
