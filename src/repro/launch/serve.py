"""Serving launcher — the incremental writing-assistant loop.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch vq-opt-125m --smoke \
      --doc-len 128 --edits 20
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.edits import random_atomic_edit
from repro.data import SyntheticCorpus
from repro.models import transformer as T
from repro.serving.engine import IncrementalServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vq-opt-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--doc-len", type=int, default=128)
    ap.add_argument("--edits", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    assert cfg.vqt is not None, "serve demo requires a VQT config (e.g. vq-opt-125m)"
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import restore_pytree

        params = restore_pytree(args.ckpt, params)
    server = IncrementalServer(jax.device_get(params), cfg)

    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
    doc = list(corpus.document(args.doc_len, 0))
    server.open_document("doc", doc)
    print(f"opened {len(doc)}-token document; streaming {args.edits} atomic edits")

    rng = np.random.default_rng(0)
    tokens = doc
    for i in range(args.edits):
        e = random_atomic_edit(rng, tokens, cfg.vocab)
        ops = server.apply_edit("doc", e)
        from repro.core.edits import apply_edit

        tokens = apply_edit(tokens, e)
        dense = server._dense_ops(len(tokens))
        print(f"edit {i:3d} {e.op:8s}@{e.pos:4d} ops={ops:>14,} "
              f"(from-scratch {dense:>14,} -> {dense/max(ops,1):6.1f}X)")
    s = server.stats
    print(f"\ntotals: edits={s.edits} defrags={s.defrags} "
          f"cumulative speedup={s.speedup:.1f}X")


if __name__ == "__main__":
    main()
