"""Parameter / batch / cache sharding rules for the production mesh.

Rules are name-based (matching the parameter dict keys used by the model
modules) and rank-aware: stage parameters carry a leading ``repeat`` axis
from the scan stacking, so the *core* spec for the trailing dims is padded
with ``None`` on the left.

Baseline policy (§Roofline baselines; hillclimbed in EXPERIMENTS.md §Perf):
  * tensor parallelism on ``model``: attention heads / FFN hidden / vocab /
    MoE experts;
  * data parallelism on ``("pod", "data")`` for batch-bearing tensors;
  * sequence parallelism on ``data`` for batch-1 long-context decode caches;
  * everything small (norms, biases, codebooks, routers) replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from repro.common.pytree import path_entry_name, path_names
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> core spec over the trailing dims (padded left with None to rank)
_CORE_RULES: dict[str, tuple] = {
    # attention / hymba
    "wq": (None, "model"),
    "wk": (None, "model"),
    "wv": (None, "model"),
    "wo": ("model", None),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "bo": (None,),
    "w_xz": (None, "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    # mla
    "w_dq": (None, None),
    "w_uq": (None, "model"),
    "w_dkv": (None, None),
    "w_uk": (None, "model"),
    "w_uv": (None, "model"),
    # rwkv time-mix (square d x d) / channel-mix handled by parent context
    "w_r": (None, "model"),
    "w_g": (None, "model"),
    "w_o": ("model", None),
    "w_dec_a": (None, None),
    "w_dec_b": (None, None),
    # dense ffn
    "w_gate": (None, "model"),
    "w_up": (None, "model"),
    "w_down": ("model", None),
    "b_up": ("model",),
    "b_down": (None,),
    # heads / embeddings
    "lm_head": (None, "model"),
    "proj": (None, None),
    "vis_proj": (None, None),
    "router": (None, None),
}

_REPLICATED = {
    "scale", "bias", "mu", "u", "w0", "gn_scale", "gn_bias", "codebook",
    "w_B", "w_C", "w_dt", "dt_bias", "A_log", "pos", "norm_attn", "norm_ssm",
    "step", "rng",
}


def _spec_for(path: tuple[str, ...], shape: tuple[int, ...]) -> P:
    name = path[-1]
    rank = len(shape)
    if name == "tok":
        # [vocab, d] or audio [cb, vocab, d]: shard the vocab axis
        core = ("model", None) if rank == 2 else (None, "model", None)
        return P(*core)
    if name in _REPLICATED:
        return P(*([None] * rank))
    # MoE expert tensors: rank-4 [repeat, E, d, f] — shard experts
    if name in ("w_gate", "w_up", "w_down") and rank == 4:
        return P(None, "model", None, None)
    if name in ("w_gate", "w_up", "w_down") and rank == 3 and "shared" not in path:
        core = _CORE_RULES[name]
        return P(*([None] * (rank - len(core)) + list(core)))
    # rwkv channel-mix w_v: [d, d_ff] (mixer w_v is [d, d] — same rule works)
    if name in _CORE_RULES:
        core = _CORE_RULES[name]
        if rank < len(core):
            return P(*([None] * rank))
        return P(*([None] * (rank - len(core)) + list(core)))
    if name == "w_k":  # rwkv tm [d,d] / cm [d,d_ff]
        return P(*([None] * (len(shape) - 2) + [None, "model"]))
    if name == "w_v":  # rwkv tm [d,d] -> col shard; cm [d_ff,d] -> row shard
        # disambiguate by parent: cm lives under "ffn"
        if "ffn" in path:
            return P(*([None] * (len(shape) - 2) + ["model", None]))
        return P(*([None] * (len(shape) - 2) + [None, "model"]))
    return P(*([None] * rank))


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        names = tuple(path_entry_name(p) for p in path)
        yield names, leaf


def _divisible(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim —
    jit in_shardings (unlike sharding constraints) require exact divisibility
    (e.g. hymba's vocab 32001, phi's 24 heads on a 16-way model axis)."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def param_shardings(tree, mesh: Mesh):
    """NamedSharding pytree matching ``tree`` (params / TrainState / opt)."""

    def one(path, leaf):
        names = tuple(
            path_entry_name(p) for p in path
        )
        shape = jnp.shape(leaf)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _divisible(_spec_for(names, shape), shape, mesh))

    return jax.tree_util.tree_map_with_path(one, tree)


def batch_shardings(batch, mesh: Mesh, *, seq_sharded: bool = False):
    """Training / prefill batches: leading axis on all data axes. With
    ``seq_sharded`` (batch-1 long-context), the sequence axis goes on "data"."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]

    def one(path, leaf):
        shape = jnp.shape(leaf)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if seq_sharded and len(shape) >= 2 and shape[1] % mesh.shape["data"] == 0:
            return NamedSharding(mesh, P(None, "data", *([None] * (len(shape) - 2))))
        if shape[0] % max(n_data, 1) != 0:  # e.g. batch-1 long-context decode
            return NamedSharding(mesh, P(*([None] * len(shape))))
        return NamedSharding(
            mesh, P(data_axes if data_axes else None, *([None] * (len(shape) - 1)))
        )

    return jax.tree_util.tree_map_with_path(one, batch)


def serving_batch_sharding(mesh: Mesh, axis: str = "data") -> NamedSharding:
    """Leading-(document-)axis sharding for the batched serving stack
    (DESIGN.md §6): usable as a jit/shard_map pytree *prefix*, so one value
    covers every leaf of a ``BatchedJitState`` / edit-bucket / ``KVExport``
    pytree — dim 0 (the batch of documents) splits across ``axis``, all
    trailing dims replicate. ``BatchedJitEngine._sharded`` builds every
    sharded dispatch spec from this; the scheduler guarantees divisibility
    by padding dispatch batches to a multiple of the mesh axis size."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no axis {axis!r}")
    return NamedSharding(mesh, P(axis))


def cache_shardings(caches, mesh: Mesh, *, batch: int):
    """Decode caches. Layout (after the stage-stacking leading axis):
    k/v [r, b, S, Hkv, dh]; mla ckv [r, b, S, c]; ssm [r, b, H, dk, dv];
    'len' [r, b]. Batch >= data size -> shard batch; else shard the sequence
    axis on "data" (long-context batch-1 decode)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    shard_batch = batch % max(n_data, 1) == 0 and batch >= n_data

    def one(path, leaf):
        names = tuple(path_entry_name(p) for p in path)
        shape = jnp.shape(leaf)
        rank = len(shape)
        name = names[-1]
        if rank <= 1:
            return NamedSharding(mesh, P())
        if name == "len":
            return NamedSharding(
                mesh, _divisible(P(None, data_axes if shard_batch else None), shape, mesh)
            )
        b_spec = data_axes if shard_batch else None
        s_spec = None if shard_batch else ("data" if "data" in mesh.axis_names else None)
        if name in ("k", "v"):  # [r, b, S, Hkv, dh]
            spec = P(None, b_spec, s_spec, "model", None)
        elif name in ("ckv", "krope"):  # [r, b, S, c]
            spec = P(None, b_spec, s_spec, None)
        elif name == "ssm_state":  # [r, b, H, dk, dv]
            spec = P(None, b_spec, "model", None, None)
        elif name == "conv_state":  # [r, b, K-1, d_inner]
            spec = P(None, b_spec, None, "model")
        elif name == "S":  # rwkv [r, b, H, dh, dh]
            spec = P(None, b_spec, "model", None, None)
        elif name in ("x_last", "cm_x_last"):  # [r, b, d]
            spec = P(None, b_spec, None)
        else:
            spec = P(*([None] * rank))
        return NamedSharding(mesh, _divisible(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(one, caches)
