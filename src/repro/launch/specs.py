"""ShapeDtypeStruct input stand-ins for every (architecture × input shape).

No device allocation — everything here is shape metadata for
``jax.jit(...).lower()``. The modality carve-out (audio / VLM frontends) is
implemented here: ``input_specs`` provides precomputed patch/frame embeddings
of the right shape for the stubbed encoders.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}

# archs allowed to run long_500k (sub-quadratic decode; DESIGN.md §4)
LONG_CONTEXT_OK = {"gemma3-12b", "h2o-danube-1.8b", "hymba-1.5b", "rwkv6-7b"}


def shape_supported(cfg: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: long_500k requires sub-quadratic decode"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    """Model inputs for forward/train at this shape (decode handled by
    ``decode_input_specs`` since it also needs caches)."""
    b, n = shape.global_batch, shape.seq_len
    out: dict = {}
    if cfg.input_mode == "vlm":
        n_text = n - cfg.n_patches
        out["tokens"] = sds((b, n_text), jnp.int32)
        out["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    elif cfg.n_codebooks > 1:
        out["tokens"] = sds((b, n, cfg.n_codebooks), jnp.int32)
    else:
        out["tokens"] = sds((b, n), jnp.int32)
    if cfg.pos in ("learned", "sampled"):
        out["positions"] = sds(out["tokens"].shape[:2], jnp.int32)
    return out


def decode_token_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    b = shape.global_batch
    if cfg.n_codebooks > 1:
        tok = sds((b, 1, cfg.n_codebooks), jnp.int32)
    else:
        tok = sds((b, 1), jnp.int32)
    return {"tokens": tok, "positions": sds((b, 1), jnp.int32)}
