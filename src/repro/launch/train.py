"""Training launcher.

CPU smoke run:
  PYTHONPATH=src python -m repro.launch.train --arch vq-opt-125m --smoke \
      --steps 50 --batch 8 --seq 128

On a real TPU slice the same entry point runs the production mesh
(``--mesh pod|single``) with the sharding rules of ``launch.sharding``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_train_state
from repro.configs import get_config
from repro.data import SyntheticCorpus, lm_batches
from repro.distributed.context import use_mesh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding import batch_shardings, param_shardings
from repro.training import make_schedule, make_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--vqt", action="store_true", help="enable the paper's VQT feature")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mesh", choices=["host", "single", "pod"], default="host")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    kwargs = {"vqt": True} if args.vqt else {}
    cfg = get_config(args.arch, smoke=args.smoke, **kwargs)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} vocab={cfg.vocab}")

    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "pod")

    sched = make_schedule(peak_lr=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps, final_lr=args.lr / 10)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)

    with use_mesh(mesh):
        state = train_state_init(jax.random.PRNGKey(0), cfg)
        state_sh = param_shardings(state, mesh)
        state = jax.device_put(state, state_sh)
        step_fn = jax.jit(
            make_train_step(cfg, sched, accum_steps=args.accum),
            in_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        t0 = time.time()
        for i, batch in enumerate(
            lm_batches(corpus, batch=args.batch, seq_len=args.seq, steps=args.steps,
                       pos_pool=cfg.pos_pool if cfg.pos == "sampled" else None)
        ):
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            state, m = step_fn(state, b)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(
                    f"step {i:5d} loss={float(m['lm_loss']):.4f} "
                    f"aux={float(m['aux_loss']):.4f} gnorm={float(m['grad_norm']):.3f} "
                    f"lr={float(m['lr']):.2e} ({time.time()-t0:.1f}s)",
                    flush=True,
                )
    if args.ckpt:
        save_train_state(args.ckpt, jax.device_get(state), step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
