"""GQA attention: RoPE, sliding windows, softmax or the paper's element-wise
σ attention (eq. 1), and the VQT vector-quantization hook on the concatenated
head outputs (before the mixing projection, per paper §3).

σ-attention normalization: with an element-wise non-linearity the row sums are
unbounded in sequence length, so we normalize each output row by the number of
attended positions. This keeps magnitudes seq-length-stable and remains
incrementally patchable (a pure per-location rescale; see
``repro.core.incremental``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerCfg
from repro.core import vq as vq_mod
from repro.distributed.context import constrain


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [b, n, h, dh]; positions: [b, n] int."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [b, n, dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def attn_init(key: jax.Array, cfg: ArchConfig, layer: LayerCfg, dtype=jnp.float32) -> dict:
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, H * dh)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Hkv * dh)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Hkv * dh)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * dh, d)) * (H * dh) ** -0.5).astype(dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((Hkv * dh,), dtype)
        p["bv"] = jnp.zeros((Hkv * dh,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    if cfg.vqt is not None:
        p["vq"] = vq_mod.init(ks[4], H * dh, cfg.vqt, dtype=jnp.float32)
    return p


def _qkv(params: dict, cfg: ArchConfig, x: jax.Array):
    b, n, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (
        q.reshape(b, n, H, dh),
        k.reshape(b, n, Hkv, dh),
        v.reshape(b, n, Hkv, dh),
    )


def sigma_attn_weights(scores: jax.Array, mask: jax.Array) -> jax.Array:
    """Paper eq. 1: element-wise GELU instead of softmax, masked entries 0,
    rows normalized by their attended count."""
    w = jax.nn.gelu(scores, approximate=True) * mask
    counts = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1.0)
    return w / counts


def make_mask(
    n_q: int,
    n_k: int,
    *,
    causal: bool,
    window: Optional[int],
    q_offset=0,
    valid_k: Optional[jax.Array] = None,
    dtype=jnp.float32,
) -> jax.Array:
    """[1, 1, n_q, n_k] {0,1} mask. q_offset: absolute index of first query
    (decode: n_q=1, q_offset=cache_len)."""
    qi = jnp.arange(n_q) + q_offset  # absolute query order indices
    ki = jnp.arange(n_k)
    m = jnp.ones((n_q, n_k), bool)
    if causal:
        m &= ki[None, :] <= qi[:, None]
    if window is not None:
        m &= ki[None, :] > (qi[:, None] - window)
    m = m[None, None].astype(dtype)
    if valid_k is not None:  # [b, n_k] validity (padding / ring cache)
        m = m * valid_k[:, None, None, :].astype(dtype)
    return m


# sequences longer than this use the streaming (flash-style) path; kept as a
# module attribute so tests can force either path and compare.
STREAM_THRESHOLD = 2048

# dispatch σ-attention to the Pallas kernel (repro.kernels.gated_attention).
# Default off on CPU (interpret mode is slow); a TPU deployment flips this on.
USE_PALLAS_SIGMA = False


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softmax: bool = True,
    valid_k: Optional[jax.Array] = None,
) -> jax.Array:
    """Self-attention over a full sequence. Dispatches to the Pallas σ kernel
    (VQT fast path), else the streaming KV-block path for long sequences
    (memory: no [n, n] score tensor), else the dense core."""
    n = q.shape[1]
    if (USE_PALLAS_SIGMA and not softmax and causal and window is None
            and valid_k is None):
        from repro.kernels.gated_attention import gated_attention

        return gated_attention(q, k, v)
    if n > STREAM_THRESHOLD and valid_k is None:
        from repro.models.flash import streaming_attention

        return streaming_attention(
            q, k, v, causal=causal, window=window, softmax=softmax
        )
    mask = make_mask(n, k.shape[1], causal=causal, window=window, valid_k=valid_k)
    return attention_core(q, k, v, mask, softmax=softmax)


def attention_core(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array,
    *,
    softmax: bool,
) -> jax.Array:
    """q: [b, nq, H, dh]; k, v: [b, nk, Hkv, dh]; mask [b|1, 1, nq, nk]."""
    b, nq, H, dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    scale = dh ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                        preferred_element_type=jnp.float32) * scale
    if softmax:
        scores = jnp.where(mask > 0, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
    else:
        w = sigma_attn_weights(scores, mask)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), vr)
    return out.reshape(b, nq, H * dh)


def constrain_qkv(cfg: ArchConfig, q, k, v):
    """Head-shard Q/K/V on the model axis when the head count divides it;
    otherwise fall back to *query-sequence* sharding on the model axis
    (context parallelism) — head sharding with non-divisible counts silently
    replicates the whole attention computation (§Perf iteration 3)."""
    from repro.distributed.context import get_ctx

    ctx = get_ctx()
    M = ctx.mesh.shape.get("model", 1) if ctx else 1
    if cfg.n_heads % max(M, 1) == 0:
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)
    else:
        q = constrain(q, "batch", "seq_model", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    return q, k, v


def attn_apply(
    params: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    train: bool = False,
    vq_rng: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Full (train / prefill) attention. Returns (out [b,n,d], vq_aux_loss)."""
    b, n, _ = x.shape
    q, k, v = _qkv(params, cfg, x)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q, k, v = constrain_qkv(cfg, q, k, v)
    o = full_attention(q, k, v, causal=True, window=layer.window, softmax=cfg.attn_softmax)
    o = constrain(o, "batch", None, "model")
    aux = jnp.zeros((), jnp.float32)
    if "vq" in params:
        if train:
            o, _, aux = vq_mod.forward_train(params["vq"], o, cfg.vqt, rng=vq_rng)
        else:
            o, _ = vq_mod.quantize(params["vq"], o)
    o = o @ params["wo"]
    if "bo" in params:
        o = o + params["bo"]
    return o, aux


def attn_decode_core(
    cfg: ArchConfig,
    layer: LayerCfg,
    q: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    cache: dict,
) -> tuple[jax.Array, dict]:
    """Cache update + attention for one decode token (shared by GQA & Hymba).

    q: [b,1,H,dh]; k_new/v_new: [b,1,Hkv,dh].
    cache: {"k": [b, S, Hkv, dh], "v": [b, S, Hkv, dh], "len": [b] int32}.
    For windowed layers S == window and writes wrap (ring buffer).
    Returns (out [b, 1, H*dh], new_cache).
    """
    S = cache["k"].shape[1]
    cache_len = cache["len"]  # [b]
    if layer.window is not None:
        slot = cache_len % S  # ring buffer
    else:
        slot = jnp.minimum(cache_len, S - 1)
    k = jax.vmap(lambda c, kn, s: jax.lax.dynamic_update_slice(c, kn, (s, 0, 0)))(
        cache["k"], k_new, slot
    )
    v = jax.vmap(lambda c, vn, s: jax.lax.dynamic_update_slice(c, vn, (s, 0, 0)))(
        cache["v"], v_new, slot
    )
    k = constrain(k, "batch", "seq", "model", None)
    v = constrain(v, "batch", "seq", "model", None)
    # Validity: slot j holds a real token if j < len+1 (ring: all valid when
    # len+1 >= S).
    ki = jnp.arange(S)[None, :]
    valid = ki < jnp.minimum(cache_len + 1, S)[:, None]
    mask = valid[:, None, None, :].astype(jnp.float32)
    o = attention_core(q, k, v, mask, softmax=cfg.attn_softmax)
    return o, {"k": k, "v": v, "len": cache_len + 1}


def attn_decode(
    params: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    cache: dict,
    positions: jax.Array,
) -> tuple[jax.Array, dict]:
    """One-token decode step against a KV cache."""
    b, n, _ = x.shape
    assert n == 1, "decode step processes one new token"
    q, k_new, v_new = _qkv(params, cfg, x)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    o, new_cache = attn_decode_core(cfg, layer, q, k_new, v_new, cache)
    if "vq" in params:
        o, _ = vq_mod.quantize(params["vq"], o)
    o = o @ params["wo"]
    if "bo" in params:
        o = o + params["bo"]
    return o, new_cache


def attn_prefill(
    params: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    cache: dict,
    positions: jax.Array,
) -> tuple[jax.Array, dict]:
    """Chunked prefill: append ``m`` tokens to a KV cache in ONE step.

    x: [b, m, d]; positions: [b, m]. The chunk's keys/values are written at
    cache slots ``len .. len+m-1`` and each chunk token attends causally —
    cache slot j is visible to chunk token i iff ``j <= len + i``. With
    ``m == 1`` this is exactly ``attn_decode``'s masking, so a chunked
    prefill followed by one-token decode steps is the same computation as
    feeding every token through ``attn_decode`` (the property the
    suggestion-serving differential tests rely on).

    Requires a full (non-ring) cache: windowed layers keep their
    ring-buffer semantics only under one-token decode. The caller must
    guarantee ``len + m <= S`` (``jax.lax.dynamic_update_slice`` clamps
    out-of-range starts, which would silently corrupt the cache).
    """
    b, m, _ = x.shape
    if layer.window is not None:
        raise ValueError("chunked prefill requires a non-windowed layer "
                         "(ring caches only support one-token decode)")
    q, k_new, v_new = _qkv(params, cfg, x)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    S = cache["k"].shape[1]
    start = cache["len"]  # [b]
    k = jax.vmap(lambda c, kn, s: jax.lax.dynamic_update_slice(c, kn, (s, 0, 0)))(
        cache["k"], k_new.astype(cache["k"].dtype), start
    )
    v = jax.vmap(lambda c, vn, s: jax.lax.dynamic_update_slice(c, vn, (s, 0, 0)))(
        cache["v"], v_new.astype(cache["v"].dtype), start
    )
    k = constrain(k, "batch", "seq", "model", None)
    v = constrain(v, "batch", "seq", "model", None)
    qi = start[:, None] + jnp.arange(m)[None, :]  # [b, m] absolute order index
    ki = jnp.arange(S)
    mask = (ki[None, None, :] <= qi[:, :, None]).astype(jnp.float32)[:, None]
    o = attention_core(q, k, v, mask, softmax=cfg.attn_softmax)
    if "vq" in params:
        o, _ = vq_mod.quantize(params["vq"], o)
    o = o @ params["wo"]
    if "bo" in params:
        o = o + params["bo"]
    return o, {"k": k, "v": v, "len": start + m}


def attn_cache_init(cfg: ArchConfig, layer: LayerCfg, batch: int, seq_len: int,
                    dtype=jnp.bfloat16) -> dict:
    S = min(layer.window, seq_len) if layer.window is not None else seq_len
    Hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, S, Hkv, dh), dtype),
        "v": jnp.zeros((batch, S, Hkv, dh), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
