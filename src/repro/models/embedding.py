"""Token / positional embeddings, including the paper's sampled positions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def embedding_init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype)}
    if cfg.n_codebooks > 1:  # musicgen: one embedding per EnCodec codebook
        p["tok"] = (
            jax.random.normal(ks[0], (cfg.n_codebooks, cfg.vocab, cfg.d_model)) * 0.02
        ).astype(dtype)
    if cfg.pos == "learned":
        p["pos"] = (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model)) * 0.02).astype(dtype)
    elif cfg.pos == "sampled":
        pool = cfg.pos_pool if cfg.pos_pool else cfg.max_seq * 100
        p["pos"] = (jax.random.normal(ks[1], (pool, cfg.d_model)) * 0.02).astype(dtype)
    if cfg.input_mode == "vlm":
        # projector from (stub) vision embeddings to d_model
        p["vis_proj"] = (
            jax.random.normal(ks[2], (cfg.d_model, cfg.d_model)) * cfg.d_model ** -0.5
        ).astype(dtype)
    return p


def embed_tokens(params: dict, cfg: ArchConfig, tokens: jax.Array,
                 positions: jax.Array | None = None) -> jax.Array:
    """tokens: [b, n] (or [b, n, n_codebooks] for audio). positions: [b, n]
    absolute ids (required for 'learned'/'sampled')."""
    if cfg.n_codebooks > 1:
        assert tokens.ndim == 3, "audio tokens must be [b, n, n_codebooks]"
        # params['tok']: [cb, vocab, d]; tokens: [b, n, cb]
        x = sum(
            jnp.take(params["tok"][c], tokens[..., c], axis=0)
            for c in range(cfg.n_codebooks)
        )
    else:
        x = jnp.take(params["tok"], tokens, axis=0)
    if cfg.pos in ("learned", "sampled"):
        assert positions is not None, f"pos={cfg.pos} needs explicit position ids"
        x = x + jnp.take(params["pos"], positions, axis=0)
    return x


def merge_vision(params: dict, patch_embeds: jax.Array, x: jax.Array) -> jax.Array:
    """Prefix (stub) vision patch embeddings to the token stream (VLM)."""
    vis = patch_embeds @ params["vis_proj"]
    return jnp.concatenate([vis.astype(x.dtype), x], axis=1)
