"""Dense feed-forward variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def ffn_init(key: jax.Array, kind: str, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], d, d_ff, dtype),
            "w_up": _dense_init(ks[1], d, d_ff, dtype),
            "w_down": _dense_init(ks[2], d_ff, d, dtype),
        }
    if kind in ("gelu", "relu", "relu2"):
        return {
            "w_up": _dense_init(ks[0], d, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": _dense_init(ks[1], d_ff, d, dtype),
            "b_down": jnp.zeros((d,), dtype),
        }
    raise ValueError(kind)


def ffn_apply(kind: str, params: dict, x: jax.Array) -> jax.Array:
    if kind == "swiglu":
        g = jax.nn.silu(x @ params["w_gate"])
        return (g * (x @ params["w_up"])) @ params["w_down"]
    if kind == "geglu":
        g = jax.nn.gelu(x @ params["w_gate"], approximate=True)
        return (g * (x @ params["w_up"])) @ params["w_down"]
    if kind in ("gelu", "relu", "relu2"):
        h = x @ params["w_up"] + params["b_up"]
        if kind == "gelu":
            h = jax.nn.gelu(h, approximate=True)
        elif kind == "relu":
            h = jax.nn.relu(h)
        else:
            h = jax.nn.relu(h) ** 2
        return h @ params["w_down"] + params["b_down"]
    raise ValueError(kind)
