"""Streaming (flash-style) attention in pure JAX — lax.scan over KV blocks.

Two accumulation modes:

* ``softmax=True`` — online-softmax (running max + denominator), the standard
  flash recurrence;
* ``softmax=False`` — the paper's element-wise σ attention (eq. 1). Because σ
  is applied per score entry, every KV block contributes an *independent*
  partial sum: no running max, no accumulator rescaling. This is the
  TPU-friendly property DESIGN.md §3 records as a beyond-paper win (the
  Pallas kernel ``repro.kernels.gated_attention`` is the MXU version of this
  loop).

Each block body is wrapped in ``jax.checkpoint`` so the backward pass
recomputes block scores instead of storing [b, H, n_q, n_k] — this is what
makes the 4k-train and 32k-prefill shapes fit in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# §Perf iteration toggle: when False, every q block scans ALL kv blocks
# (the paper-faithful / pre-optimization baseline for the roofline A/B).
SKIP_MASKED_BLOCKS = True


def _block_mask(
    q_idx: jax.Array,  # [nq] absolute query order indices
    k_start: int | jax.Array,
    kv_block: int,
    n_k: int,
    *,
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    """{0,1} mask [nq, kv_block] for one KV block starting at ``k_start``."""
    ki = k_start + jnp.arange(kv_block)
    m = (ki < n_k)[None, :]
    if causal:
        m = m & (ki[None, :] <= q_idx[:, None])
    if window is not None:
        m = m & (ki[None, :] > (q_idx[:, None] - window))
    return m


def streaming_attention(
    q: jax.Array,  # [b, nq, H, dqk]
    k: jax.Array,  # [b, nk, Hkv, dqk]
    v: jax.Array,  # [b, nk, Hkv, dv]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    softmax: bool = True,
    kv_block: int = 1024,
    q_block: int = 1024,
    remat: bool = True,
) -> jax.Array:
    """Returns [b, nq, H*dv] (f32 accumulation, cast to v.dtype).

    Queries are processed in static blocks and each q block scans only the
    KV blocks its causal/sliding-window mask can reach (§Perf iteration 2):
    fully-masked (q, kv) block pairs are skipped at *trace* time, so the
    causal lower triangle costs ~half and a window w touches only
    ~(w + q_block)/kv_block blocks per q block.
    """
    b, nq_all, H, dqk = q.shape
    # bound the static unroll: <=16 kv blocks per q block, <=8 q blocks
    kv_block = max(kv_block, -(-k.shape[1] // 16))
    q_block = max(q_block, -(-nq_all // 8))
    if nq_all > q_block:
        outs = []
        for qs in range(0, nq_all, q_block):
            qe = min(qs + q_block, nq_all)
            outs.append(
                streaming_attention(
                    q[:, qs:qe], k, v, causal=causal, window=window,
                    q_offset=q_offset + qs, softmax=softmax,
                    kv_block=kv_block, q_block=q_block, remat=remat,
                )
            )
        return jnp.concatenate(outs, axis=1)

    nq = nq_all
    nk = k.shape[1]
    Hkv = k.shape[2]
    dv = v.shape[-1]
    rep = H // Hkv
    scale = dqk ** -0.5
    q_idx = q_offset + jnp.arange(nq)

    kv_block = min(kv_block, nk)
    pad = (-nk) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk_all = (nk + pad) // kv_block
    # static reachability: this q block sees keys in (q_offset - window, q_offset + nq)
    lo_blk = 0
    hi_blk = nblk_all
    if SKIP_MASKED_BLOCKS:
        if window is not None:
            lo_blk = max(0, (q_offset - window + 1) // kv_block)
        if causal:
            hi_blk = min(nblk_all, (q_offset + nq - 1) // kv_block + 1)
    nblk = max(hi_blk - lo_blk, 1)
    kb = k.reshape(b, nblk_all, kv_block, Hkv, dqk)[:, lo_blk:lo_blk + nblk]
    vb = v.reshape(b, nblk_all, kv_block, Hkv, dv)[:, lo_blk:lo_blk + nblk]

    # dots take bf16 operands with f32 accumulation (MXU-native); casting
    # inputs to f32 first doubles the score-tensor traffic for nothing
    # (§Perf iteration 1 — measured in EXPERIMENTS.md).
    def block_scores(k_blk, blk_i):
        kr = jnp.repeat(k_blk, rep, axis=2) if rep > 1 else k_blk  # [b,blk,H,dqk]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask(q_idx, blk_i * kv_block, kv_block, nk,
                           causal=causal, window=window)
        return s, mask

    # NOTE: the KV loop is a *Python* loop (statically unrolled), not a
    # lax.scan: (i) on TPU this loop is the Pallas grid; (ii) XLA cost
    # analysis counts a scan body once regardless of trip count, which would
    # hide the attention cost from the §Roofline terms (verified); (iii) the
    # block counts are bounded by the adaptive block sizes chosen in
    # full_attention. Each block body is checkpointed so backward recomputes
    # its scores instead of storing them.
    if softmax:

        def body(carry, k_blk, v_blk, blk_i):
            o, m_run, l_run = carry
            s, mask = block_scores(k_blk, blk_i)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            vr = jnp.repeat(v_blk, rep, axis=2) if rep > 1 else v_blk
            # PV in input precision with f32 accumulation (flash-standard)
            o = o * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vr.dtype), vr,
                preferred_element_type=jnp.float32,
            )
            l_run = l_run * alpha + p.sum(-1)
            return o, m_new, l_run

        carry = (
            jnp.zeros((b, H, nq, dv), jnp.float32),
            jnp.full((b, H, nq), NEG_INF, jnp.float32),
            jnp.zeros((b, H, nq), jnp.float32),
        )
        fn = jax.checkpoint(body) if remat else body
        for j in range(nblk):
            carry = fn(carry, kb[:, j], vb[:, j], lo_blk + j)
        o, _, l = carry
        o = o / jnp.maximum(l[..., None], 1e-9)
    else:
        # σ attention: independent partial sums — no rescaling pass at all.
        def body(carry, k_blk, v_blk, blk_i):
            o, cnt = carry
            s, mask = block_scores(k_blk, blk_i)
            w = jax.nn.gelu(s, approximate=True) * mask[None, None]
            vr = jnp.repeat(v_blk, rep, axis=2) if rep > 1 else v_blk
            o = o + jnp.einsum("bhqk,bkhd->bhqd", w.astype(vr.dtype), vr,
                               preferred_element_type=jnp.float32)
            cnt = cnt + mask.sum(-1).astype(jnp.float32)
            return o, cnt

        carry = (
            jnp.zeros((b, H, nq, dv), jnp.float32),
            jnp.zeros((nq,), jnp.float32),
        )
        fn = jax.checkpoint(body) if remat else body
        for j in range(nblk):
            carry = fn(carry, kb[:, j], vb[:, j], lo_blk + j)
        o, cnt = carry
        o = o / jnp.maximum(cnt, 1.0)[None, None, :, None]

    out = jnp.moveaxis(o, 1, 2).reshape(b, nq, H * dv)
    return out.astype(v.dtype)
