"""Hymba hybrid-head mixer (arXiv:2411.13676).

Each Hymba block runs *parallel* attention heads and Mamba-2 (SSD) heads over
the same input and fuses their (independently normalized) outputs:

    out = W_o ( mean( norm(attn(x)), norm(ssm(x)) ) )

The attention branch is standard GQA (optionally sliding-window); the SSM
branch is a Mamba-2 style selective recurrence with a scalar-per-head decay,
evaluated with the shared chunked linear-recurrence core
(``repro.models.linear_scan`` with ``mamba_style=True``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerCfg
from repro.core import vq as vq_mod
from repro.distributed.context import constrain
from repro.models.attention import apply_rope, attn_cache_init, full_attention
from repro.models.norms import rmsnorm, rmsnorm_init


def hymba_init(key: jax.Array, cfg: ArchConfig, layer: LayerCfg, dtype=jnp.float32) -> dict:
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    s = cfg.ssm
    d_inner = H * dh  # ssm branch width matches the attention branch
    ks = jax.random.split(key, 12)
    sc = d ** -0.5
    p = {
        # attention branch
        "wq": (jax.random.normal(ks[0], (d, H * dh)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Hkv * dh)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Hkv * dh)) * sc).astype(dtype),
        # ssm branch (mamba2-lite): input/gate proj, conv, B/C/dt projections
        "w_xz": (jax.random.normal(ks[3], (d, 2 * d_inner)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[4], (s.d_conv, d_inner)) * 0.3).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_B": (jax.random.normal(ks[5], (d, s.d_state)) * sc).astype(dtype),
        "w_C": (jax.random.normal(ks[6], (d, s.d_state)) * sc).astype(dtype),
        "w_dt": (jax.random.normal(ks[7], (d, H)) * sc).astype(dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32) / 4.0 + 0.5),
        # per-branch output norms + fusion
        "norm_attn": rmsnorm_init(H * dh, dtype),
        "norm_ssm": rmsnorm_init(d_inner, dtype),
        "wo": (jax.random.normal(ks[8], (H * dh, d)) * (H * dh) ** -0.5).astype(dtype),
    }
    if cfg.vqt is not None:
        p["vq"] = vq_mod.init(ks[9], H * dh, cfg.vqt, dtype=jnp.float32)
    return p


def _ssm_qkv(params: dict, cfg: ArchConfig, xc: jax.Array, x_raw: jax.Array):
    """From the conv'd ssm stream ``xc`` [b,n,d_inner] and the raw block input
    ``x_raw`` [b,n,d], build the linear-recurrence operands."""
    H = cfg.n_heads
    b, n, d_inner = xc.shape
    dh = d_inner // H
    ds = cfg.ssm.d_state
    Bm = x_raw @ params["w_B"]  # [b, n, ds] shared across heads
    Cm = x_raw @ params["w_C"]  # [b, n, ds]
    dt = jax.nn.softplus(
        x_raw.astype(jnp.float32) @ params["w_dt"].astype(jnp.float32)
        + params["dt_bias"]
    )  # [b, n, H]
    A = jnp.exp(params["A_log"])  # [H] positive
    logw = -(dt * A[None, None, :])  # [b, n, H] log decay (scalar per head)
    # -> [b, h, n, *]
    q = jnp.broadcast_to(Cm[:, None], (b, H, n, ds))
    k = jnp.broadcast_to(Bm[:, None], (b, H, n, ds)) * jnp.moveaxis(dt, -1, 1)[..., None]
    v = jnp.moveaxis(xc.reshape(b, n, H, dh), 2, 1)  # [b, H, n, dh]
    logw_b = jnp.broadcast_to(jnp.moveaxis(logw, -1, 1)[..., None], (b, H, n, ds))
    return q, k, v, logw_b


def _causal_conv(params: dict, xc: jax.Array, conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv over time. xc: [b, n, d_inner]. conv_state:
    [b, d_conv-1, d_inner] trailing inputs from the previous call (decode)."""
    w = params["conv_w"]  # [d_conv, d_inner]
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xc.shape[0], K - 1, xc.shape[2]), xc.dtype)
    else:
        pad = conv_state.astype(xc.dtype)
    xp = jnp.concatenate([pad, xc], axis=1)  # [b, n+K-1, d_inner]
    out = sum(xp[:, i : i + xc.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1) :, :] if K > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out + params["conv_b"]), new_state


def hymba_apply(
    params: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    train: bool = False,
    vq_rng: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Full (train / prefill) hybrid mixer. Returns (out [b,n,d], vq_aux)."""
    from repro.models.linear_scan import lin_attn_chunked

    b, n, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    # --- attention branch ---
    q = (x @ params["wq"]).reshape(b, n, H, dh)
    k = (x @ params["wk"]).reshape(b, n, Hkv, dh)
    v = (x @ params["wv"]).reshape(b, n, Hkv, dh)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    from repro.models.attention import constrain_qkv

    q, k, v = constrain_qkv(cfg, q, k, v)
    attn_out = full_attention(
        q, k, v, causal=True, window=layer.window, softmax=cfg.attn_softmax
    )  # [b,n,H*dh]
    attn_out = constrain(attn_out, "batch", None, "model")
    # --- ssm branch ---
    xz = x @ params["w_xz"]
    xs, z = jnp.split(xz, 2, axis=-1)  # each [b, n, d_inner]
    xc, _ = _causal_conv(params, xs)
    qs, ks, vs, logw = _ssm_qkv(params, cfg, xc, x)
    pad_to = -n % 16
    if pad_to:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad_to), (0, 0)))
        qs, ks, vs, logw = padf(qs), padf(ks), padf(vs), padf(logw)
    y, _ = lin_attn_chunked(qs, ks, vs, logw, mamba_style=True)
    y = y[:, :, :n]  # [b, H, n, dh]
    ssm_out = jnp.moveaxis(y, 1, 2).reshape(b, n, H * dh).astype(x.dtype)
    ssm_out = ssm_out * jax.nn.silu(z)
    # --- fuse ---
    fused = 0.5 * (
        rmsnorm(params["norm_attn"], attn_out) + rmsnorm(params["norm_ssm"], ssm_out)
    )
    aux = jnp.zeros((), jnp.float32)
    if "vq" in params:
        if train:
            fused, _, aux = vq_mod.forward_train(params["vq"], fused, cfg.vqt, rng=vq_rng)
        else:
            fused, _ = vq_mod.quantize(params["vq"], fused)
    return fused @ params["wo"], aux


def hymba_decode(
    params: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    cache: dict,
    positions: jax.Array,
) -> tuple[jax.Array, dict]:
    """One-token decode. cache: {"attn": attn-kv-cache, "ssm_state":
    [b,H,ds,dh], "conv_state": [b,d_conv-1,d_inner]}."""
    from repro.models.attention import attn_decode_core
    from repro.models.linear_scan import lin_attn_decode_step

    b, n, _ = x.shape
    assert n == 1
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    # attention branch against kv cache
    q = (x @ params["wq"]).reshape(b, 1, H, dh)
    k_new = (x @ params["wk"]).reshape(b, 1, Hkv, dh)
    v_new = (x @ params["wv"]).reshape(b, 1, Hkv, dh)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    attn_out, attn_cache = attn_decode_core(
        cfg, layer, q, k_new, v_new, cache["attn"]
    )
    # ssm branch: single-step conv + recurrence
    xz = x @ params["w_xz"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(params, xs, conv_state=cache["conv_state"])
    qs, ks, vs, logw = _ssm_qkv(params, cfg, xc, x)
    y, S = lin_attn_decode_step(
        qs[:, :, 0], ks[:, :, 0], vs[:, :, 0], logw[:, :, 0],
        cache["ssm_state"], mamba_style=True,
    )
    ssm_out = y.reshape(b, 1, H * dh).astype(x.dtype) * jax.nn.silu(z)
    fused = 0.5 * (
        rmsnorm(params["norm_attn"], attn_out) + rmsnorm(params["norm_ssm"], ssm_out)
    )
    if "vq" in params:
        fused, _ = vq_mod.quantize(params["vq"], fused)
    return fused @ params["wo"], {
        "attn": attn_cache,
        "ssm_state": S,
        "conv_state": conv_state,
    }


def hymba_cache_init(cfg: ArchConfig, layer: LayerCfg, batch: int, seq_len: int,
                     dtype=jnp.bfloat16) -> dict:
    H, dh = cfg.n_heads, cfg.resolved_head_dim
    s = cfg.ssm
    d_inner = H * dh
    return {
        "attn": attn_cache_init(cfg, layer, batch, seq_len, dtype),
        "ssm_state": jnp.zeros((batch, H, s.d_state, dh), jnp.float32),
        "conv_state": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
    }
