"""Shared linear-recurrence core for SSM-family mixers (Mamba2 SSD, RWKV6).

Recurrence (per batch & head, state S ∈ R^{dk×dv}):

    S_t = diag(λ_t) S_{t-1} + k_t v_tᵀ
    y_t = (q_t ⊙ d_t) · S_{t-1} + (q_t ⊙ u ⊙ k_t) · v_t

with per-channel decay λ_t = exp(logw_t) ∈ (0, 1]. Setting d_t = 1 and a
learned bonus u gives RWKV6's WKV (Finch, arXiv:2404.05892); setting
d_t = λ_t and u = 1 gives Mamba-2's SSD with scalar-per-head decay broadcast
over dk (arXiv:2405.21060 as used by Hymba).

Two implementations:
* ``sequential`` — lax.scan over time. The oracle; O(n) tiny outer products
  (VPU-bound on TPU, used for tests and decode states).
* ``chunked`` — the TPU-native form: O(n/L) chunks of dense matmuls (MXU),
  with in-chunk decays materialized via cumulative log-sums. Per-step log
  decay is clamped to >= ``MIN_LOGW`` so the inverse in-chunk decay
  exp(-W) stays inside f32 range (chunk 16 × 5 = e^80 < f32 max). Decays
  below e^-5 per step are numerically indistinguishable from 0 after a few
  steps, so the clamp is lossless in practice (see DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

MIN_LOGW = -5.0
CHUNK = 16


def _prep(q, k, v, logw, u, mamba_style):
    # shapes: q,k,logw [b,h,n,dk]; v [b,h,n,dv]; u None or [h,dk]
    logw = jnp.clip(logw.astype(jnp.float32), MIN_LOGW, 0.0)
    lam = jnp.exp(logw)
    d = lam if mamba_style else jnp.ones_like(lam)
    if u is None:
        u_eff = jnp.ones((q.shape[1], q.shape[-1]), jnp.float32)
    else:
        u_eff = u.astype(jnp.float32)
    return q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), logw, lam, d, u_eff


def lin_attn_sequential(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: Optional[jax.Array] = None,
    s0: Optional[jax.Array] = None,
    *,
    mamba_style: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [b,h,n,dv], s_final [b,h,dk,dv])."""
    q, k, v, logw, lam, d, u_eff = _prep(q, k, v, logw, u, mamba_style)
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(S, inp):
        qt, kt, vt, lt, dt = inp  # [b,h,dk] etc.
        y = jnp.einsum("bhk,bhkv->bhv", qt * dt, S) + jnp.einsum(
            "bhk,bhv->bhv", qt * u_eff[None] * kt, vt
        )
        S = lt[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (q, k, v, lam, d))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2), s_fin


def lin_attn_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: Optional[jax.Array] = None,
    s0: Optional[jax.Array] = None,
    *,
    mamba_style: bool = False,
    chunk: int = CHUNK,
) -> tuple[jax.Array, jax.Array]:
    """Chunked (matmul-form) evaluation. Same contract as sequential."""
    q, k, v, logw, lam, d, u_eff = _prep(q, k, v, logw, u, mamba_style)
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    L = chunk
    assert n % L == 0, f"seq {n} must be a multiple of chunk {L} (pad upstream)"
    C = n // L
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    # reshape to chunks: [b,h,C,L,*]
    rc = lambda a: a.reshape(b, h, C, L, a.shape[-1])
    qc, kc, vc, lwc, dc = map(rc, (q, k, v, logw, d))
    W = jnp.cumsum(lwc, axis=3)  # inclusive in-chunk cumulative log decay
    Wtot = W[:, :, :, -1:, :]  # [b,h,C,1,dk]
    # decayed views
    q_in = qc * dc * jnp.exp(W - lwc)  # q_t ⊙ d_t ⊙ P_{t-1}/P_{c0}  (P rel. chunk start)
    k_out = kc * jnp.exp(-W)  # k_s ⊙ P_{c0}/P_s
    k_carry = kc * jnp.exp(Wtot - W)  # k_s ⊙ P_end/P_s

    # intra-chunk attention (strictly lower-triangular) + u-diagonal
    A = jnp.einsum("bhcld,bhcmd->bhclm", q_in, k_out)  # l=query, m=key
    tri = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)
    A = A * tri
    diag = jnp.einsum("bhcld,bhcld->bhcl", qc * u_eff[None, :, None, None, :], kc)
    y_intra = jnp.einsum("bhclm,bhcmv->bhclv", A, vc) + diag[..., None] * vc

    # inter-chunk: scan carry over chunk states. The per-chunk state delta
    # (an outer product [dk, dv]) is formed *inside* the scan body so we never
    # materialize the full [b,h,C,dk,dv] tensor.
    lam_tot = jnp.exp(Wtot[:, :, :, 0, :])  # [b,h,C,dk]

    def carry_fn(S, inp):
        lam_c, kcar_c, v_c, q_c = inp  # [b,h,dk], [b,h,L,dk], [b,h,L,dv], [b,h,L,dk]
        y_cross = jnp.einsum("bhld,bhdv->bhlv", q_c, S)
        dS_c = jnp.einsum("bhld,bhlv->bhdv", kcar_c, v_c)
        S_next = lam_c[..., None] * S + dS_c
        return S_next, y_cross

    xs = (
        jnp.moveaxis(lam_tot, 2, 0),
        jnp.moveaxis(k_carry, 2, 0),
        jnp.moveaxis(vc, 2, 0),
        jnp.moveaxis(q_in, 2, 0),
    )
    s_fin, y_cross = jax.lax.scan(carry_fn, s0, xs)
    y = y_intra + jnp.moveaxis(y_cross, 0, 2)
    return y.reshape(b, h, n, dv), s_fin


def lin_attn_decode_step(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    S: jax.Array,
    u: Optional[jax.Array] = None,
    *,
    mamba_style: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Single-token state update. q,k,logw: [b,h,dk]; v: [b,h,dv];
    S: [b,h,dk,dv]. Returns (y [b,h,dv], S')."""
    logw = jnp.clip(logw.astype(jnp.float32), MIN_LOGW, 0.0)
    lam = jnp.exp(logw)
    d = lam if mamba_style else jnp.ones_like(lam)
    if u is None:
        u = jnp.ones((q.shape[1], q.shape[-1]), jnp.float32)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    y = jnp.einsum("bhk,bhkv->bhv", qf * d, S) + jnp.einsum(
        "bhk,bhv->bhv", qf * u[None] * kf, vf
    )
    S = lam[..., None] * S + kf[..., None] * vf[..., None, :]
    return y, S
