"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2405.04434 §2.1).

Train / prefill uses the *naive* (expanded) form; decode uses the *absorbed*
form, caching only the compressed latent ``c_kv`` (kv_lora dims) plus the
shared RoPE key (rope_dim dims) per token — 576 floats/token for V2/V3
instead of 2*H*dh. This is the memory win that makes 32k decode caches cheap
and is exactly how the paper's serving deployments run.

Weights:
  w_dq:  [d, q_lora]         w_uq: [q_lora, H*(nope+rope)]
  w_dkv: [d, kv_lora+rope]   w_uk: [kv_lora, H*nope]   w_uv: [kv_lora, H*v]
  wo:    [H*v, d]
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerCfg
from repro.core import vq as vq_mod
from repro.distributed.context import constrain
from repro.models.attention import apply_rope, make_mask, sigma_attn_weights
from repro.models.norms import rmsnorm, rmsnorm_init


def mla_init(key: jax.Array, cfg: ArchConfig, layer: LayerCfg, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    p = {
        "w_dq": (jax.random.normal(ks[0], (d, m.q_lora)) * s).astype(dtype),
        "w_uq": (
            jax.random.normal(ks[1], (m.q_lora, H * (m.nope_dim + m.rope_dim)))
            * m.q_lora ** -0.5
        ).astype(dtype),
        "w_dkv": (jax.random.normal(ks[2], (d, m.kv_lora + m.rope_dim)) * s).astype(dtype),
        "w_uk": (
            jax.random.normal(ks[3], (m.kv_lora, H * m.nope_dim)) * m.kv_lora ** -0.5
        ).astype(dtype),
        "w_uv": (
            jax.random.normal(ks[4], (m.kv_lora, H * m.v_dim)) * m.kv_lora ** -0.5
        ).astype(dtype),
        "wo": (jax.random.normal(ks[5], (H * m.v_dim, d)) * (H * m.v_dim) ** -0.5).astype(dtype),
        "q_norm": rmsnorm_init(m.q_lora, dtype),
        "kv_norm": rmsnorm_init(m.kv_lora, dtype),
    }
    if cfg.vqt is not None:
        p["vq"] = vq_mod.init(ks[6], H * m.v_dim, cfg.vqt, dtype=jnp.float32)
    return p


def _queries(params: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    b, n, _ = x.shape
    H = cfg.n_heads
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (cq @ params["w_uq"]).reshape(b, n, H, m.nope_dim + m.rope_dim)
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(params: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    ckv_full = x @ params["w_dkv"]  # [b, n, kv_lora + rope]
    c_kv = rmsnorm(params["kv_norm"], ckv_full[..., : m.kv_lora])
    k_rope = ckv_full[..., None, m.kv_lora :]  # [b, n, 1, rope] shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_apply(
    params: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    train: bool = False,
    vq_rng: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Naive (expanded) MLA for train/prefill."""
    m = cfg.mla
    b, n, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latent(params, cfg, x, positions)
    k_nope = (c_kv @ params["w_uk"]).reshape(b, n, H, m.nope_dim)
    v = (c_kv @ params["w_uv"]).reshape(b, n, H, m.v_dim)
    q_nope = constrain(q_nope, "batch", None, "model", None)
    k_nope = constrain(k_nope, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    from repro.models.attention import STREAM_THRESHOLD

    if n > STREAM_THRESHOLD:
        # streaming path: fold the shared RoPE key into a combined head dim
        from repro.models.flash import streaming_attention

        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)  # [b,n,H,nope+rope]
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, n, H, m.rope_dim))], axis=-1
        )
        o = streaming_attention(
            q_cat, k_cat, v, causal=True, window=layer.window,
            softmax=cfg.attn_softmax,
        ).reshape(b, n, H * m.v_dim)
    else:
        scale = (m.nope_dim + m.rope_dim) ** -0.5
        scores = (
            jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bqhd,bkxd->bhqk", q_rope, k_rope,
                         preferred_element_type=jnp.float32)
        ) * scale
        mask = make_mask(n, n, causal=True, window=layer.window)
        if cfg.attn_softmax:
            w = jax.nn.softmax(jnp.where(mask > 0, scores, -1e30), axis=-1)
        else:
            w = sigma_attn_weights(scores, mask)
        o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v).reshape(b, n, H * m.v_dim)
    aux = jnp.zeros((), jnp.float32)
    if "vq" in params:
        if train:
            o, _, aux = vq_mod.forward_train(params["vq"], o, cfg.vqt, rng=vq_rng)
        else:
            o, _ = vq_mod.quantize(params["vq"], o)
    return o @ params["wo"], aux


def mla_decode(
    params: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    cache: dict,
    positions: jax.Array,
) -> tuple[jax.Array, dict]:
    """Absorbed-form decode: attend in the kv_lora latent space.

    cache: {"ckv": [b, S, kv_lora], "krope": [b, S, rope], "len": [b]}.
    Per new token: q̃ = q_nope @ W_uk (absorb), scores = q̃·c_kv + q_rope·k_rope,
    o_latent = w·c_kv, o = (o_latent @ W_uv per head) — W_uv application is a
    per-head matmul done once per step (H*v_dim*kv_lora), not per cached token.
    """
    m = cfg.mla
    b, n, _ = x.shape
    assert n == 1
    H = cfg.n_heads
    q_nope, q_rope = _queries(params, cfg, x, positions)  # [b,1,H,*]
    c_new, krope_new = _latent(params, cfg, x, positions)  # [b,1,kv], [b,1,1,rope]
    S = cache["ckv"].shape[1]
    cache_len = cache["len"]
    slot = jnp.minimum(cache_len, S - 1)
    ckv = jax.vmap(lambda c, nw, s: jax.lax.dynamic_update_slice(c, nw, (s, 0)))(
        cache["ckv"], c_new, slot
    )
    krope = jax.vmap(lambda c, nw, s: jax.lax.dynamic_update_slice(c, nw, (s, 0)))(
        cache["krope"], krope_new[:, :, 0, :], slot
    )
    ckv = constrain(ckv, "batch", "seq", None)
    # Absorb W_uk into the query: q̃ [b,1,H,kv_lora]
    w_uk = params["w_uk"].reshape(m.kv_lora, H, m.nope_dim)  # [c, h, d]
    q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    scores = (
        jnp.einsum("bqhc,bkc->bhqk", q_lat, ckv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope[:, :, :, :], krope,
                     preferred_element_type=jnp.float32)
    ) * scale
    ki = jnp.arange(S)[None, :]
    valid = (ki < jnp.minimum(cache_len + 1, S)[:, None])[:, None, None, :]
    if cfg.attn_softmax:
        w = jax.nn.softmax(jnp.where(valid, scores, -1e30), axis=-1)
    else:
        w = sigma_attn_weights(scores, valid.astype(jnp.float32))
    o_lat = jnp.einsum("bhqk,bkc->bqhc", w.astype(ckv.dtype), ckv)  # [b,1,H,kv]
    w_uv = params["w_uv"].reshape(m.kv_lora, H, m.v_dim)
    o = jnp.einsum("bqhc,chd->bqhd", o_lat, w_uv).reshape(b, n, H * m.v_dim)
    if "vq" in params:
        o, _ = vq_mod.quantize(params["vq"], o)
    return o @ params["wo"], {"ckv": ckv, "krope": krope, "len": cache_len + 1}


def mla_cache_init(cfg: ArchConfig, layer: LayerCfg, batch: int, seq_len: int,
                   dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, seq_len, m.kv_lora), dtype),
        "krope": jnp.zeros((batch, seq_len, m.rope_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
