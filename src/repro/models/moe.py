"""Mixture-of-Experts with expert parallelism (DeepSeek-V2/V3 style).

Two execution paths:

* ``moe_apply_dense`` — reference path (loops over experts, mask-weighted).
  Used on single devices (smoke tests) and as the correctness oracle.
* ``moe_apply_ep`` — shard_map expert parallelism over the ``model`` mesh
  axis. Tokens are split across the model axis (on top of their data-axis
  sharding), routed to expert-owner devices through a fixed-capacity
  ``all_to_all`` (cumsum slotting, no dynamic sort), run through the local
  experts as one grouped einsum, routed back with the inverse ``all_to_all``,
  and combined with the router gates. Shared experts run densely on all
  tokens. Fixed capacity means tokens beyond ``capacity_factor`` are dropped
  (standard for TPU MoE, cf. Switch/GShard/MaxText).

With the paper's VQT feature enabled, the *inputs* to the router are
vector-quantized activations, so identical codes route identically — the
incremental serving engine exploits this to dedup expert compute across
revisions (see DESIGN.md §4, a beyond-paper amplification of the technique).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.context import get_ctx, shard_map_compat as _shard_map
from repro.models.ffn import ffn_apply, ffn_init


def moe_init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e.n_experts)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e.n_experts, d, e.d_ff_expert)) * s).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e.n_experts, d, e.d_ff_expert)) * s).astype(dtype),
        "w_down": (
            jax.random.normal(ks[3], (e.n_experts, e.d_ff_expert, d)) * e.d_ff_expert ** -0.5
        ).astype(dtype),
    }
    if e.n_shared > 0:
        p["shared"] = ffn_init(ks[4], "swiglu", d, e.n_shared * e.d_ff_expert, dtype)
    return p


def _router(params: dict, e, x: jax.Array):
    """x: [T, d] -> (gates [T, k], eidx [T, k], aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, e.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renorm (DeepSeek)
    # Switch-style load-balance loss.
    frac_prob = probs.mean(axis=0)  # [E]
    assign = jax.nn.one_hot(eidx, e.n_experts, dtype=jnp.float32).sum(axis=1)  # [T, E]
    frac_tok = assign.mean(axis=0) / e.top_k
    aux = e.n_experts * jnp.sum(frac_prob * frac_tok) * e.aux_loss_weight
    return gates, eidx, aux


def _expert_ffn(w_gate, w_up, w_down, xs):
    """xs: [E_loc, C, d] grouped tokens; weights [E_loc, ...]."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, w_gate))
    h = g * jnp.einsum("ecd,edf->ecf", xs, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_apply_dense(params: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference: x [b, n, d] -> (y, aux). Loops over all experts."""
    e = cfg.moe
    b, n, d = x.shape
    xt = x.reshape(-1, d)
    gates, eidx, aux = _router(params, e, xt)
    y = jnp.zeros_like(xt)

    def body(i, y):
        w = (eidx == i).astype(x.dtype) * gates.astype(x.dtype)  # [T, k]
        wi = w.sum(-1, keepdims=True)  # [T, 1]
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i, 1, axis=0)
        fe = _expert_ffn(
            sl(params["w_gate"]), sl(params["w_up"]), sl(params["w_down"]), xt[None]
        )[0]
        return y + fe * wi

    y = jax.lax.fori_loop(0, e.n_experts, body, y)
    if "shared" in params:
        y = y + ffn_apply("swiglu", params["shared"], xt)
    return y.reshape(b, n, d), aux


def _ep_capacity(t2: int, e, n_experts: int) -> int:
    cap = int(math.ceil(t2 * e.top_k / n_experts * e.capacity_factor))
    return max(8, cap)


def moe_apply_ep(params: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map. x: [b, n, d] sharded on batch axes."""
    ctx = get_ctx()
    if ctx is None:
        return moe_apply_dense(params, cfg, x)
    mesh = ctx.mesh
    e = cfg.moe
    b, n, d = x.shape
    M = mesh.shape.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    E = e.n_experts
    assert E % M == 0, f"experts {E} must divide model axis {M}"
    E_loc = E // M

    tok_spec = P(data_axes if data_axes else None, None, None)
    # router weights replicated; expert weights sharded over model on axis 0.
    param_specs = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if "shared" in params:
        param_specs["shared"] = {k: P(None, "model") if k != "w_down" else P("model", None)
                                 for k in params["shared"]}

    def local_moe(p, xb):
        # xb: [b_loc, n, d] local tokens (replicated over model axis).
        b_loc = xb.shape[0]
        xt = xb.reshape(-1, d)
        T_loc = xt.shape[0]
        T2 = -(-T_loc // M)  # tokens this model-slice is responsible for
        pad = T2 * M - T_loc
        if pad:
            xt = jnp.concatenate([xt, jnp.zeros((pad, d), xt.dtype)], axis=0)
        midx = jax.lax.axis_index("model")
        x_mine = jax.lax.dynamic_slice_in_dim(xt, midx * T2, T2, axis=0)  # [T2, d]
        gates, eidx, aux = _router(p, e, x_mine)  # [T2, k]
        cap = _ep_capacity(T2, e, E)
        # --- dispatch: slot each assignment into its expert bucket ---
        flat_e = eidx.reshape(-1)  # [T2*k]
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T2*k, E]
        rank = jnp.cumsum(onehot, axis=0) - onehot  # prior count
        pos = jnp.sum(rank * onehot, axis=1)  # [T2*k] position within bucket
        keep = pos < cap
        src = jnp.repeat(jnp.arange(T2), e.top_k)
        buf = jnp.zeros((E, cap, d), xt.dtype)
        buf = buf.at[flat_e, jnp.minimum(pos, cap - 1)].add(
            x_mine[src] * keep[:, None].astype(xt.dtype), mode="drop"
        )
        # --- all_to_all to expert owners: [E, cap, d] -> [M, E_loc, cap, d] ---
        buf = buf.reshape(M, E_loc, cap, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0, tiled=False)
        # recv: [M_src, E_loc, cap, d] -> group per expert
        grouped = jnp.moveaxis(recv, 0, 1).reshape(E_loc, M * cap, d)
        out_g = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], grouped)
        # --- route back ---
        back = jnp.moveaxis(out_g.reshape(E_loc, M, cap, d), 1, 0)  # [M, E_loc, cap, d]
        ret = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0, tiled=False)
        ret = ret.reshape(E, cap, d)  # my tokens' expert outputs
        vals = ret[flat_e, jnp.minimum(pos, cap - 1)] * keep[:, None].astype(xt.dtype)
        w = gates.reshape(-1)[:, None].astype(xt.dtype)
        y_mine = jnp.zeros((T2, d), xt.dtype).at[src].add(vals * w)
        # --- reassemble across the model axis ---
        y_all = jax.lax.all_gather(y_mine, "model", axis=0, tiled=True)  # [T2*M, d]
        y = y_all[:T_loc]
        if "shared" in p:
            y = y + ffn_apply("swiglu", p["shared"], xb.reshape(-1, d))
        aux = jax.lax.pmean(aux, "model")
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(b_loc, n, d), aux

    y, aux = _shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(param_specs, tok_spec),
        out_specs=(tok_spec, P()),
    )({k: params[k] for k in param_specs}, x)
    return y, aux


def moe_apply(params: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    return moe_apply_ep(params, cfg, x)


def moe_per_code(params: dict, cfg: ArchConfig, c) -> tuple:
    """MoE over a *compressed* activation tensor (DESIGN.md §4, the
    beyond-paper amplification): identical VQ codes route identically, so
    routing + expert FFN run once per unique codebook row — O(q) instead of
    O(b·n) expert compute across a batch of revisions.

    c: repro.core.compressed.Compressed. Returns (Compressed y, aux)."""
    from repro.core.compressed import Compressed

    y_rows, aux = moe_apply_dense(params, cfg, c.codebook[None])
    return Compressed(y_rows[0], c.idx, c.n_codes), aux
