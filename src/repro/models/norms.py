"""Normalization layers (pure JAX, params = dicts of arrays)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype)
    if kind == "layernorm":
        return layernorm_init(d, dtype)
    raise ValueError(kind)


def apply_norm(kind: str, params: dict, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(params, x)
    if kind == "layernorm":
        return layernorm(params, x)
    raise ValueError(kind)


def groupnorm(x: jax.Array, n_groups: int, scale: jax.Array, bias: jax.Array,
              eps: float = 64e-5) -> jax.Array:
    """GroupNorm over the last dim (used by RWKV6 on per-head outputs)."""
    dt = x.dtype
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf.reshape(*lead, d) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)
