"""RWKV-6 "Finch" mixer (arXiv:2404.05892): attention-free time-mix with
data-dependent per-channel decay, plus the RWKV channel-mix FFN.

Time-mix (per head, state S ∈ R^{dk×dv}):

    wkv_t = (r_t) · S_{t-1} + (r_t ⊙ u ⊙ k_t) · v_t
    S_t   = diag(λ_t) S_{t-1} + k_t v_tᵀ ,   λ_t = exp(-exp(w_t))

where w_t is produced by a low-rank ("decay LoRA") projection of the
token-shifted input — the data-dependent decay that defines RWKV-6. The
recurrence is the ``mamba_style=False`` case of the shared linear-scan core.

Token shift: every projection sees lerp(x_t, x_{t-1}, μ); decode carries the
previous token's input in the cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerCfg
from repro.models.norms import groupnorm


def _lora(x, w_a, w_b, activation=jnp.tanh):
    return activation(x @ w_a) @ w_b


def rwkv_init(key: jax.Array, cfg: ArchConfig, layer: LayerCfg, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    r = cfg.rwkv
    H = d // r.head_dim
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    p = {
        # token-shift lerp coefficients for r/k/v/w/g streams
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(dtype),
        "w_r": (jax.random.normal(ks[1], (d, d)) * s).astype(dtype),
        "w_k": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "w_v": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "w_g": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        # decay: w0 + lora(x_w)
        "w0": (jnp.zeros((d,)) - 0.6).astype(jnp.float32),
        "w_dec_a": (jax.random.normal(ks[5], (d, r.decay_lora)) * s).astype(dtype),
        "w_dec_b": (
            jax.random.normal(ks[6], (r.decay_lora, d)) * r.decay_lora ** -0.5 * 0.1
        ).astype(dtype),
        # per-channel bonus u
        "u": (jax.random.normal(ks[7], (d,)) * 0.3).astype(jnp.float32),
        "w_o": (jax.random.normal(ks[8], (d, d)) * s).astype(dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
    }
    return p


def cm_init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    """RWKV channel-mix FFN params."""
    d, d_ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        "mu": (jax.random.uniform(ks[0], (2, d)) * 0.5).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d, d_ff)) * s).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d_ff, d)) * d_ff ** -0.5).astype(dtype),
        "w_r": (jax.random.normal(ks[0], (d, d)) * s).astype(dtype),
    }


def _token_shift(x: jax.Array, x_last: Optional[jax.Array] = None) -> jax.Array:
    """x_{t-1} stream: shift right by one along time. x: [b, n, d]."""
    if x_last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_last[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _streams(params: dict, x: jax.Array, x_prev: jax.Array):
    """Five token-shift-mixed streams r/k/v/w/g: lerp(x, x_prev, mu_i)."""
    mu = params["mu"]  # [5, d]
    mix = lambda i: x + (x_prev - x) * mu[i][None, None, :]
    return mix(0), mix(1), mix(2), mix(3), mix(4)


def _time_mix_ops(params: dict, cfg: ArchConfig, x, x_prev):
    d = cfg.d_model
    H = d // cfg.rwkv.head_dim
    dh = cfg.rwkv.head_dim
    xr, xk, xv, xw, xg = _streams(params, x, x_prev)
    rr = xr @ params["w_r"]
    kk = xk @ params["w_k"]
    vv = xv @ params["w_v"]
    gg = jax.nn.silu(xg @ params["w_g"])
    logw = -jnp.exp(
        params["w0"][None, None, :]
        + _lora(xw.astype(jnp.float32), params["w_dec_a"].astype(jnp.float32),
                params["w_dec_b"].astype(jnp.float32))
    )  # [b, n, d], strictly negative
    split = lambda a: jnp.moveaxis(a.reshape(*a.shape[:-1], H, dh), -2, 1)
    u = params["u"].reshape(H, dh)
    return split(rr), split(kk), split(vv), split(logw), gg, u


def rwkv_time_mix(
    params: dict,
    cfg: ArchConfig,
    x: jax.Array,
    x_last: Optional[jax.Array] = None,
    s0: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix. Returns (out [b,n,d], s_final, x_final)."""
    from repro.models.linear_scan import lin_attn_chunked

    b, n, d = x.shape
    H = d // cfg.rwkv.head_dim
    x_prev = _token_shift(x, x_last)
    r, k, v, logw, g, u = _time_mix_ops(params, cfg, x, x_prev)
    pad_to = -n % 16
    if pad_to:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad_to), (0, 0)))
        r, k, v, logw = padf(r), padf(k), padf(v), padf(logw)
    y, s_fin = lin_attn_chunked(r, k, v, logw, u=u, s0=s0, mamba_style=False)
    y = y[:, :, :n]
    y = jnp.moveaxis(y, 1, 2).reshape(b, n, d)  # [b, n, d]
    y = groupnorm(y.astype(x.dtype), H, params["gn_scale"], params["gn_bias"])
    out = (y * g) @ params["w_o"]
    return out, s_fin, x[:, -1, :]


def rwkv_time_mix_step(
    params: dict,
    cfg: ArchConfig,
    x: jax.Array,
    state: dict,
) -> tuple[jax.Array, dict]:
    """Single-token decode. x: [b, 1, d]; state: {"S": [b,H,dh,dh],
    "x_last": [b, d]}."""
    from repro.models.linear_scan import lin_attn_decode_step

    b, n, d = x.shape
    assert n == 1
    H = d // cfg.rwkv.head_dim
    x_prev = _token_shift(x, state["x_last"])
    r, k, v, logw, g, u = _time_mix_ops(params, cfg, x, x_prev)
    y, S = lin_attn_decode_step(
        r[:, :, 0], k[:, :, 0], v[:, :, 0], logw[:, :, 0], state["S"], u=u,
        mamba_style=False,
    )
    y = y.reshape(b, 1, d)
    y = groupnorm(y.astype(x.dtype), H, params["gn_scale"], params["gn_bias"])
    out = (y * g) @ params["w_o"]
    return out, {"S": S, "x_last": x[:, -1, :]}


def rwkv_channel_mix(
    params: dict,
    x: jax.Array,
    x_last: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Channel-mix FFN with token shift. Returns (out, x_final)."""
    x_prev = _token_shift(x, x_last)
    mu = params["mu"]
    xk = x + (x_prev - x) * mu[0][None, None, :]
    xr = x + (x_prev - x) * mu[1][None, None, :]
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    kv = k @ params["w_v"]
    return jax.nn.sigmoid(xr @ params["w_r"]) * kv, x[:, -1, :]


def rwkv_state_init(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    H = d // cfg.rwkv.head_dim
    dh = cfg.rwkv.head_dim
    return {
        "tm": {
            "S": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "x_last": jnp.zeros((batch, d), dtype),
        },
        "cm_x_last": jnp.zeros((batch, d), dtype),
    }
