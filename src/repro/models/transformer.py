"""Composable decoder stack.

A model is a sequence of *stages* ``(pattern, repeat)`` (see
``repro.configs.base``). Parameters for a stage are stacked along a leading
``repeat`` axis and the stage runs under ``jax.lax.scan`` with the pattern
body unrolled — bounded HLO size for 48-61-layer models, heterogeneous
layouts (Gemma-3 5 local:1 global, DeepSeek dense-first-k, Hymba) supported
through the pattern.

Three entry points:
  * ``init_params``  — full parameter pytree
  * ``forward``      — train / prefill forward over [b, n] tokens
  * ``decode_step``  — one-token decode against per-layer caches
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerCfg
from repro.distributed.context import constrain
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import (
    attn_apply,
    attn_cache_init,
    attn_decode,
    attn_init,
    attn_prefill,
)
from repro.models.embedding import embed_tokens, embedding_init, merge_vision
from repro.models.ffn import ffn_apply, ffn_init
from repro.models.hymba import hymba_apply, hymba_cache_init, hymba_decode, hymba_init
from repro.models.mla import mla_apply, mla_cache_init, mla_decode, mla_init
from repro.models.moe import moe_apply, moe_init
from repro.models.norms import apply_norm, norm_init


# ---------------------------------------------------------------- init


def _layer_init(key: jax.Array, cfg: ArchConfig, layer: LayerCfg, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"norm1": norm_init(cfg.norm, d, dtype), "norm2": norm_init(cfg.norm, d, dtype)}
    if layer.mixer == "gqa":
        p["mixer"] = attn_init(ks[0], cfg, layer, dtype)
    elif layer.mixer == "mla":
        p["mixer"] = mla_init(ks[0], cfg, layer, dtype)
    elif layer.mixer == "hymba":
        p["mixer"] = hymba_init(ks[0], cfg, layer, dtype)
    elif layer.mixer == "rwkv6":
        p["mixer"] = rwkv_mod.rwkv_init(ks[0], cfg, layer, dtype)
    else:
        raise ValueError(layer.mixer)
    if layer.ffn == "moe":
        p["ffn"] = moe_init(ks[1], cfg, dtype)
    elif layer.ffn == "rwkv_cm":
        p["ffn"] = rwkv_mod.cm_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = ffn_init(ks[1], layer.ffn, d, cfg.d_ff, dtype)
    return p


def init_params(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, len(cfg.stages) + 3)
    params: dict = {"embed": embedding_init(keys[0], cfg, dtype)}
    stages = []
    for si, (pattern, repeat) in enumerate(cfg.stages):
        stage_keys = jax.random.split(keys[si + 1], repeat)

        def one(k, _pattern=pattern):
            lk = jax.random.split(k, len(_pattern))
            return tuple(
                _layer_init(lk[i], cfg, _pattern[i], dtype) for i in range(len(_pattern))
            )

        stages.append(jax.vmap(one)(stage_keys))
    params["stages"] = stages
    params["final_norm"] = norm_init(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        v_out = cfg.vocab * max(cfg.n_codebooks, 1)
        params["lm_head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, v_out)) * cfg.d_model ** -0.5
        ).astype(dtype)
    if cfg.mtp:
        d = cfg.d_model
        params["mtp"] = {
            "norm_h": norm_init(cfg.norm, d, dtype),
            "norm_e": norm_init(cfg.norm, d, dtype),
            "proj": (jax.random.normal(keys[-1], (2 * d, d)) * (2 * d) ** -0.5).astype(dtype),
            "ffn": ffn_init(keys[-1], "swiglu", d, cfg.d_ff, dtype),
            "norm_f": norm_init(cfg.norm, d, dtype),
        }
    return params


# ---------------------------------------------------------------- forward


def _layer_fwd(
    lp: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    train: bool,
    vq_rng: Optional[jax.Array],
) -> tuple[jax.Array, jax.Array]:
    """Pre-norm block: x + mixer(n1(x)); then x + ffn(n2(x)). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, lp["norm1"], x)
    if layer.mixer == "gqa":
        mix, a = attn_apply(lp["mixer"], cfg, layer, h, positions, train=train, vq_rng=vq_rng)
    elif layer.mixer == "mla":
        mix, a = mla_apply(lp["mixer"], cfg, layer, h, positions, train=train, vq_rng=vq_rng)
    elif layer.mixer == "hymba":
        mix, a = hymba_apply(lp["mixer"], cfg, layer, h, positions, train=train, vq_rng=vq_rng)
    elif layer.mixer == "rwkv6":
        mix, _, _ = rwkv_mod.rwkv_time_mix(lp["mixer"], cfg, h)
        a = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(layer.mixer)
    aux += a
    x = x + mix
    h2 = apply_norm(cfg.norm, lp["norm2"], x)
    if layer.ffn == "moe":
        y, moe_aux = moe_apply(lp["ffn"], cfg, h2)
        aux += moe_aux
    elif layer.ffn == "rwkv_cm":
        y, _ = rwkv_mod.rwkv_channel_mix(lp["ffn"], h2)
    else:
        y = ffn_apply(layer.ffn, lp["ffn"], h2)
    x = x + y
    # Megatron-style sequence parallelism: the residual stream lives
    # sequence-sharded on the model axis between layers, so norms/residual
    # elementwise work (the dominant byte traffic at 7k d_model) touches
    # 1/|model| of the tokens; GSPMD inserts the all-gather before QKV and
    # the reduce-scatter after the output projections (§Perf iteration 5).
    x = constrain(x, "batch", "seq_model", None)
    return x, aux


def _run_stages(
    params: dict,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    train: bool,
    rng: Optional[jax.Array],
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    base_rng = rng if rng is not None else jax.random.PRNGKey(0)
    layer_idx = 0
    for (pattern, repeat), stage_params in zip(cfg.stages, params["stages"]):

        def body(carry, sp, _pattern=pattern):
            xc, auxc, li = carry
            for pi, layer in enumerate(_pattern):
                vq_rng = jax.random.fold_in(base_rng, li * 8 + pi) if train else None
                xc, a = _layer_fwd(
                    sp[pi], cfg, layer, xc, positions, train=train, vq_rng=vq_rng
                )
                auxc = auxc + a
            return (xc, auxc, li + len(_pattern)), None

        # activation checkpointing: backward recomputes each layer body from
        # its carry instead of storing per-layer intermediates. Full remat
        # (no saveable policy): §Perf iteration 4 A/B-measured
        # dots_with_no_batch_dims_saveable as WORSE on byte traffic (+14%
        # on deepseek-v3 train) — recompute beats storing dot outputs here.
        body_fn = jax.checkpoint(body, prevent_cse=False) if (train and remat) else body
        if repeat == 1:
            (x, aux_total, layer_idx), _ = body_fn(
                (x, aux_total, jnp.asarray(layer_idx)),
                jax.tree.map(lambda a: a[0], stage_params),
            )
        else:
            (x, aux_total, layer_idx), _ = jax.lax.scan(
                body_fn, (x, aux_total, jnp.asarray(layer_idx)), stage_params
            )
    return x, aux_total


def _head(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        emb = params["embed"]["tok"]
        if cfg.n_codebooks > 1:
            logits = jnp.einsum("bnd,cvd->bncv", x, emb)
            return logits
        return x @ emb.T
    logits = x @ params["lm_head"]
    logits = constrain(logits, "batch", None, "model")
    if cfg.n_codebooks > 1:
        b, n, _ = logits.shape
        return logits.reshape(b, n, cfg.n_codebooks, cfg.vocab)
    return logits


def forward(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,
    positions: Optional[jax.Array] = None,
    *,
    patch_embeds: Optional[jax.Array] = None,
    train: bool = False,
    rng: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """tokens: [b, n] (audio: [b, n, n_codebooks]). Returns (logits, aux_dict).

    For VLM inputs, ``patch_embeds`` [b, n_patches, d] are projected and
    prefixed; logits cover the full (patches + text) sequence.
    """
    b = tokens.shape[0]
    n_text = tokens.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(n_text, dtype=jnp.int32), (b, n_text))
    x = embed_tokens(params["embed"], cfg, tokens, positions)
    if cfg.input_mode == "vlm":
        assert patch_embeds is not None, "vlm input requires patch_embeds"
        x = merge_vision(params["embed"], patch_embeds, x)
        npat = patch_embeds.shape[1]
        positions = jnp.concatenate(
            [
                jnp.broadcast_to(jnp.arange(npat, dtype=jnp.int32), (b, npat)),
                positions + npat,
            ],
            axis=1,
        )
    x = constrain(x, "batch", None, None)
    x, aux = _run_stages(params, cfg, x, positions, train=train, rng=rng)
    logits = _head(params, cfg, x)
    out_aux = {"aux_loss": aux, "hidden": x}
    if cfg.mtp and "mtp" in params:
        # DeepSeek-V3 style depth-1 multi-token prediction: combine h_t with
        # the embedding of token t+1 to predict token t+2 with the shared head.
        m = params["mtp"]
        emb_next = jnp.roll(embed_tokens(params["embed"], cfg, tokens, positions[:, -n_text:]), -1, axis=1)
        h_main = x[:, -n_text:]
        hcat = jnp.concatenate(
            [
                apply_norm(cfg.norm, m["norm_h"], h_main),
                apply_norm(cfg.norm, m["norm_e"], emb_next.astype(x.dtype)),
            ],
            axis=-1,
        )
        h_mtp = hcat @ m["proj"]
        h_mtp = h_mtp + ffn_apply("swiglu", m["ffn"], apply_norm(cfg.norm, m["norm_f"], h_mtp))
        out_aux["mtp_logits"] = _head(params, cfg, h_mtp)
    return logits, out_aux


# ---------------------------------------------------------------- decode


def _layer_cache_init(cfg: ArchConfig, layer: LayerCfg, batch: int, seq_len: int, dtype):
    if layer.mixer == "gqa":
        c = {"mix": attn_cache_init(cfg, layer, batch, seq_len, dtype)}
    elif layer.mixer == "mla":
        c = {"mix": mla_cache_init(cfg, layer, batch, seq_len, dtype)}
    elif layer.mixer == "hymba":
        c = {"mix": hymba_cache_init(cfg, layer, batch, seq_len, dtype)}
    elif layer.mixer == "rwkv6":
        c = {"mix": rwkv_mod.rwkv_state_init(cfg, batch, dtype)}
    else:
        raise ValueError(layer.mixer)
    return c


def init_caches(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> list:
    """Per-stage stacked caches mirroring the parameter structure."""
    caches = []
    for pattern, repeat in cfg.stages:
        per_layer = tuple(
            _layer_cache_init(cfg, layer, batch, seq_len, dtype) for layer in pattern
        )
        caches.append(
            jax.tree.map(lambda a: jnp.zeros((repeat,) + a.shape, a.dtype), per_layer)
        )
    return caches


def _layer_decode(
    lp: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    cache: dict,
    positions: jax.Array,
) -> tuple[jax.Array, dict]:
    h = apply_norm(cfg.norm, lp["norm1"], x)
    if layer.mixer == "gqa":
        mix, mc = attn_decode(lp["mixer"], cfg, layer, h, cache["mix"], positions)
    elif layer.mixer == "mla":
        mix, mc = mla_decode(lp["mixer"], cfg, layer, h, cache["mix"], positions)
    elif layer.mixer == "hymba":
        mix, mc = hymba_decode(lp["mixer"], cfg, layer, h, cache["mix"], positions)
    elif layer.mixer == "rwkv6":
        mix, tm = rwkv_mod.rwkv_time_mix_step(lp["mixer"], cfg, h, cache["mix"]["tm"])
        mc = {"tm": tm, "cm_x_last": cache["mix"]["cm_x_last"]}
    else:
        raise ValueError(layer.mixer)
    x = x + mix
    h2 = apply_norm(cfg.norm, lp["norm2"], x)
    if layer.ffn == "moe":
        y, _ = moe_apply(lp["ffn"], cfg, h2)
    elif layer.ffn == "rwkv_cm":
        y, cm_last = rwkv_mod.rwkv_channel_mix(lp["ffn"], h2, cache["mix"]["cm_x_last"])
        mc["cm_x_last"] = cm_last
    else:
        y = ffn_apply(layer.ffn, lp["ffn"], h2)
    return x + y, mc


def chunkable(cfg: ArchConfig) -> bool:
    """Whether ``prefill_step`` supports this config: plain-token GQA stacks
    with no sliding windows and no stateful (rwkv_cm) FFNs. Other mixers
    keep per-token recurrent/ring state that a multi-token chunk cannot
    update in one fixed-shape write."""
    return (
        cfg.input_mode == "tokens"
        and cfg.n_codebooks == 1
        and all(
            layer.mixer == "gqa" and layer.window is None and layer.ffn != "rwkv_cm"
            for layer in cfg.layer_list()
        )
    )


def _layer_prefill(
    lp: dict,
    cfg: ArchConfig,
    layer: LayerCfg,
    x: jax.Array,
    cache: dict,
    positions: jax.Array,
) -> tuple[jax.Array, dict]:
    h = apply_norm(cfg.norm, lp["norm1"], x)
    mix, mc = attn_prefill(lp["mixer"], cfg, layer, h, cache["mix"], positions)
    x = x + mix
    h2 = apply_norm(cfg.norm, lp["norm2"], x)
    if layer.ffn == "moe":
        y, _ = moe_apply(lp["ffn"], cfg, h2)
    else:
        y = ffn_apply(layer.ffn, lp["ffn"], h2)
    return x + y, mc


def prefill_step(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,
    caches: list,
    positions: jax.Array,
) -> tuple[jax.Array, list]:
    """``m`` new tokens per sequence in ONE fixed-shape step (the batched
    prefill that ``decode_step`` is the m=1 special case of). tokens /
    positions: [b, m]. Returns (logits [b, m, vocab], new caches). Only
    ``chunkable`` configs (non-windowed GQA over plain tokens) are
    supported — exactly the VQT serving shape."""
    if not chunkable(cfg):
        raise ValueError(
            f"{cfg.name}: chunked prefill requires non-windowed gqa layers "
            "over plain tokens — use per-token decode_step instead")
    x = embed_tokens(params["embed"], cfg, tokens, positions)
    x = constrain(x, "batch", None, None)
    new_caches = []
    for (pattern, repeat), sp, sc in zip(cfg.stages, params["stages"], caches):

        def body_wrap(xc, inp, _pattern=pattern):
            spi, sci = inp
            new_sci = []
            for pi, layer in enumerate(_pattern):
                xc, mc = _layer_prefill(spi[pi], cfg, layer, xc, sci[pi], positions)
                new_sci.append({"mix": mc})
            return xc, tuple(new_sci)

        if repeat == 1:
            x, nc = body_wrap(
                x, (jax.tree.map(lambda a: a[0], sp), jax.tree.map(lambda a: a[0], sc))
            )
            nc = jax.tree.map(lambda a: a[None], nc)
        else:
            x, nc = jax.lax.scan(body_wrap, x, (sp, sc))
        new_caches.append(nc)
    logits = _head(params, cfg, x)
    return logits, new_caches


def caches_from_kv(
    cfg: ArchConfig,
    k: jax.Array,
    v: jax.Array,
    length: jax.Array,
    *,
    seq_len: Optional[int] = None,
    dtype=jnp.float32,
) -> list:
    """Build decode caches from per-layer stacked K/V — e.g. the jit
    engine's ``export_kv`` (DESIGN.md §5).

    k, v: [L, b, S0, Hkv, dh] sequence-ordered cached keys/values (rows
    beyond each document's real length may hold garbage — the cache
    ``length`` masks them). length: [b] int32 — how many leading rows to
    trust; rows at/after it are expected to be re-prefilled. ``seq_len``
    pads the cache beyond S0 to leave room for continuation tokens."""
    layers = cfg.layer_list()
    if k.shape[0] != len(layers):
        raise ValueError(f"k carries {k.shape[0]} layers, config has {len(layers)}")
    b, S0 = k.shape[1], k.shape[2]
    S = seq_len if seq_len is not None else S0
    if S < S0:
        raise ValueError(f"seq_len {S} smaller than exported rows {S0}")
    length = jnp.asarray(length, jnp.int32).reshape(b)
    caches = []
    li = 0
    for pattern, repeat in cfg.stages:
        per_repeat = []
        for _ in range(repeat):
            per_layer = []
            for layer in pattern:
                if layer.mixer != "gqa" or layer.window is not None:
                    raise ValueError(
                        "caches_from_kv supports non-windowed gqa layers only")
                Hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
                kb = jnp.zeros((b, S, Hkv, dh), dtype)
                vb = jnp.zeros((b, S, Hkv, dh), dtype)
                kb = kb.at[:, :S0].set(k[li].astype(dtype))
                vb = vb.at[:, :S0].set(v[li].astype(dtype))
                per_layer.append({"mix": {"k": kb, "v": vb, "len": length}})
                li += 1
            per_repeat.append(tuple(per_layer))
        caches.append(jax.tree.map(lambda *a: jnp.stack(a), *per_repeat))
    return caches


def set_cache_length(caches: list, length) -> list:
    """Rewind (or advance) every layer's cache length counter — the
    suggestion engine's prefix-reuse primitive: rows at/after ``length``
    become invisible to attention and are overwritten by the next
    prefill/decode writes. Full (non-ring) caches only: lengths are
    absolute slot counts there."""

    def _rec(node):
        if isinstance(node, dict):
            return {
                key: (jnp.full_like(val, length) if key == "len" else _rec(val))
                for key, val in node.items()
            }
        if isinstance(node, tuple):
            return tuple(_rec(x) for x in node)
        if isinstance(node, list):
            return [_rec(x) for x in node]
        return node

    return _rec(caches)


def decode_step(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,
    caches: list,
    positions: jax.Array,
) -> tuple[jax.Array, list]:
    """One new token per sequence. tokens: [b, 1] (audio [b, 1, cb]).
    Returns (logits [b, 1, ...], new caches)."""
    x = embed_tokens(params["embed"], cfg, tokens, positions)
    x = constrain(x, "batch", None, None)
    new_caches = []
    for (pattern, repeat), sp, sc in zip(cfg.stages, params["stages"], caches):

        def body_wrap(xc, inp, _pattern=pattern):
            spi, sci = inp
            new_sci = []
            for pi, layer in enumerate(_pattern):
                xc, mc = _layer_decode(spi[pi], cfg, layer, xc, sci[pi], positions)
                new_sci.append({"mix": mc})
            return xc, tuple(new_sci)

        if repeat == 1:
            x, nc = body_wrap(
                x, (jax.tree.map(lambda a: a[0], sp), jax.tree.map(lambda a: a[0], sc))
            )
            nc = jax.tree.map(lambda a: a[None], nc)
        else:
            x, nc = jax.lax.scan(body_wrap, x, (sp, sc))
        new_caches.append(nc)
    logits = _head(params, cfg, x)
    return logits, new_caches
