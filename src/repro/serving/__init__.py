from repro.serving.engine import IncrementalServer, ServerStats
from repro.serving.decode import greedy_decode, make_serve_step
from repro.serving.jit_engine import (
    JitIncrementalEngine, JitState, KVExport, OP_DELETE, OP_INSERT, OP_REPLACE,
)
from repro.serving.batch_engine import (
    BatchedJitEngine, BatchedJitState, stack_states, unstack_state,
)
from repro.serving.batch_server import BatchServer, BatchStats, next_pow2
from repro.serving.async_server import (
    AsyncBatchServer, AsyncStats, SuggestionStream, Ticket,
)
from repro.serving.latency import LatencyStats
from repro.serving.state_store import (
    DeviceBudgetError, StateStore, TIER_COLD, TIER_HOT, TIER_VOID, TIER_WARM,
)
from repro.serving.suggest import (
    PositionHeadroomError, SuggestionEngine, SuggestStats, oracle_suggestion,
)
from repro.launch.mesh import make_serving_mesh
