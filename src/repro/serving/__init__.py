from repro.serving.engine import IncrementalServer, ServerStats
from repro.serving.decode import make_serve_step
