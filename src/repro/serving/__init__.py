from repro.serving.engine import IncrementalServer, ServerStats
from repro.serving.decode import make_serve_step
from repro.serving.jit_engine import (
    JitIncrementalEngine, JitState, OP_DELETE, OP_INSERT, OP_REPLACE,
)
from repro.serving.batch_engine import (
    BatchedJitEngine, BatchedJitState, stack_states, unstack_state,
)
from repro.serving.batch_server import BatchServer, BatchStats, next_pow2
