"""Deadline-batching async front end over ``BatchServer`` (DESIGN.md §8).

``BatchServer`` is a synchronous scheduler: the caller submits edits and
drives ``step()``/``flush()`` itself, so batching only happens when one
caller happens to queue work for many documents before flushing. A real
assistant fleet is the opposite shape — many concurrent sessions, each
producing small bursts of edits and wanting a suggestion back *soon*. This
module adds the missing front end:

1. **Concurrent admission.** Any thread may ``open_document`` /
   ``submit_replace|insert|delete`` / ``suggest`` / ``subscribe``; requests
   land in one admission queue with their arrival timestamp and return a
   ticket (a latch the scheduler resolves). The inner ``BatchServer`` is
   touched ONLY by the scheduler thread — jax dispatch, host mirrors and
   allocator state stay single-threaded, so every invariant the synchronous
   scheduler proves (snapshot/rollback, FIFO per document, exactly-once
   application) carries over unchanged.
2. **Deadline batching.** The scheduler dispatches a round when the bucket
   is full (``bucket_docs`` distinct documents have admitted work) OR when
   ``max_batch_delay_ms`` has elapsed since the round's oldest admission —
   latency as a first-class scheduling knob (Barad et al., PAPERS.md). A
   partial bucket never waits past its deadline; a hot fleet never waits at
   all.
3. **Coalescing.** All of a document's edits admitted before the round
   drain into its FIFO queue together, so ``_take_bucket`` serves the burst
   as one take (up to the edit capacity ``C`` per dispatch) instead of one
   dispatch per keystroke. Opens admitted in the same window batch into one
   ``open_documents`` ingest dispatch.
4. **Streaming.** ``subscribe`` returns a ``SuggestionStream``; every real
   refresh pushes ``("token", serial, index, token)`` events as the decode
   loop produces them, then one ``("suggestion", serial, tokens)`` event
   with the complete continuation.
5. **Latency SLOs.** Admission-to-completion latency is recorded per edit
   and per suggestion into ``BatchStats.edit_latency`` /
   ``BatchStats.suggest_latency`` (p50/p99/max, ``serving.latency``).

Exactness contract (tests/test_async_server.py): any interleaving of client
threads through this front end yields final documents and suggestion tokens
identical to a sequential ``BatchServer`` fed each document's requests in
the same per-document order — including rounds cut short by the deadline
(partial buckets) and mid-stream defrag/grow re-ingests.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from queue import Empty, Queue
from typing import Optional, Sequence

import numpy as np

from repro.core.edits import Edit
from repro.serving.batch_server import BatchServer


class Ticket:
    """A latch the scheduler thread resolves when the request is served.

    ``result(timeout)`` blocks for the request's value (None for edits),
    re-raising the scheduler-side exception if the request failed —
    submission errors (bad position, unknown document) surface here instead
    of crashing the serving loop."""

    __slots__ = ("doc_id", "admit_t", "_event", "_value", "_error")

    def __init__(self, doc_id: Optional[str]):
        self.doc_id = doc_id
        self.admit_t = time.perf_counter()
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request for {self.doc_id!r} not served in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    # scheduler side
    def _resolve(self, value=None) -> None:
        self._value = value
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()


class SuggestionStream:
    """Subscriber endpoint for one document's suggestion updates.

    Events (in order per refresh, ``serial`` strictly increasing):

    * ``("token", serial, index, token)`` — one decoded token, pushed as
      the decode loop produces it;
    * ``("suggestion", serial, tokens)`` — the complete refreshed
      continuation (np.int32 array);
    * ``("closed", None, None)`` — the document closed or the front end
      shut down; no further events.
    """

    def __init__(self, doc_id: str, n_new: int):
        self.doc_id = doc_id
        self.n_new = int(n_new)
        self._q: Queue = Queue()

    def get(self, timeout: Optional[float] = None) -> tuple:
        try:
            return self._q.get(timeout=timeout)
        except Empty:
            raise TimeoutError(
                f"no suggestion event for {self.doc_id!r} in {timeout}s")

    def next_suggestion(self, timeout: Optional[float] = None
                        ) -> tuple[int, np.ndarray]:
        """Block for the next COMPLETE continuation; token events before it
        are consumed (callers that want them use ``get``)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            left = None if deadline is None else deadline - time.monotonic()
            kind, serial, *rest = self.get(left)
            if kind == "suggestion":
                return serial, rest[0]
            if kind == "closed":
                raise RuntimeError(f"stream for {self.doc_id!r} closed")

    # scheduler side
    def _push(self, event: tuple) -> None:
        self._q.put(event)


@dataclass
class AsyncStats:
    """Scheduling-round accounting for the deadline batcher."""

    rounds: int = 0
    deadline_rounds: int = 0  # dispatched because max_batch_delay_ms expired
    full_rounds: int = 0  # dispatched because the bucket filled first
    admitted_edits: int = 0
    admitted_suggests: int = 0
    admitted_opens: int = 0
    requests_failed: int = 0  # tickets resolved with an exception

    @property
    def mean_edits_per_round(self) -> float:
        return self.admitted_edits / max(self.rounds, 1)


class AsyncBatchServer:
    """Event-loop serving front end: concurrent admission, deadline
    batching, per-document coalescing, suggestion streaming, latency SLOs.

    One scheduler thread owns the wrapped ``BatchServer``; every public
    method is safe from any thread and returns either a ``Ticket`` or a
    ``SuggestionStream``. Use as a context manager, or call ``close()``
    (which drains admitted work before stopping).
    """

    def __init__(self, server: BatchServer, *,
                 max_batch_delay_ms: float = 10.0,
                 bucket_docs: Optional[int] = None):
        if max_batch_delay_ms < 0:
            raise ValueError("max_batch_delay_ms must be >= 0")
        self.server = server
        self.max_batch_delay_ms = float(max_batch_delay_ms)
        self.bucket_docs = int(bucket_docs or server.max_batch)
        if self.bucket_docs < 1:
            raise ValueError("bucket_docs must be >= 1")
        self.stats = AsyncStats()
        self._cond = threading.Condition()
        self._requests: deque = deque()  # (kind, ticket, payload)
        self._subs: dict[str, list[SuggestionStream]] = {}
        self._subs_lock = threading.Lock()
        self._stream_idx: Optional[list] = None  # [(doc, serial), next index]
        self._stop = False
        server.on_suggest_token = self._stream_token
        self._thread = threading.Thread(
            target=self._loop, name="repro-async-serve", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ client API

    def open_document(self, doc_id: str, tokens: Sequence[int]) -> Ticket:
        """Admit a session open. Opens admitted within one deadline window
        ingest through a single batched ``open_documents`` dispatch."""
        return self._admit("open", doc_id, list(tokens))

    def close_document(self, doc_id: str) -> Ticket:
        """Admit a session close. Like the synchronous server, closing
        discards the document's still-queued edits — await your edit
        tickets before closing if they must land."""
        return self._admit("close", doc_id, None)

    def submit_replace(self, doc_id: str, pos: int, tok: int) -> Ticket:
        return self._admit("edit", doc_id, ("replace", int(pos), int(tok)))

    def submit_insert(self, doc_id: str, pos: int, tok: int) -> Ticket:
        return self._admit("edit", doc_id, ("insert", int(pos), int(tok)))

    def submit_delete(self, doc_id: str, pos: int) -> Ticket:
        return self._admit("edit", doc_id, ("delete", int(pos), 0))

    def submit_edit(self, doc_id: str, e: Edit) -> Ticket:
        if e.op == "replace":
            return self.submit_replace(doc_id, e.pos, e.token)
        if e.op == "insert":
            return self.submit_insert(doc_id, e.pos, e.token)
        return self.submit_delete(doc_id, e.pos)

    def suggest(self, doc_id: str, n_new: int = 8) -> Ticket:
        """Admit a one-shot suggestion request; ``result()`` is the greedy
        continuation AFTER every edit admitted before it applied (the
        document stays subscribed at ``n_new``, like ``BatchServer.suggest``)."""
        return self._admit("suggest", doc_id, int(n_new))

    def subscribe(self, doc_id: str, n_new: int = 8) -> SuggestionStream:
        """Open a standing suggestion subscription with streaming delivery:
        after every round that leaves the document's suggestion stale, the
        refresh pushes token events to the returned stream."""
        stream = SuggestionStream(doc_id, n_new)
        with self._subs_lock:
            self._subs.setdefault(doc_id, []).append(stream)
        self._admit("subscribe", doc_id, stream)
        return stream

    def unsubscribe(self, stream: SuggestionStream) -> None:
        with self._subs_lock:
            streams = self._subs.get(stream.doc_id, [])
            if stream in streams:
                streams.remove(stream)
                if not streams:
                    self._subs.pop(stream.doc_id, None)
        stream._push(("closed", None, None))

    def tokens(self, doc_id: str) -> Ticket:
        """Admit a read of the document's (flushed) tokens in sequence
        order — serialized through the scheduler like every other touch."""
        return self._admit("tokens", doc_id, None)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Block until every request admitted before this call is served."""
        self._admit("barrier", None, None).result(timeout)

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain admitted work, stop the scheduler thread, close streams.
        Idempotent; the wrapped (now-quiescent) ``BatchServer`` remains
        usable synchronously afterwards."""
        with self._cond:
            if self._stop and not self._thread.is_alive():
                return
            self._stop = True
            self._cond.notify_all()
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("async scheduler did not stop in time")
        self.server.on_suggest_token = None
        with self._subs_lock:
            streams = [s for ss in self._subs.values() for s in ss]
            self._subs.clear()
        for s in streams:
            s._push(("closed", None, None))

    def __enter__(self) -> "AsyncBatchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- admission

    def _admit(self, kind: str, doc_id: Optional[str], payload) -> Ticket:
        ticket = Ticket(doc_id)
        with self._cond:
            if self._stop:
                raise RuntimeError("async server is closed")
            self._requests.append((kind, ticket, payload))
            self._cond.notify_all()
        return ticket

    def _ready_docs(self) -> int:
        """Distinct documents with admitted dispatchable work (held lock)."""
        return len({t.doc_id for kind, t, _ in self._requests
                    if kind in ("edit", "open")})

    # ------------------------------------------------------------- scheduler

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._requests and not self._stop:
                    self._cond.wait()
                if not self._requests:  # stopping, fully drained
                    break
                full = False
                if not self._stop:  # draining rounds skip the deadline wait
                    deadline = (self._requests[0][1].admit_t
                                + self.max_batch_delay_ms / 1e3)
                    while not self._stop:
                        if self._ready_docs() >= self.bucket_docs:
                            full = True
                            break
                        remaining = deadline - time.perf_counter()
                        if remaining <= 0:
                            break
                        self._cond.wait(remaining)
                batch = list(self._requests)
                self._requests.clear()
            self._run_round(batch, full)

    def _run_round(self, batch: list, full: bool) -> None:
        srv = self.server
        self.stats.rounds += 1
        if full:
            self.stats.full_rounds += 1
        else:
            self.stats.deadline_rounds += 1

        # ---- phase 1: apply admissions to the inner server's queues, in
        # admission order. Consecutive opens buffer into ONE batched ingest;
        # any other request first flushes the open buffer, so a client that
        # fires open->edit without waiting still sees its order preserved.
        edit_tickets: list[Ticket] = []
        suggest_reqs: list[tuple[Ticket, str, int]] = []
        barriers: list[Ticket] = []
        pending_opens: dict[str, tuple[Ticket, list]] = {}

        def flush_opens() -> None:
            if not pending_opens:
                return
            items = {d: toks for d, (t, toks) in pending_opens.items()}
            try:
                srv.open_documents(items)
                self.stats.admitted_opens += len(items)
                for t, _ in pending_opens.values():
                    t._resolve()
            except Exception:
                # one bad document must not strand the batch: retry one by
                # one so only the culprit's ticket carries the error
                for d, (t, toks) in pending_opens.items():
                    try:
                        srv.open_documents({d: toks})
                        self.stats.admitted_opens += 1
                        t._resolve()
                    except Exception as e:
                        self.stats.requests_failed += 1
                        t._fail(e)
            pending_opens.clear()

        for kind, ticket, payload in batch:
            try:
                if kind == "open":
                    pending_opens[ticket.doc_id] = (ticket, payload)
                    continue
                flush_opens()
                if kind == "edit":
                    op, pos, tok = payload
                    if op == "replace":
                        srv.submit_replace(ticket.doc_id, pos, tok)
                    elif op == "insert":
                        srv.submit_insert(ticket.doc_id, pos, tok)
                    else:
                        srv.submit_delete(ticket.doc_id, pos)
                    edit_tickets.append(ticket)
                elif kind == "suggest":
                    srv.submit_suggest(ticket.doc_id, payload)
                    suggest_reqs.append((ticket, ticket.doc_id, payload))
                elif kind == "subscribe":
                    srv.submit_suggest(ticket.doc_id, payload.n_new)
                    ticket._resolve()
                elif kind == "close":
                    self._close_streams(ticket.doc_id)
                    srv.close_document(ticket.doc_id)
                    ticket._resolve()
                elif kind == "tokens":
                    ticket._resolve(srv.tokens(ticket.doc_id))
                elif kind == "barrier":
                    barriers.append(ticket)
                else:  # pragma: no cover - admission kinds are internal
                    raise AssertionError(f"unknown request kind {kind!r}")
            except Exception as e:
                self.stats.requests_failed += 1
                ticket._fail(e)
        flush_opens()

        # ---- phase 2: one synchronous scheduling drain. flush() groups the
        # coalesced per-document queues into capacity-bucketed dispatches
        # and refreshes every stale subscription (snapshot/rollback and the
        # oracle guarantees are the inner scheduler's, untouched).
        serials = {d_id: d.suggest_serial for d_id, d in srv.docs.items()}
        try:
            srv.flush()
        except Exception as e:
            # dispatch failure: the inner scheduler rolled every affected
            # document back and KEPT its queued edits, so the work retries
            # with the next round; these tickets report the failure
            for t in edit_tickets:
                self.stats.requests_failed += 1
                t._fail(e)
            for t, _, _ in suggest_reqs:
                self.stats.requests_failed += 1
                t._fail(e)
            for t in barriers:
                t._fail(e)
            return

        now = time.perf_counter()
        for t in edit_tickets:
            srv.stats.edit_latency.record((now - t.admit_t) * 1e3)
            t._resolve()
        self.stats.admitted_edits += len(edit_tickets)

        for t, doc_id, n_new in suggest_reqs:
            try:
                out = srv.suggest(doc_id, n_new)  # fresh -> cached, no work
            except Exception as e:
                self.stats.requests_failed += 1
                t._fail(e)
                continue
            srv.stats.suggest_latency.record(
                (time.perf_counter() - t.admit_t) * 1e3)
            t._resolve(out)
        self.stats.admitted_suggests += len(suggest_reqs)

        # ---- phase 3: deliver refreshed subscriptions. Token events were
        # already streamed live from the decode loop; completed
        # continuations are pushed here, and edit-triggered refreshes (no
        # explicit suggest ticket) record their latency from the round's
        # oldest admission — the queueing delay is part of the SLO.
        round_t0 = min((t.admit_t for _, t, _ in batch), default=now)
        explicit = {doc_id for _, doc_id, _ in suggest_reqs}
        with self._subs_lock:
            subscribed = {d: list(ss) for d, ss in self._subs.items()}
        for doc_id, streams in subscribed.items():
            doc = srv.docs.get(doc_id)
            if doc is None or not doc.suggest_fresh:
                continue
            if doc.suggest_serial == serials.get(doc_id):
                continue  # nothing new since the last delivery
            if doc_id not in explicit:
                srv.stats.suggest_latency.record(
                    (time.perf_counter() - round_t0) * 1e3)
            event = ("suggestion", doc.suggest_serial, doc.suggestion.copy())
            for s in streams:
                s._push(event)
        for t in barriers:
            t._resolve()

    # ------------------------------------------------------------- streaming

    def _stream_token(self, doc_id: str, serial: int, token: int) -> None:
        """BatchServer.on_suggest_token hook: forward one decoded token to
        the document's subscribers the moment the decode loop yields it."""
        with self._subs_lock:
            streams = list(self._subs.get(doc_id, ()))
        if not streams:
            return
        idx = self._stream_idx
        if idx is None or idx[0] != (doc_id, serial):
            self._stream_idx = idx = [(doc_id, serial), 0]
        for s in streams:
            s._push(("token", serial, idx[1], int(token)))
        idx[1] += 1

    def _close_streams(self, doc_id: str) -> None:
        with self._subs_lock:
            streams = self._subs.pop(doc_id, [])
        for s in streams:
            s._push(("closed", None, None))
