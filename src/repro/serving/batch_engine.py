"""Batched dirty-slot serving: the vmapped static-capacity jit engine.

``JitIncrementalEngine`` serves ONE document per dispatch. Under real
traffic (the ROADMAP's millions-of-users setting) many documents have
pending edits at once, and each bucketed step is a small fixed-shape
program — exactly the shape regime where batching pays. This module vmaps
the engine's un-jitted ``*_impl`` methods over a leading document axis:

* ``BatchedJitState`` — the same ``JitState`` NamedTuple, every leaf with a
  leading ``[B]`` batch axis (``stack_states`` / ``unstack_state`` convert);
* ``batch_full_forward(tokens [B, n], positions [B, n], valid [B, n])`` —
  one fused program ingests B slot-buffer documents;
* ``batch_apply_edits(state, slot/tok/pos_id/op [B, C])`` — one fused step
  applies up to C typed edits (replace / insert / delete, see the opcodes
  in ``jit_engine``) to EACH of B documents and returns a per-document
  ``overflow [B]`` bool vector. Documents in the batch may have disjoint
  edit buckets (pad unused slots with -1) — including all-empty buckets,
  which leave that document unchanged. The op vector is *data*, so
  replace-, insert- and delete-typed scheduler buckets all share this one
  compiled step — no per-op re-jit;
* ``batch_apply_replaces`` / ``batch_apply_inserts`` / ``batch_apply_deletes``
  — typed conveniences over the same impl.

All documents in a batch must share the capacities ``(n_cap, C, R)`` — the
batch server's capacity buckets guarantee this. With
``use_patch_kernel=True`` the per-layer column patch runs through the
``incr_patch`` Pallas kernel; under vmap its grid gains a leading batch
dimension (one ``(doc, row-block, head)`` cell per grid point), so the
batched step reuses the same kernel as single-document serving.

Multi-device serving (DESIGN.md §6)
-----------------------------------
Pass ``mesh=`` (see ``repro.launch.mesh.make_serving_mesh``) to shard the
document axis over a 1-D device mesh: every batched entry point becomes a
``shard_map`` over per-shard ``[B/n_dev, ...]`` slices (weights replicate
via closure), so each device runs the ordinary vmapped step — including
the batched Pallas kernels, whose grids see only the local batch slice —
and no cross-device communication exists anywhere in a dispatch (sequence
order is position-id order *within* each document, so the batch axis is
embarrassingly parallel). ``B`` must be a multiple of the mesh's batch
axis; the batch server pads dispatches accordingly. A mesh of size 1 (or
``mesh=None``) routes through the exact single-device jit path, bit-for-bit
identical to pre-mesh behavior (tested in tests/test_sharded_parity.py).

Exactness: slice b of every batched result equals the single-document
engine run on document b (tested in tests/test_batch_serving.py), under
any mesh size (tests/test_sharded_parity.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.distributed.context import shard_map_compat
from repro.launch.sharding import serving_batch_sharding
from repro.serving.jit_engine import JitIncrementalEngine, JitState, KVExport

# A JitState whose every leaf carries a leading [B] document axis.
BatchedJitState = JitState


def stack_states(states: list[JitState]) -> BatchedJitState:
    """Stack per-document states along a new leading batch axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(batched: BatchedJitState, b: int) -> JitState:
    """Slice document ``b`` back out of a batched state."""
    return jax.tree.map(lambda x: x[b], batched)


class BatchedJitEngine(JitIncrementalEngine):
    """vmap'd ``JitIncrementalEngine``: one fixed-shape step, B documents.

    Same constructor as the single-document engine (``edit_capacity``,
    ``row_capacity``, ``use_patch_kernel``, ``use_fused_kernel``,
    ``delta_threshold`` — the sigma-delta propagation gate of DESIGN.md §10,
    applied per document slice — ``_weights``), plus ``mesh`` /
    ``batch_axis`` for data-parallel sharding
    of the document axis. With ``use_fused_kernel=True`` each layer's patch
    + requantize runs as ONE batched ``fused_step`` Pallas launch (the
    batching rule turns the per-document kernel grid into a
    (doc, row-block, vq-head) grid).
    """

    def __init__(self, params, cfg, *, edit_capacity: int = 8,
                 row_capacity: int = 64, use_patch_kernel: bool = False,
                 use_fused_kernel: bool = False, delta_threshold: float = 0.0,
                 mesh: Optional[Mesh] = None, batch_axis: str = "data",
                 _weights=None):
        super().__init__(params, cfg, edit_capacity=edit_capacity,
                         row_capacity=row_capacity,
                         use_patch_kernel=use_patch_kernel,
                         use_fused_kernel=use_fused_kernel,
                         delta_threshold=delta_threshold, _weights=_weights)
        if mesh is not None:
            serving_batch_sharding(mesh, batch_axis)  # validates the axis
        self.mesh = mesh
        self.batch_axis = batch_axis
        self._sharded_fns: dict[str, callable] = {}

    @property
    def n_shards(self) -> int:
        """Devices the document axis splits across (1 = single-device path)."""
        return int(self.mesh.shape[self.batch_axis]) if self.mesh is not None else 1

    # ------------------------------------------------------------ shard plumbing

    def _check_batch(self, B: int) -> None:
        if B % self.n_shards != 0:
            raise ValueError(
                f"batch of {B} documents does not divide the serving mesh's "
                f"{self.n_shards}-way batch axis — pad the dispatch "
                "(BatchServer pads to a multiple automatically)")

    def _sharded(self, name: str):
        """jit(shard_map(vmapped impl)) with every input/output pytree leaf
        sharded on the batch axis (a single ``P(batch_axis)`` acts as the
        pytree-prefix spec for states, buckets and exports alike). Built
        lazily per entry point and cached per engine — one compiled step
        per (B, n_cap, C, R) exactly like the single-device path."""
        fn = self._sharded_fns.get(name)
        if fn is None:
            builders = {
                "full_forward": (
                    lambda t, p, v: jax.vmap(self._full_forward_impl)(t, p, v),
                    3),
                "apply_edits": (
                    lambda s, sl, tk, pi, op: jax.vmap(self._apply_edits_impl)(
                        s, sl, tk, pi, op), 5),
                "export_kv": (lambda s: jax.vmap(self._export_kv_impl)(s), 1),
                "logits_at": (
                    lambda s, i: jax.vmap(self._logits_at_impl)(s, i), 2),
            }
            body, n_args = builders[name]
            spec = serving_batch_sharding(self.mesh, self.batch_axis).spec
            fn = jax.jit(shard_map_compat(
                body, mesh=self.mesh, in_specs=(spec,) * n_args,
                out_specs=spec))
            self._sharded_fns[name] = fn
        return fn

    # ------------------------------------------------------------ batched API

    def batch_full_forward(self, tokens: jax.Array, positions: jax.Array,
                           valid: Optional[jax.Array] = None
                           ) -> BatchedJitState:
        """tokens/positions: [B, n] int32, valid: [B, n] bool (None = all
        real) → stacked state, leaves [B, ...]."""
        if self.n_shards > 1:
            self._check_batch(tokens.shape[0])
            if valid is None:
                valid = jnp.ones(tokens.shape, bool)
            return self._sharded("full_forward")(tokens, positions, valid)
        return self._batch_full_forward_local(tokens, positions, valid)

    @functools.partial(jax.jit, static_argnums=0)
    def _batch_full_forward_local(self, tokens, positions, valid=None):
        if valid is None:
            return jax.vmap(
                lambda t, p: self._full_forward_impl(t, p))(tokens, positions)
        return jax.vmap(self._full_forward_impl)(tokens, positions, valid)

    def batch_apply_edits(
        self, state: BatchedJitState, slot: jax.Array, tok: jax.Array,
        pos_id: jax.Array, op: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """slot/tok/pos_id/op: [B, C] int32 (pad unused slots with -1).
        Returns (new_state, overflow [B] bool). A document whose overflow
        flag is set exceeded its row bucket R at some layer; its slice is
        UNRELIABLE and the caller must re-run a full forward for it (the
        batch server's fallback + capacity-doubling policy)."""
        if self.n_shards > 1:
            self._check_batch(slot.shape[0])
            return self._sharded("apply_edits")(state, slot, tok, pos_id, op)
        return self._batch_apply_edits_local(state, slot, tok, pos_id, op)

    @functools.partial(jax.jit, static_argnums=0)
    def _batch_apply_edits_local(self, state, slot, tok, pos_id, op):
        return jax.vmap(self._apply_edits_impl)(state, slot, tok, pos_id, op)

    def batch_apply_replaces(
        self, state: BatchedJitState, edit_pos: jax.Array, edit_tok: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """Replace-only bucket: edit_pos/edit_tok [B, C] int32 (pad -1)."""
        z = jnp.zeros_like(edit_pos)
        return self.batch_apply_edits(state, edit_pos, edit_tok, z, z)

    def batch_apply_inserts(
        self, state: BatchedJitState, slot: jax.Array, tok: jax.Array,
        pos_id: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """Insert-only bucket: claim free slots with fresh mid-gap ids."""
        from repro.serving.jit_engine import OP_INSERT

        op = jnp.where(slot >= 0, OP_INSERT, 0).astype(slot.dtype)
        return self.batch_apply_edits(state, slot, tok, pos_id, op)

    def batch_apply_deletes(
        self, state: BatchedJitState, slot: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """Delete-only bucket: invalidate slots, subtract their columns."""
        from repro.serving.jit_engine import OP_DELETE

        z = jnp.zeros_like(slot)
        op = jnp.where(slot >= 0, OP_DELETE, 0).astype(slot.dtype)
        return self.batch_apply_edits(state, slot, z, z, op)

    def batch_export_kv(self, state: BatchedJitState) -> KVExport:
        """Position-ordered KV export for every document in the batch in one
        fused gather: each ``KVExport`` leaf gains a leading [B] axis.
        Parity-tested against the per-document ``export_kv`` — the batched
        entry point for a future bucket-batched suggestion refresh (the
        current scheduler exports per document as it refreshes)."""
        if self.n_shards > 1:
            self._check_batch(state.tokens.shape[0])
            return self._sharded("export_kv")(state)
        return self._batch_export_kv_local(state)

    @functools.partial(jax.jit, static_argnums=0)
    def _batch_export_kv_local(self, state):
        return jax.vmap(self._export_kv_impl)(state)

    def batch_logits_at(self, state: BatchedJitState,
                        index: jax.Array) -> jax.Array:
        """index: [B] int32 per-document slot (the last-in-position-order
        valid slot for padded docs — the host scheduler tracks it)."""
        if self.n_shards > 1:
            self._check_batch(index.shape[0])
            return self._sharded("logits_at")(state, index)
        return self._batch_logits_at_local(state, index)

    @functools.partial(jax.jit, static_argnums=0)
    def _batch_logits_at_local(self, state, index):
        return jax.vmap(self._logits_at_impl)(state, index)
