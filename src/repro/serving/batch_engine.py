"""Batched dirty-slot serving: the vmapped static-capacity jit engine.

``JitIncrementalEngine`` serves ONE document per dispatch. Under real
traffic (the ROADMAP's millions-of-users setting) many documents have
pending edits at once, and each bucketed step is a small fixed-shape
program — exactly the shape regime where batching pays. This module vmaps
the engine's un-jitted ``*_impl`` methods over a leading document axis:

* ``BatchedJitState`` — the same ``JitState`` NamedTuple, every leaf with a
  leading ``[B]`` batch axis (``stack_states`` / ``unstack_state`` convert);
* ``batch_full_forward(tokens [B, n], positions [B, n], valid [B, n])`` —
  one fused program ingests B slot-buffer documents;
* ``batch_apply_edits(state, slot/tok/pos_id/op [B, C])`` — one fused step
  applies up to C typed edits (replace / insert / delete, see the opcodes
  in ``jit_engine``) to EACH of B documents and returns a per-document
  ``overflow [B]`` bool vector. Documents in the batch may have disjoint
  edit buckets (pad unused slots with -1) — including all-empty buckets,
  which leave that document unchanged. The op vector is *data*, so
  replace-, insert- and delete-typed scheduler buckets all share this one
  compiled step — no per-op re-jit;
* ``batch_apply_replaces`` / ``batch_apply_inserts`` / ``batch_apply_deletes``
  — typed conveniences over the same impl.

All documents in a batch must share the capacities ``(n_cap, C, R)`` — the
batch server's capacity buckets guarantee this. With
``use_patch_kernel=True`` the per-layer column patch runs through the
``incr_patch`` Pallas kernel; under vmap its grid gains a leading batch
dimension (one ``(doc, row-block, head)`` cell per grid point), so the
batched step reuses the same kernel as single-document serving.

Exactness: slice b of every batched result equals the single-document
engine run on document b (tested in tests/test_batch_serving.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.serving.jit_engine import JitIncrementalEngine, JitState, KVExport

# A JitState whose every leaf carries a leading [B] document axis.
BatchedJitState = JitState


def stack_states(states: list[JitState]) -> BatchedJitState:
    """Stack per-document states along a new leading batch axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(batched: BatchedJitState, b: int) -> JitState:
    """Slice document ``b`` back out of a batched state."""
    return jax.tree.map(lambda x: x[b], batched)


class BatchedJitEngine(JitIncrementalEngine):
    """vmap'd ``JitIncrementalEngine``: one fixed-shape step, B documents.

    Same constructor as the single-document engine (``edit_capacity``,
    ``row_capacity``, ``use_patch_kernel``, ``_weights``).
    """

    # ------------------------------------------------------------ batched API

    @functools.partial(jax.jit, static_argnums=0)
    def batch_full_forward(self, tokens: jax.Array, positions: jax.Array,
                           valid: Optional[jax.Array] = None
                           ) -> BatchedJitState:
        """tokens/positions: [B, n] int32, valid: [B, n] bool (None = all
        real) → stacked state, leaves [B, ...]."""
        if valid is None:
            return jax.vmap(
                lambda t, p: self._full_forward_impl(t, p))(tokens, positions)
        return jax.vmap(self._full_forward_impl)(tokens, positions, valid)

    @functools.partial(jax.jit, static_argnums=0)
    def batch_apply_edits(
        self, state: BatchedJitState, slot: jax.Array, tok: jax.Array,
        pos_id: jax.Array, op: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """slot/tok/pos_id/op: [B, C] int32 (pad unused slots with -1).
        Returns (new_state, overflow [B] bool). A document whose overflow
        flag is set exceeded its row bucket R at some layer; its slice is
        UNRELIABLE and the caller must re-run a full forward for it (the
        batch server's fallback + capacity-doubling policy)."""
        return jax.vmap(self._apply_edits_impl)(state, slot, tok, pos_id, op)

    def batch_apply_replaces(
        self, state: BatchedJitState, edit_pos: jax.Array, edit_tok: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """Replace-only bucket: edit_pos/edit_tok [B, C] int32 (pad -1)."""
        z = jnp.zeros_like(edit_pos)
        return self.batch_apply_edits(state, edit_pos, edit_tok, z, z)

    def batch_apply_inserts(
        self, state: BatchedJitState, slot: jax.Array, tok: jax.Array,
        pos_id: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """Insert-only bucket: claim free slots with fresh mid-gap ids."""
        from repro.serving.jit_engine import OP_INSERT

        op = jnp.where(slot >= 0, OP_INSERT, 0).astype(slot.dtype)
        return self.batch_apply_edits(state, slot, tok, pos_id, op)

    def batch_apply_deletes(
        self, state: BatchedJitState, slot: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """Delete-only bucket: invalidate slots, subtract their columns."""
        from repro.serving.jit_engine import OP_DELETE

        z = jnp.zeros_like(slot)
        op = jnp.where(slot >= 0, OP_DELETE, 0).astype(slot.dtype)
        return self.batch_apply_edits(state, slot, z, z, op)

    @functools.partial(jax.jit, static_argnums=0)
    def batch_export_kv(self, state: BatchedJitState) -> KVExport:
        """Position-ordered KV export for every document in the batch in one
        fused gather: each ``KVExport`` leaf gains a leading [B] axis.
        Parity-tested against the per-document ``export_kv`` — the batched
        entry point for a future bucket-batched suggestion refresh (the
        current scheduler exports per document as it refreshes)."""
        return jax.vmap(self._export_kv_impl)(state)

    @functools.partial(jax.jit, static_argnums=0)
    def batch_logits_at(self, state: BatchedJitState,
                        index: jax.Array) -> jax.Array:
        """index: [B] int32 per-document slot (the last-in-position-order
        valid slot for padded docs — the host scheduler tracks it)."""
        return jax.vmap(self._logits_at_impl)(state, index)
