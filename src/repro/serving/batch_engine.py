"""Batched dirty-slot serving: the vmapped static-capacity jit engine.

``JitIncrementalEngine`` serves ONE document per dispatch. Under real
traffic (the ROADMAP's millions-of-users setting) many documents have
pending edits at once, and each bucketed step is a small fixed-shape
program — exactly the shape regime where batching pays. This module vmaps
the engine's un-jitted ``*_impl`` methods over a leading document axis:

* ``BatchedJitState`` — the same ``JitState`` NamedTuple, every leaf with a
  leading ``[B]`` batch axis (``stack_states`` / ``unstack_state`` convert);
* ``batch_full_forward(tokens [B, n], positions [B, n])`` — one fused
  program ingests B documents;
* ``batch_apply_replaces(state, edit_pos [B, C], edit_tok [B, C])`` — one
  fused step applies up to C replace-edits to EACH of B documents and
  returns a per-document ``overflow [B]`` bool vector. Documents in the
  batch may have disjoint edit buckets (pad unused slots with -1) —
  including all-empty buckets, which leave that document unchanged.

All documents in a batch must share the capacities ``(n, C, R)`` — the
batch server's capacity buckets guarantee this. With
``use_patch_kernel=True`` the per-layer column patch runs through the
``incr_patch`` Pallas kernel; under vmap its grid gains a leading batch
dimension (one ``(doc, row-block, head)`` cell per grid point), so the
batched step reuses the same kernel as single-document serving.

Exactness: slice b of every batched result equals the single-document
engine run on document b (tested in tests/test_batch_serving.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.serving.jit_engine import JitIncrementalEngine, JitState

# A JitState whose every leaf carries a leading [B] document axis.
BatchedJitState = JitState


def stack_states(states: list[JitState]) -> BatchedJitState:
    """Stack per-document states along a new leading batch axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(batched: BatchedJitState, b: int) -> JitState:
    """Slice document ``b`` back out of a batched state."""
    return jax.tree.map(lambda x: x[b], batched)


class BatchedJitEngine(JitIncrementalEngine):
    """vmap'd ``JitIncrementalEngine``: one fixed-shape step, B documents.

    Same constructor as the single-document engine (``edit_capacity``,
    ``row_capacity``, ``use_patch_kernel``, ``_weights``).
    """

    # ------------------------------------------------------------ batched API

    @functools.partial(jax.jit, static_argnums=0)
    def batch_full_forward(self, tokens: jax.Array,
                           positions: jax.Array) -> BatchedJitState:
        """tokens/positions: [B, n] int32 → stacked state, leaves [B, ...]."""
        return jax.vmap(self._full_forward_impl)(tokens, positions)

    @functools.partial(jax.jit, static_argnums=0)
    def batch_apply_replaces(
        self, state: BatchedJitState, edit_pos: jax.Array, edit_tok: jax.Array,
    ) -> tuple[BatchedJitState, jax.Array]:
        """edit_pos/edit_tok: [B, C] int32 (pad unused slots with -1).
        Returns (new_state, overflow [B] bool). A document whose overflow
        flag is set exceeded its row bucket R at some layer; its slice is
        UNRELIABLE and the caller must re-run a full forward for it (the
        batch server's fallback + capacity-doubling policy)."""
        return jax.vmap(self._apply_replaces_impl)(state, edit_pos, edit_tok)

    @functools.partial(jax.jit, static_argnums=0)
    def batch_logits_at(self, state: BatchedJitState,
                        index: jax.Array) -> jax.Array:
        """index: [B] int32 per-document row (n_real − 1 for padded docs)."""
        return jax.vmap(self._logits_at_impl)(state, index)
