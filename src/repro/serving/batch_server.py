"""Multi-document request scheduler over the batched jit engine.

The serving model (ROADMAP north star: heavy concurrent traffic):

1. clients ``open_document`` (or ``open_documents`` for a fleet) — each
   token buffer is padded up to a power-of-two length bucket ``n_cap``;
   same-bucket documents ingest together through a batched full forward;
2. clients ``submit_replace`` edits, which queue per-document (FIFO);
3. ``step()`` runs ONE scheduling round: documents with pending edits are
   grouped into **capacity buckets** keyed by ``(n_cap, C, R)`` — all shape
   parameters of the jitted step — each group is chunked to ``max_batch``
   documents, each document contributes up to ``C`` queued edits (conflicting
   writes to the same position stay queued for the next round, preserving
   submission order), and one fixed-shape ``batch_apply_replaces`` dispatch
   serves the whole chunk;
4. a document whose per-doc overflow flag trips gets a full-forward
   **fallback** (its batched slice is discarded) and its row capacity ``R``
   doubles — capped at ``n_cap``, at which point overflow is impossible —
   moving it to a bigger bucket whose first dispatch re-jits (the classic
   capacity-doubling / re-jit serving policy).

Scheduler invariants (property-tested in tests/test_batch_scheduler.py):
every submitted edit is applied exactly once; all bucket capacities
(``n_cap``, ``C``, ``R``) are powers of two; per-document FIFO submission
order is preserved, so final token buffers equal the edit-replayed
reference under any interleaving of submits and flushes.

Padding correctness: pad rows sit AFTER every real row, so under causal
attention they never influence a real row; their own (garbage) activations
are maintained but unread. They can consume propagation slots, which only
makes overflow conservative, never wrong.

Known cost: each dispatch stacks members' full ``JitState`` into a batched
pytree and unstacks the result — O(total state size) copies per round, not
O(C). A persistent per-bucket arena (documents resident in stacked arrays,
edits scattered in place) would remove the copies; measured step-only
timings live in ``benchmarks/batch_scaling.run_jit_batched``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.positional import spread_positions
from repro.serving.batch_engine import (
    BatchedJitEngine, stack_states, unstack_state,
)
from repro.serving.jit_engine import JitState


def next_pow2(n: int, minimum: int = 1) -> int:
    c = max(int(minimum), 1)
    while c < n:
        c *= 2
    return c


@dataclass
class BatchStats:
    docs: int = 0
    edits_submitted: int = 0
    edits_applied: int = 0
    batch_steps: int = 0  # batched dispatches issued
    batched_docs: int = 0  # sum of dispatch group sizes
    overflows: int = 0
    full_forwards: int = 0  # ingests + overflow fallbacks
    rejits: int = 0  # distinct dispatch shapes traced

    @property
    def mean_batch(self) -> float:
        return self.batched_docs / max(self.batch_steps, 1)


@dataclass
class _BatchDoc:
    doc_id: str
    tokens: np.ndarray  # [n_cap] int32, host-side source of truth
    n: int  # real length (rows n..n_cap-1 are padding)
    n_cap: int
    row_capacity: int  # per-document R; doubles on overflow
    positions: np.ndarray  # [n_cap] int32
    state: JitState  # device state at padded shape
    pending: deque = field(default_factory=deque)  # FIFO of (pos, tok)


class BatchServer:
    """Replace-edit serving for many documents over one vmapped jit engine."""

    def __init__(self, params: dict, cfg: ArchConfig, *, edit_capacity: int = 8,
                 row_capacity: int = 64, max_batch: int = 8,
                 min_doc_capacity: int = 16, use_patch_kernel: bool = False,
                 pos_pool: Optional[int] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cfg = cfg
        self.C = next_pow2(edit_capacity)
        self.R = next_pow2(row_capacity)
        self.max_batch = max_batch
        self.min_doc_capacity = next_pow2(min_doc_capacity)
        self.use_patch_kernel = use_patch_kernel
        self.pos_pool = pos_pool or (cfg.pos_pool if cfg.pos_pool else cfg.max_seq)
        base = BatchedJitEngine(params, cfg, edit_capacity=self.C,
                                row_capacity=self.R,
                                use_patch_kernel=use_patch_kernel)
        self._weights = base.weights
        self._engines: dict[tuple[int, int], BatchedJitEngine] = {
            (self.C, self.R): base}
        self._shapes_seen: set = set()
        self.docs: dict[str, _BatchDoc] = {}
        self.stats = BatchStats()

    # ------------------------------------------------------------- engines

    def engine(self, edit_capacity: int, row_capacity: int) -> BatchedJitEngine:
        """The per-capacity-bucket engine (cached; shares weight stacks)."""
        key = (edit_capacity, row_capacity)
        if key not in self._engines:
            self._engines[key] = BatchedJitEngine(
                {}, self.cfg, edit_capacity=edit_capacity,
                row_capacity=row_capacity,
                use_patch_kernel=self.use_patch_kernel, _weights=self._weights)
        return self._engines[key]

    def _count_shape(self, shape: tuple) -> None:
        if shape not in self._shapes_seen:
            self._shapes_seen.add(shape)
            self.stats.rejits += 1

    def _padded_batch(self, chunk_len: int) -> int:
        """Dispatch batch sizes are padded up to a power of two (capped at
        ``max_batch``) so each capacity bucket compiles O(log max_batch)
        shapes instead of one per observed group size."""
        return min(next_pow2(chunk_len), self.max_batch)

    # ------------------------------------------------------------- documents

    def open_document(self, doc_id: str, tokens: Sequence[int]) -> None:
        self.open_documents({doc_id: tokens})

    def open_documents(self, items: dict) -> None:
        """Ingest a fleet at once: documents sharing a length bucket are run
        through ONE ``batch_full_forward`` dispatch (chunked like edits)."""
        prepared = []
        for doc_id, tokens in items.items():
            if doc_id in self.docs:
                raise KeyError(f"document {doc_id!r} already open")
            n = len(tokens)
            if n < 1:
                raise ValueError("empty document")
            n_cap = next_pow2(n, self.min_doc_capacity)
            padded = np.zeros(n_cap, np.int32)
            padded[:n] = np.asarray(tokens, np.int32)
            positions = spread_positions(n_cap, self.pos_pool).astype(np.int32)
            prepared.append((doc_id, padded, n, n_cap, positions))
        eng = self.engine(self.C, self.R)
        groups: dict[int, list] = {}
        for p in prepared:
            groups.setdefault(p[3], []).append(p)
        for n_cap, members in sorted(groups.items()):
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                B_pad = self._padded_batch(len(chunk))
                toks = np.stack([c[1] for c in chunk]
                                + [chunk[0][1]] * (B_pad - len(chunk)))
                poss = np.stack([c[4] for c in chunk]
                                + [chunk[0][4]] * (B_pad - len(chunk)))
                bstate = eng.batch_full_forward(jnp.asarray(toks),
                                                jnp.asarray(poss))
                self._count_shape(("full", B_pad, n_cap))
                for b, (doc_id, padded, n, n_cap, positions) in enumerate(chunk):
                    self.docs[doc_id] = _BatchDoc(
                        doc_id=doc_id, tokens=padded, n=n, n_cap=n_cap,
                        row_capacity=min(self.R, n_cap), positions=positions,
                        state=unstack_state(bstate, b))
                    self.stats.docs += 1
                    self.stats.full_forwards += 1

    def submit_replace(self, doc_id: str, pos: int, tok: int) -> None:
        doc = self.docs[doc_id]
        if not 0 <= pos < doc.n:
            raise IndexError(f"pos {pos} out of range for doc of length {doc.n}")
        if not 0 <= tok < self.cfg.vocab:
            raise ValueError(f"token {tok} outside vocab of {self.cfg.vocab}")
        doc.pending.append((int(pos), int(tok)))
        self.stats.edits_submitted += 1

    def pending_count(self) -> int:
        return sum(len(d.pending) for d in self.docs.values())

    # ------------------------------------------------------------- scheduling

    def _take_bucket(self, doc: _BatchDoc) -> tuple[np.ndarray, np.ndarray]:
        """Pop up to C pending edits into a padded (-1) edit bucket. A second
        write to a position already in this bucket stays queued — buckets
        scatter, and only distinct positions keep last-writer order exact.
        Edits to other positions commute with the deferred write, so they
        still ship this round; per-position FIFO order is what matters."""
        edit_pos = np.full(self.C, -1, np.int32)
        edit_tok = np.zeros(self.C, np.int32)
        taken: set[int] = set()
        kept = deque()
        i = 0
        while doc.pending and i < self.C:
            pos, tok = doc.pending.popleft()
            if pos in taken:
                kept.append((pos, tok))  # conflicts queue for the next round,
                continue                 # in submission order
            taken.add(pos)
            edit_pos[i] = pos
            edit_tok[i] = tok
            i += 1
        # unscanned edits were submitted after every kept one; append them
        kept.extend(doc.pending)
        doc.pending.clear()
        doc.pending.extend(kept)
        return edit_pos, edit_tok

    def step(self) -> int:
        """One scheduling round; returns the number of edits applied."""
        ready = [d for d in self.docs.values() if d.pending]
        if not ready:
            return 0
        groups: dict[tuple[int, int, int], list[_BatchDoc]] = {}
        for d in ready:
            groups.setdefault((d.n_cap, self.C, d.row_capacity), []).append(d)
        applied = 0
        for (n_cap, C, R), members in sorted(groups.items()):
            for lo in range(0, len(members), self.max_batch):
                applied += self._dispatch(members[lo:lo + self.max_batch],
                                          n_cap, C, R)
        return applied

    def flush(self) -> int:
        """Drain every queue; returns total edits applied."""
        total = 0
        while self.pending_count():
            total += self.step()
        return total

    def _dispatch(self, chunk: list[_BatchDoc], n_cap: int, C: int,
                  R: int) -> int:
        eng = self.engine(C, R)
        buckets = [self._take_bucket(d) for d in chunk]
        states = [d.state for d in chunk]
        # pad to a pow2 batch with copies of doc 0 carrying empty edit
        # buckets (all -1): a no-op slice whose output is discarded
        B_pad = self._padded_batch(len(chunk))
        padded = buckets + [(np.full(C, -1, np.int32), np.zeros(C, np.int32))
                            ] * (B_pad - len(chunk))
        states += [states[0]] * (B_pad - len(chunk))
        edit_pos = jnp.asarray(np.stack([b[0] for b in padded]))
        edit_tok = jnp.asarray(np.stack([b[1] for b in padded]))
        batched = stack_states(states)
        try:
            new_state, overflow = eng.batch_apply_replaces(batched, edit_pos,
                                                           edit_tok)
            overflow = np.asarray(overflow)
        except Exception:
            # a failed dispatch (OOM, interrupt) must not lose edits: put
            # each taken bucket back at the FRONT of its queue, in order
            for doc, (ep, et) in zip(chunk, buckets):
                doc.pending.extendleft(
                    (int(p), int(t)) for p, t in zip(ep[::-1], et[::-1])
                    if p >= 0)
            raise
        self.stats.batch_steps += 1
        self.stats.batched_docs += len(chunk)
        self._count_shape(("edit", B_pad, n_cap, C, R))
        applied = 0
        for b, doc in enumerate(chunk):
            ep, et = buckets[b]
            n_edits = int((ep >= 0).sum())
            applied += n_edits
            self.stats.edits_applied += n_edits
            doc.tokens[ep[ep >= 0]] = et[ep >= 0]
            if overflow[b]:
                self._fallback_full_forward(doc)
            else:
                doc.state = unstack_state(new_state, b)
        return applied

    def _fallback_full_forward(self, doc: _BatchDoc) -> None:
        """Overflow: discard the unreliable batched slice, recompute from the
        host token buffer, and double the document's row bucket."""
        self.stats.overflows += 1
        eng = self.engine(self.C, self.R)
        doc.state = eng.full_forward(jnp.asarray(doc.tokens),
                                     jnp.asarray(doc.positions))
        self.stats.full_forwards += 1
        self._count_shape(("full", doc.n_cap))
        if doc.row_capacity < doc.n_cap:
            doc.row_capacity = min(doc.row_capacity * 2, doc.n_cap)

    # ------------------------------------------------------------- outputs

    def _flushed(self, doc_id: str) -> _BatchDoc:
        doc = self.docs[doc_id]
        if doc.pending:
            raise RuntimeError(
                f"document {doc_id!r} has {len(doc.pending)} unflushed edits")
        return doc

    def tokens(self, doc_id: str) -> np.ndarray:
        doc = self._flushed(doc_id)
        return doc.tokens[:doc.n].copy()

    def state(self, doc_id: str) -> JitState:
        return self._flushed(doc_id).state

    def logits(self, doc_id: str) -> np.ndarray:
        doc = self._flushed(doc_id)
        eng = self.engine(self.C, self.R)
        return np.asarray(eng.logits_at(doc.state, jnp.int32(doc.n - 1)))
