"""Multi-document request scheduler over the batched jit engine.

The serving model (ROADMAP north star: heavy concurrent traffic):

1. clients ``open_document`` (or ``open_documents`` for a fleet) — each
   document lives in a **slot buffer** padded up to a power-of-two capacity
   ``n_cap``: real tokens occupy slots with a ``valid`` mask and gapped
   position ids (paper §3.3), sequence order is the position-id order, and
   the host keeps the slot↔sequence mapping. Same-bucket documents ingest
   together through a batched full forward;
2. clients submit edits from the FULL algebra — ``submit_replace``,
   ``submit_insert``, ``submit_delete`` (or ``submit_edit`` with a
   ``core.edits.Edit``) — which queue per-document (FIFO) in *sequence*
   coordinates, exactly as an editor emits them;
3. ``step()`` runs ONE scheduling round: each ready document contributes a
   **typed bucket** — the longest same-op FIFO prefix of its queue, up to
   ``C`` edits, translated from sequence coordinates to slots at take time
   (inserts claim a free slot + a mid-gap position id; deletes release
   theirs) — and documents are grouped by ``(n_cap, C, R, op)``. Every
   group chunk is served by ONE fixed-shape ``batch_apply_edits`` dispatch;
   the op vector is data, so replace/insert/delete buckets share a single
   compiled step per ``(B, n_cap, C, R)`` — no per-op re-jit;
4. structural edits have two *scheduled* slow paths, both full-forward
   re-ingests at bucket boundaries: **defrag** when a gap is exhausted
   (position ids re-spread, paper: "akin to defragmentation") and **grow**
   when the slot buffer is full (``n_cap`` doubles — a re-jit at the new
   shape, amortized);
5. a document whose per-doc overflow flag trips gets a full-forward
   **fallback** (its batched slice is discarded) and its row capacity ``R``
   doubles — capped at ``n_cap``, at which point overflow is impossible —
   moving it to a bigger bucket whose first dispatch re-jits (the classic
   capacity-doubling / re-jit serving policy);
6. clients may ``submit_suggest`` a standing **suggestion subscription**:
   each scheduling round keeps a greedy continuation of the document fresh
   through ``repro.serving.suggest.SuggestionEngine`` (KV export + re-prefill
   from the earliest invalidated position, DESIGN.md §5). A newer edit for
   the same document invalidates its pending suggestion; the refresh waits
   until the edits apply and then reuses every cache row before the
   earliest edited position id;
7. with ``mesh=`` (``repro.launch.mesh.make_serving_mesh``) every dispatch
   shards its document axis across the mesh (DESIGN.md §6): batches are
   padded to a multiple of the mesh's batch axis and members are PLACED —
   each shard serves a contiguous row block, so the scheduler assigns
   heavy edit buckets to the lightest block (greedy LPT) and tracks the
   per-device dirty-slot imbalance in ``stats.mean_shard_imbalance``.
   Defrag / grow / overflow-fallback re-ingests and suggestion refreshes
   are per-document host-side slow paths and are untouched by sharding; a
   mesh of size 1 (or ``mesh=None``) is the pre-mesh scheduler bit-for-bit
   (tests/test_sharded_parity.py);
8. document state is a **tiered, budgeted resource** (DESIGN.md §7,
   ``repro.serving.state_store``): with ``device_budget_bytes=`` the fleet
   may exceed device memory — least-recently-touched documents evict to a
   host-RAM snapshot (warm) and, past ``host_budget_bytes=``, to disk
   (cold), then **rehydrate bit-exactly on next touch** (a pure re-upload,
   never a recompute). ``close_document`` ends a session and releases its
   slots, allocator and caches; ``pin``/``unpin`` exempt latency-critical
   documents from eviction; suggestion decode caches count toward the
   budget as soft state (droppable independently — the next refresh
   re-prefills from the KV export). Per-tier byte/doc counts and the
   eviction/rehydration counters live in ``BatchStats``.

Scheduler invariants (property-tested in tests/test_batch_scheduler.py):
every submitted edit is applied exactly once; all bucket capacities
(``n_cap``, ``C``, ``R``) are powers of two; per-document FIFO submission
order is preserved, so final token buffers equal the edit-replayed
reference under any interleaving of submits and flushes. A failed dispatch
(device OOM, interrupt) rolls the affected documents back to their
pre-take snapshots — host mirrors, slot maps, position allocator
(``PositionAllocator.snapshot``/``restore``) and queues — losing nothing.

Padding correctness: free slots are ``valid=False``, so the position-order
causal mask excludes them from every real row's context; their (garbage)
activations are maintained but unread. They can consume propagation slots,
which only makes overflow conservative, never wrong.

Known cost: each dispatch stacks members' full ``JitState`` into a batched
pytree and unstacks the result — O(total state size) copies per round, not
O(C). A persistent per-bucket arena (documents resident in stacked arrays,
edits scattered in place) would remove the copies; measured step-only
timings live in ``benchmarks/batch_scaling.run_jit_batched``.
"""
from __future__ import annotations

import os
from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (
    restore_serving_document, save_serving_document,
)
from repro.common.bucketing import capacity_class, next_pow2
from repro.common.compile_cache import enable_persistent_compilation_cache
from repro.configs.base import ArchConfig
from repro.core.edits import Edit
from repro.core.positional import PositionAllocator
from repro.serving.batch_engine import (
    BatchedJitEngine, stack_states, unstack_state,
)
from repro.serving.latency import LatencyStats
from repro.serving.jit_engine import (
    JitState, OP_DELETE, OP_INSERT, OP_REPLACE, state_from_host,
    state_nbytes_for, state_to_host,
)
from repro.serving.state_store import StateStore
from repro.serving.suggest import (
    PositionHeadroomError, SuggestionEngine, SuggestStats,
)


_OPCODE = {"replace": OP_REPLACE, "insert": OP_INSERT, "delete": OP_DELETE}


def _device_copy(arr: np.ndarray):
    """Move a LIVE host mirror onto the device through an eager host copy.

    jax's CPU backend reads numpy inputs ASYNCHRONOUSLY (and may zero-copy
    them outright) — ``jnp.array``'s copy semantics do not guarantee the
    source buffer is consumed before the call returns. Handing a mutable
    mirror (``doc.tokens`` / ``doc.valid`` / ``doc.positions``) straight to
    ``full_forward`` therefore lets the NEXT take's host-side mutation race
    the deferred device read — observed as a re-ingest that "saw" inserts
    which the following dispatch then applied AGAIN: double-counted
    ``n_real``, garbage columns baked into every row's T, VQ code flips
    (caught by the sharded-serving benchmark's oracle leg). The numpy-level
    ``np.array(..., copy=True)`` completes before returning and the fresh
    buffer is never mutated, so whenever jax actually reads it the content
    is the call-time snapshot. Arrays freshly built per call (``np.stack``
    results) are safe without this."""
    return jnp.asarray(np.array(arr, copy=True))


@dataclass
class BatchStats:
    docs: int = 0
    edits_submitted: int = 0
    edits_applied: int = 0
    batch_steps: int = 0  # batched dispatches issued
    batched_docs: int = 0  # sum of dispatch group sizes
    overflows: int = 0
    full_forwards: int = 0  # ingests + overflow/defrag/grow re-ingests
    defrags: int = 0  # gap exhaustion -> position-id re-spread
    grows: int = 0  # slot buffer full -> capacity-class jump
    device_defrags: int = 0  # defrags served by the device-side
    # gather + re-spread path (no host mirror round-trip; DESIGN.md §9)
    device_grows: int = 0  # grows served by the device-side pad_state path
    # (no full-forward re-ingest — existing slots keep their bits)
    rejits: int = 0  # distinct dispatch shapes traced
    kernel_launches: int = 0  # device program launches on the edit path
    # (edit dispatches, ingests/re-ingests, device pads/gathers) — the
    # per-edit launch budget of the fused hot path
    suggest_refreshes: int = 0  # suggestion recomputes served
    suggest_invalidations: int = 0  # fresh suggestions staled by newer edits
    suggest_cached_hits: int = 0  # suggestions served from the cached
    # continuation without touching the prefill/dispatch path (the
    # watermarks were unchanged since the last refresh)
    # ---- latency SLOs (DESIGN.md §8): per-request admission-to-completion
    # histograms, recorded by the async front end (serving.async_server)
    edit_latency: LatencyStats = field(default_factory=LatencyStats)
    suggest_latency: LatencyStats = field(default_factory=LatencyStats)
    # ---- per-device dispatch balance (mesh>1 serving, DESIGN.md §6)
    sharded_dispatches: int = 0  # dispatches issued over a mesh of size > 1
    shard_imbalance_sum: float = 0.0  # sum over dispatches of (max-min)/max load
    # ---- tiered state residency (state_store, DESIGN.md §7). Byte and doc
    # counters are maintained by the StateStore and reconcile exactly
    # against a recount of the underlying objects
    # (tests/test_state_store.py::test_stats_reconcile).
    closes: int = 0  # close_document calls (docs stays = documents opened)
    bytes_hot: int = 0  # device-resident document states
    bytes_warm: int = 0  # host-RAM snapshots
    bytes_cold: int = 0  # on-disk spills
    bytes_suggest: int = 0  # device-resident suggestion decode caches (soft)
    docs_hot: int = 0
    docs_warm: int = 0
    docs_cold: int = 0
    evictions: int = 0  # hot -> warm demotions
    spills: int = 0  # warm -> cold demotions
    rehydrations: int = 0  # warm/cold -> hot re-uploads (bit-exact)
    rollback_rebuilds: int = 0  # void -> hot full-forward rebuilds (rollback
    # corner: the pre-take copy was consumed by a mid-take re-ingest)
    state_touches: int = 0  # device-state reads routed through the store
    hot_hits: int = 0  # touches served without a rehydration/rebuild
    # ---- cross-process migration (fleet serving, DESIGN.md §11)
    exports: int = 0  # export_document calls (doc handed off to a snapshot)
    imports: int = 0  # import_document calls (doc adopted from a snapshot)

    @property
    def mean_batch(self) -> float:
        return self.batched_docs / max(self.batch_steps, 1)

    @property
    def traced_shapes(self) -> int:
        """Distinct compiled dispatch shapes this server has traced — the
        quantity the ragged capacity classes exist to bound (a long mixed
        stream must stay within a fixed shape budget,
        tests/test_mixed_edit_streams.py). Alias of ``rejits`` under the
        name the benchmarks report."""
        return self.rejits

    @property
    def kernel_launches_per_edit(self) -> float:
        """Edit-path device program launches per applied edit. The fused
        hot path's first-class wall-clock proxy: one launch per dispatch,
        amortized over its whole bucket, with slow paths (re-ingests,
        device pads/gathers) surfacing as fractional overhead."""
        return self.kernel_launches / max(self.edits_applied, 1)

    @property
    def hot_hit_rate(self) -> float:
        """Fraction of device-state touches served from the hot tier — the
        tiered store's first-class benchmarked quantity
        (benchmarks/state_churn.py). 1.0 = the budget never forced a
        rehydration."""
        return self.hot_hits / max(self.state_touches, 1)

    @property
    def mean_shard_imbalance(self) -> float:
        """Mean per-dispatch dirty-slot imbalance across mesh shards:
        0.0 = perfectly balanced, 1.0 = some device received all the work
        while another idled. The scheduler's balanced placement keeps this
        low; it is the first-class benchmarked quantity of sharded serving
        (benchmarks/sharded_serving.py)."""
        return self.shard_imbalance_sum / max(self.sharded_dispatches, 1)


@dataclass
class _BatchDoc:
    doc_id: str
    tokens: np.ndarray  # [n_cap] int32 slot buffer, host-side source of truth
    valid: np.ndarray  # [n_cap] bool
    positions: np.ndarray  # [n_cap] int32 (gapped ids; free slots: sentinel)
    slots: list  # sequence index -> slot (the host's order oracle)
    free: list  # free slot indices
    n_cap: int
    row_capacity: int  # per-document R; doubles on overflow
    allocator: PositionAllocator  # sequence-ordered gapped position ids
    state: Optional[JitState]  # device state at padded shape (None = evicted)
    state_epoch: int = 0  # bumped on every content-CHANGING state replacement
    # (dispatch adoption, re-ingest) but NOT on rehydration, which re-uploads
    # identical bits — the rollback path uses it to tell the two apart
    pending: deque = field(default_factory=deque)  # FIFO of (op, pos, tok)
    n_virtual: int = 0  # length after every queued edit applies
    # ---- suggestion serving (DESIGN.md §5)
    suggestion: Optional[np.ndarray] = None  # last refreshed continuation
    suggest_n: int = 0  # standing request length (0 = no subscription)
    suggest_fresh: bool = False  # suggestion matches the current doc + queue
    suggest_serial: int = 0  # bumped per real refresh (NOT per cached hit);
    # the async front end uses it to detect which subscriptions advanced
    invalid_from: Optional[int] = None  # min pid edited since last refresh
    touched_from: Optional[int] = None  # min pid touched since last ingest

    @property
    def n(self) -> int:  # real length
        return len(self.slots)

    def seq_tokens(self) -> np.ndarray:
        return self.tokens[np.asarray(self.slots, np.int64)]

    def seq_positions(self) -> np.ndarray:
        return self.positions[np.asarray(self.slots, np.int64)]


class BatchServer:
    """Full-edit-algebra serving for many documents over one vmapped engine."""

    def __init__(self, params: dict, cfg: ArchConfig, *, edit_capacity: int = 8,
                 row_capacity: int = 64, max_batch: int = 8,
                 min_doc_capacity: int = 16, use_patch_kernel: bool = False,
                 use_fused_kernel: bool = True,
                 delta_threshold: float = 0.0,
                 capacity_class_step: int = 4, device_grow: bool = True,
                 device_defrag: bool = True,
                 pos_pool: Optional[int] = None, mesh=None,
                 batch_axis: str = "data",
                 device_budget_bytes: Optional[int] = None,
                 host_budget_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 compilation_cache_dir: Optional[str] = None):
        """The fused ragged hot path (DESIGN.md §9) is ON by default:
        ``use_fused_kernel`` routes each layer's patch + requantize through
        one ``fused_step`` Pallas launch; ``capacity_class_step`` spaces the
        document capacity classes (4 = one compiled step serves a 4× range
        of lengths; 2 = the legacy power-of-two lattice); ``device_grow`` /
        ``device_defrag`` serve the structural slow paths on-device
        (``pad_state`` / ``gather_slots``) instead of host re-ingests. Set
        all four to their legacy values (False/2/False/False) to reproduce
        the pre-fused scheduler.

        ``delta_threshold`` is the served tolerance (sigma-delta tier,
        DESIGN.md §10): 0.0 (default) serves bit-exactly like the ungated
        stack; > 0 lets code-flipped rows whose hidden state drifted less
        than the threshold propagate nothing. Suppressed rows always sit at
        position ids >= the earliest edited pid (causal masking — exactly
        the rows the ``invalid_from`` / ``touched_from`` watermarks already
        cover), so suggestion refreshes re-prefill every possibly-drifted
        row through the exact decode path and stay oracle-TOKEN-exact at
        any threshold; only ``logits()`` served straight from engine state
        carries the bounded drift. Every engine this server builds (the
        base engine and each per-(C, R) bucket re-jit) shares the one
        threshold — the served tolerance is a server-level contract, not a
        per-document knob."""
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if capacity_class_step < 2:
            raise ValueError("capacity_class_step must be >= 2")
        # persistent compilation cache (opt-in): per-(B, n_cap, C, R) bucket
        # steps survive process restarts instead of re-tracing + re-compiling
        # on every boot. None still honors $REPRO_COMPILE_CACHE_DIR.
        self.compilation_cache_dir = enable_persistent_compilation_cache(
            compilation_cache_dir)
        self.cfg = cfg
        self.C = next_pow2(edit_capacity)
        self.R = next_pow2(row_capacity)
        self.max_batch = max_batch
        self.min_doc_capacity = next_pow2(min_doc_capacity)
        self.use_patch_kernel = use_patch_kernel
        self.use_fused_kernel = use_fused_kernel
        self.delta_threshold = float(delta_threshold)
        self.capacity_class_step = capacity_class_step
        self.device_grow = device_grow
        self.device_defrag = device_defrag
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.pos_pool = pos_pool or (cfg.pos_pool if cfg.pos_pool else cfg.max_seq)
        base = BatchedJitEngine(params, cfg, edit_capacity=self.C,
                                row_capacity=self.R,
                                use_patch_kernel=use_patch_kernel,
                                use_fused_kernel=use_fused_kernel,
                                delta_threshold=self.delta_threshold,
                                mesh=mesh, batch_axis=batch_axis)
        if base.n_shards > max_batch:
            raise ValueError(
                f"serving mesh batch axis of {base.n_shards} exceeds "
                f"max_batch={max_batch} — every dispatch must give each "
                "device at least one document row")
        if max_batch % base.n_shards != 0:
            raise ValueError(
                f"max_batch={max_batch} is not a multiple of the serving "
                f"mesh's {base.n_shards}-way batch axis — a full chunk "
                "would pad past the max_batch cap")
        self.n_shards = base.n_shards
        self._weights = base.weights
        self._engines: dict[tuple[int, int], BatchedJitEngine] = {
            (self.C, self.R): base}
        self._shapes_seen: set = set()
        self.docs: dict[str, _BatchDoc] = {}
        self.stats = BatchStats()
        self._sugg: Optional[SuggestionEngine] = None
        self._params = params
        # streaming hook (serving.async_server): when set, every REAL
        # suggestion refresh calls ``on_suggest_token(doc_id, serial, token)``
        # per decoded token, as the decode loop produces it — cached-hit
        # fast paths do not re-stream tokens the subscriber already has
        self.on_suggest_token = None
        # True while step() is inside its take/dispatch section: host mirrors
        # of a peeled document run AHEAD of its device state there, so
        # snapshots the store captures mid-round are flagged inconsistent
        # (in-process rehydration is unaffected; fleet failover refuses to
        # adopt them and falls back to re-opening from tokens, DESIGN.md §11)
        self._in_round = False
        # tiered residency (DESIGN.md §7): budget=None still tracks bytes
        # and tiers — accounting is always on, eviction only under a budget
        self.store = StateStore(
            docs=self.docs, stats=self.stats,
            drop_suggest=self._drop_suggest_cache, reingest=self._reingest,
            device_budget_bytes=device_budget_bytes,
            host_budget_bytes=host_budget_bytes, spill_dir=spill_dir,
            in_round=lambda: self._in_round)

    def _drop_suggest_cache(self, doc_id: str) -> None:
        """Release one document's suggestion decode cache (the store's
        soft-state reclamation hook; the suggester's listener reports the
        freed bytes back to the store)."""
        if self._sugg is not None:
            self._sugg.drop(doc_id)

    @property
    def suggester(self) -> SuggestionEngine:
        """The (lazily built) suggestion engine shared by every document.
        Its per-document decode caches report their device bytes to the
        state store — soft state under the serving budget."""
        if self._sugg is None:
            self._sugg = SuggestionEngine(
                self._params, self.cfg,
                on_cache_bytes=self.store.note_suggest_bytes)
        return self._sugg

    @property
    def suggest_stats(self) -> SuggestStats:
        return self.suggester.stats

    # ------------------------------------------------------------- engines

    def engine(self, edit_capacity: int, row_capacity: int) -> BatchedJitEngine:
        """The per-capacity-bucket engine (cached; shares weight stacks and
        the serving mesh)."""
        key = (edit_capacity, row_capacity)
        if key not in self._engines:
            self._engines[key] = BatchedJitEngine(
                {}, self.cfg, edit_capacity=edit_capacity,
                row_capacity=row_capacity,
                use_patch_kernel=self.use_patch_kernel,
                use_fused_kernel=self.use_fused_kernel,
                delta_threshold=self.delta_threshold, mesh=self.mesh,
                batch_axis=self.batch_axis, _weights=self._weights)
        return self._engines[key]

    def _count_shape(self, shape: tuple) -> None:
        if shape not in self._shapes_seen:
            self._shapes_seen.add(shape)
            self.stats.rejits += 1

    def padded_cap(self, n: int) -> int:
        """The capacity class serving an ``n``-slot document: the smallest
        ``min_doc_capacity * step^k >= n``. All documents in a class share
        one padded shape — and therefore one compiled step per (B, C, R) —
        with valid/n_real masks carrying the real length (ragged
        execution, DESIGN.md §9)."""
        return capacity_class(n, self.min_doc_capacity,
                              self.capacity_class_step)

    def _padded_batch(self, chunk_len: int) -> int:
        """Dispatch batch sizes are padded up to a power of two (capped at
        ``max_batch``) so each capacity bucket compiles O(log max_batch)
        shapes instead of one per observed group size — then rounded up to a
        multiple of the serving mesh's batch axis, the shard_map divisibility
        contract (each device takes a contiguous ``B_pad / n_shards`` block
        of document rows)."""
        b = min(next_pow2(chunk_len), self.max_batch)
        n = self.n_shards
        b = max(b, n)
        return -(-b // n) * n

    def _place_rows(self, weights: list, B_pad: int) -> tuple[list, list]:
        """Balanced placement of dispatch members onto the padded batch rows.

        Each mesh shard serves the contiguous row block
        ``[s*B_pad/n, (s+1)*B_pad/n)``, so WHERE a document lands decides
        which device does its dirty-slot work. Greedy longest-processing-time
        assignment: heaviest bucket first onto the lightest non-full shard —
        the classic 4/3-approximation to makespan, plenty for C-bounded
        bucket weights. Returns ``(rows, loads)``: ``rows[r]`` is the member
        index occupying padded row ``r`` (None = filler row carrying an
        empty edit bucket), ``loads[s]`` the per-shard dirty-slot totals.
        With a single shard the placement is the identity — the pre-mesh
        dispatch layout, bit-for-bit."""
        n = self.n_shards
        if n == 1:
            rows = list(range(len(weights)))
            rows += [None] * (B_pad - len(weights))
            return rows, [sum(weights)]
        per = B_pad // n
        blocks: list[list] = [[] for _ in range(n)]
        loads = [0] * n
        order = sorted(range(len(weights)), key=lambda i: (-weights[i], i))
        for i in order:
            s = min((j for j in range(n) if len(blocks[j]) < per),
                    key=lambda j: (loads[j], len(blocks[j]), j))
            blocks[s].append(i)
            loads[s] += weights[i]
        rows = []
        for blk in blocks:
            rows.extend(blk)
            rows.extend([None] * (per - len(blk)))
        return rows, loads

    def _note_balance(self, loads: list) -> None:
        if self.n_shards > 1:
            self.stats.sharded_dispatches += 1
            hi = max(loads)
            self.stats.shard_imbalance_sum += (hi - min(loads)) / max(hi, 1)

    @property
    def _pos_sentinel(self) -> int:
        # Free slots point at the last pool embedding: always in-bounds for
        # the gather, >= every allocated id, and masked out by valid anyway.
        return self.pos_pool - 1

    # ------------------------------------------------------------- documents

    def open_document(self, doc_id: str, tokens: Sequence[int]) -> None:
        self.open_documents({doc_id: tokens})

    def open_documents(self, items: dict) -> None:
        """Ingest a fleet at once: documents sharing a capacity bucket are
        run through ONE ``batch_full_forward`` dispatch (chunked like
        edits)."""
        prepared = []
        for doc_id, tokens in items.items():
            if doc_id in self.docs:
                raise KeyError(f"document {doc_id!r} already open")
            n = len(tokens)
            if n < 1:
                raise ValueError("empty document")
            toks = np.asarray(tokens, np.int32)
            if toks.size and not (0 <= toks.min() and toks.max() < self.cfg.vocab):
                raise ValueError(
                    f"document {doc_id!r} has tokens outside vocab of "
                    f"{self.cfg.vocab}")
            n_cap = self.padded_cap(n)
            alloc = PositionAllocator(n, self.pos_pool)
            padded = np.zeros(n_cap, np.int32)
            padded[:n] = toks
            valid = np.zeros(n_cap, bool)
            valid[:n] = True
            positions = np.full(n_cap, self._pos_sentinel, np.int32)
            positions[:n] = alloc.snapshot()
            prepared.append((doc_id, padded, valid, positions, n, n_cap, alloc))
        eng = self.engine(self.C, self.R)
        groups: dict[int, list] = {}
        for p in prepared:
            groups.setdefault(p[5], []).append(p)
        for n_cap, members in sorted(groups.items()):
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                B_pad = self._padded_batch(len(chunk))
                # admission control BEFORE the ingest dispatch: evict LRU
                # residents (suggestion caches first, then hot states) until
                # the chunk's states fit the device budget
                self.store.admit(
                    len(chunk) * state_nbytes_for(n_cap, eng.L, eng.meta))
                # ingest work scales with real length: balance it per shard
                rows, loads = self._place_rows([c[4] for c in chunk], B_pad)
                row_of = [chunk[i] if i is not None else chunk[0] for i in rows]
                toks = np.stack([c[1] for c in row_of])
                vals = np.stack([c[2] for c in row_of])
                poss = np.stack([c[3] for c in row_of])
                bstate = eng.batch_full_forward(
                    jnp.asarray(toks), jnp.asarray(poss), jnp.asarray(vals))
                self._count_shape(("full", B_pad, n_cap))
                self.stats.kernel_launches += 1
                self._note_balance(loads)
                for b, i in enumerate(rows):
                    if i is None:
                        continue
                    doc_id, padded, valid, positions, n, n_cap, alloc = chunk[i]
                    doc = _BatchDoc(
                        doc_id=doc_id, tokens=padded, valid=valid,
                        positions=positions, slots=list(range(n)),
                        free=list(range(n_cap - 1, n - 1, -1)), n_cap=n_cap,
                        row_capacity=min(self.R, n_cap), allocator=alloc,
                        state=unstack_state(bstate, b), n_virtual=n)
                    self.docs[doc_id] = doc
                    self.store.register(doc)
                    self.stats.docs += 1
                    self.stats.full_forwards += 1

    def close_document(self, doc_id: str) -> None:
        """End a session: release the document's slot rows, allocator,
        device/warm/cold state and suggestion caches. The inverse of
        ``open_document`` — leak-free under open→edit→close churn
        (tests/test_state_store.py::test_close_document_no_leak). Pending
        (unflushed) edits are discarded with the session."""
        doc = self.docs.pop(doc_id)  # KeyError for unknown ids
        self._drop_suggest_cache(doc_id)  # listener zeroes its byte account
        self.store.close(doc)
        doc.pending.clear()
        doc.suggestion = None
        self.stats.closes += 1

    def pin(self, doc_id: str) -> None:
        """Exempt a latency-critical document from eviction (rehydrating it
        now if needed, so a pinned document is always dispatch-ready). Its
        suggestion decode cache stays evictable — soft state."""
        if doc_id not in self.docs:
            raise KeyError(doc_id)
        self.store.pin(doc_id)

    def unpin(self, doc_id: str) -> None:
        self.store.unpin(doc_id)

    def evict(self, doc_id: str, tier: str = "warm") -> str:
        """Force-demote a document's state to ``"warm"`` (host RAM) or
        ``"cold"`` (disk). Its next touch — an edit dispatch, suggestion
        refresh or logits read — rehydrates it transparently and
        bit-exactly. Mostly a test/benchmark hook; production eviction is
        the budget's job. Returns the resulting tier."""
        return self.store.demote(self.docs[doc_id], tier)

    def tier(self, doc_id: str) -> str:
        """Residency tier of an open document: "hot", "warm" or "cold"."""
        if doc_id not in self.docs:
            raise KeyError(doc_id)
        return self.store.tier(doc_id)

    # ------------------------------------------------------------- submits

    def _check_tok(self, tok: int) -> None:
        if not 0 <= tok < self.cfg.vocab:
            raise ValueError(f"token {tok} outside vocab of {self.cfg.vocab}")

    def _stale(self, doc: _BatchDoc) -> None:
        """A newer edit for the document invalidates its suggestion."""
        if doc.suggest_fresh:
            doc.suggest_fresh = False
            self.stats.suggest_invalidations += 1

    def _touch(self, doc: _BatchDoc, pid: int) -> None:
        """Record an applied edit's position id in the invalidation
        watermarks (earliest-invalidated-position tracking, DESIGN.md §5).
        The same watermark covers sigma-delta-suppressed columns
        (``delta_threshold > 0``): causal masking confines every propagated
        OR suppressed row to position ids >= the earliest edited pid, so
        the min-over-edited-pids here is already the min over
        possibly-drifted rows (DESIGN.md §10)."""
        pid = int(pid)
        doc.invalid_from = (pid if doc.invalid_from is None
                            else min(doc.invalid_from, pid))
        doc.touched_from = (pid if doc.touched_from is None
                            else min(doc.touched_from, pid))

    def submit_replace(self, doc_id: str, pos: int, tok: int) -> None:
        doc = self.docs[doc_id]
        if not 0 <= pos < doc.n_virtual:
            raise IndexError(
                f"pos {pos} out of range for doc of length {doc.n_virtual}")
        self._check_tok(tok)
        doc.pending.append(("replace", int(pos), int(tok)))
        self._stale(doc)
        self.stats.edits_submitted += 1

    def submit_insert(self, doc_id: str, pos: int, tok: int) -> None:
        """Insert ``tok`` before sequence index ``pos`` (``pos == n``
        appends). Positions refer to the sequence state after every
        previously queued edit applies, exactly like an edit script."""
        doc = self.docs[doc_id]
        if not 0 <= pos <= doc.n_virtual:
            raise IndexError(
                f"insert pos {pos} out of range for doc of length {doc.n_virtual}")
        self._check_tok(tok)
        doc.pending.append(("insert", int(pos), int(tok)))
        doc.n_virtual += 1
        self._stale(doc)
        self.stats.edits_submitted += 1

    def submit_delete(self, doc_id: str, pos: int) -> None:
        doc = self.docs[doc_id]
        if not 0 <= pos < doc.n_virtual:
            raise IndexError(
                f"delete pos {pos} out of range for doc of length {doc.n_virtual}")
        if doc.n_virtual <= 1:
            raise ValueError("cannot delete the last remaining token")
        doc.pending.append(("delete", int(pos), 0))
        doc.n_virtual -= 1
        self._stale(doc)
        self.stats.edits_submitted += 1

    def submit_edit(self, doc_id: str, e: Edit) -> None:
        """Submit a ``core.edits.Edit`` (op/pos/token) as queued traffic."""
        if e.op == "replace":
            self.submit_replace(doc_id, e.pos, e.token)
        elif e.op == "insert":
            self.submit_insert(doc_id, e.pos, e.token)
        else:
            self.submit_delete(doc_id, e.pos)

    def pending_count(self) -> int:
        return sum(len(d.pending) for d in self.docs.values())

    # ------------------------------------------------------- snapshot/rollback

    def _snapshot(self, doc: _BatchDoc) -> tuple:
        return (doc.tokens.copy(), doc.valid.copy(), doc.positions.copy(),
                list(doc.slots), list(doc.free), doc.n_cap, doc.row_capacity,
                doc.allocator.snapshot(), doc.state, doc.state_epoch,
                deque(doc.pending), doc.n_virtual, doc.invalid_from,
                doc.touched_from, doc.suggest_fresh)

    def _restore(self, doc: _BatchDoc, snap: tuple) -> None:
        (doc.tokens, doc.valid, doc.positions, doc.slots, doc.free, doc.n_cap,
         doc.row_capacity, alloc_ids, state, epoch, doc.pending,
         doc.n_virtual, doc.invalid_from, doc.touched_from,
         doc.suggest_fresh) = snap
        doc.allocator.restore(alloc_ids)
        # Device-state rollback is residency-aware and NEVER raises (the
        # except path restores many docs in a row — one failure must not
        # strand the rest). Three cases:
        # 1. epoch unchanged — the device-state CONTENT was never replaced
        #    (at most evicted and/or rehydrated, both bit-preserving), and
        #    the store's accounting already matches wherever it lives now;
        # 2. a mid-take re-ingest (grow/defrag) replaced the content, but
        #    the snapshot still references the exact pre-take state —
        #    re-adopt it (the store recounts bytes and discards the
        #    superseded copy);
        # 3. the doc entered the take evicted (snapshot state is None) and a
        #    mid-take re-ingest consumed its warm/cold copy — the restored
        #    mirrors are the only source of truth. Mark the doc void: the
        #    next touch rebuilds it with a full forward (the same semantics
        #    as any re-ingest slow path), where admission/device failures
        #    are ordinary and recoverable.
        if epoch == doc.state_epoch:
            pass
        elif state is not None:
            self.store.set_hot(doc, state)
        else:
            self.store.mark_void(doc)

    # ------------------------------------------------------------- scheduling

    def _take_bucket(self, doc: _BatchDoc):
        """Pop the longest same-op FIFO prefix (up to C) into a typed edit
        bucket, translating sequence coordinates to slots as each edit is
        peeled — so every queued position means "the sequence as all earlier
        edits left it", matching edit-script semantics. Host mirrors
        (tokens/valid/positions/slot map/allocator) are updated here; the
        device catches up at dispatch. Returns (op_kind, arrays, count)."""
        kind = doc.pending[0][0]
        slot_a = np.full(self.C, -1, np.int32)
        tok_a = np.zeros(self.C, np.int32)
        pos_a = np.zeros(self.C, np.int32)
        op_a = np.full(self.C, _OPCODE[kind], np.int32)
        i = 0
        if kind == "replace":
            # Same-slot conflicts stay queued for the next round (a scatter
            # bucket holds one write per slot; distinct-slot replaces
            # commute, so later ones may still ship this round). Scanning
            # stops at the first structural op — replaces do NOT commute
            # across an insert/delete.
            taken: set[int] = set()
            kept: list = []
            while doc.pending and i < self.C:
                if doc.pending[0][0] != "replace":
                    break
                _, pos, tok = doc.pending.popleft()
                s = doc.slots[pos]
                if s in taken:
                    kept.append(("replace", pos, tok))
                    continue
                taken.add(s)
                slot_a[i] = s
                tok_a[i] = tok
                pos_a[i] = doc.positions[s]
                doc.tokens[s] = tok
                self._touch(doc, doc.positions[s])
                i += 1
            for item in reversed(kept):
                doc.pending.appendleft(item)
        elif kind == "insert":
            while doc.pending and i < self.C:
                if doc.pending[0][0] != "insert":
                    break
                _, pos, tok = doc.pending[0]
                need_grow = not doc.free
                need_defrag = not doc.allocator.can_insert_at(pos)
                if need_grow or need_defrag:
                    if i > 0:
                        break  # flush the partial bucket first; the re-ingest
                    if need_grow:  # below rebuilds device state from hosts
                        self._grow(doc)
                    if need_defrag:
                        self._defrag(doc)
                    if not doc.allocator.can_insert_at(pos):
                        raise RuntimeError(
                            f"position pool of {doc.allocator.pool_size} cannot "
                            f"host a document of length {doc.n + 1}")
                doc.pending.popleft()
                pid = doc.allocator.insert_at(pos)
                s = doc.free.pop()
                doc.slots.insert(pos, s)
                doc.tokens[s] = tok
                doc.valid[s] = True
                doc.positions[s] = pid
                slot_a[i] = s
                tok_a[i] = tok
                pos_a[i] = pid
                self._touch(doc, pid)
                i += 1
        else:  # delete
            while doc.pending and i < self.C:
                if doc.pending[0][0] != "delete":
                    break
                _, pos, _tok = doc.pending.popleft()
                s = doc.slots.pop(pos)
                doc.allocator.delete_at(pos)
                doc.valid[s] = False
                pos_a[i] = doc.positions[s]
                slot_a[i] = s
                doc.free.append(s)  # earliest reuse is the NEXT dispatch
                self._touch(doc, doc.positions[s])
                i += 1
        return kind, (slot_a, tok_a, pos_a, op_a), i

    def step(self) -> int:
        """One scheduling round: edit dispatches, then stale suggestion
        refreshes. Returns the number of edits applied."""
        ready = [d for d in self.docs.values() if d.pending]
        if not ready:
            self._refresh_suggestions()
            return 0
        takes = []  # (doc, kind, arrays, count)
        undone: dict[int, tuple] = {}  # id(doc) -> (doc, snapshot)
        applied = 0
        self._in_round = True
        try:
            for d in ready:
                snap = self._snapshot(d)
                undone[id(d)] = (d, snap)
                kind, arrays, count = self._take_bucket(d)
                if count == 0:
                    self._restore(d, snap)
                    undone.pop(id(d))
                    continue
                takes.append((d, kind, arrays, count))
            groups: dict[tuple, list] = {}
            for t in takes:
                groups.setdefault(
                    (t[0].n_cap, self.C, t[0].row_capacity, t[1]),
                    []).append(t)
            for (n_cap, C, R, kind), members in sorted(groups.items(),
                                                       key=lambda kv: kv[0]):
                for lo in range(0, len(members), self.max_batch):
                    chunk = members[lo:lo + self.max_batch]
                    applied += self._dispatch(chunk, n_cap, C, R, kind)
                    for t in chunk:
                        undone.pop(id(t[0]), None)
        except Exception:
            # a failed take (pool exhausted mid-bucket) or dispatch (device
            # OOM, interrupt) must not lose edits: every doc not yet served
            # rolls back to its pre-take snapshot (host mirrors, slot map,
            # allocator ids, queue — its device state was never replaced)
            for d, snap in undone.values():
                self._restore(d, snap)
            raise
        finally:
            self._in_round = False
        self._refresh_suggestions()
        return applied

    def flush(self) -> int:
        """Drain every queue; returns total edits applied. Stale suggestion
        subscriptions are refreshed too — also when there were no edits to
        drain (the subscribe-then-flush flow)."""
        total = 0
        while self.pending_count():
            total += self.step()
        self._refresh_suggestions()  # no-op when every subscription is fresh
        return total

    def _dispatch(self, chunk: list, n_cap: int, C: int, R: int,
                  kind: str) -> int:
        eng = self.engine(C, R)
        docs = [t[0] for t in chunk]
        buckets = [t[2] for t in chunk]
        counts = [t[3] for t in chunk]
        # transparent rehydration on touch: every chunk member must be hot
        # for the stacked dispatch — warm/cold members re-upload their
        # snapshots (bit-exact), protected from each other's admissions
        keep = frozenset(d.doc_id for d in docs)
        for d in docs:
            self.store.ensure_hot(d, keep=keep)
        # pad to a pow2 batch (multiple of the mesh's batch axis) with copies
        # of doc 0 carrying empty edit buckets (all -1): no-op slices whose
        # output is discarded. Members are placed to balance dirty-slot work
        # across the contiguous per-shard row blocks.
        B_pad = self._padded_batch(len(chunk))
        rows, loads = self._place_rows(counts, B_pad)
        empty = (np.full(C, -1, np.int32), np.zeros(C, np.int32),
                 np.zeros(C, np.int32), np.zeros(C, np.int32))
        row_buckets = [buckets[i] if i is not None else empty for i in rows]
        states = [docs[i].state if i is not None else docs[0].state
                  for i in rows]
        slot = jnp.asarray(np.stack([b[0] for b in row_buckets]))
        tok = jnp.asarray(np.stack([b[1] for b in row_buckets]))
        pos = jnp.asarray(np.stack([b[2] for b in row_buckets]))
        batched = stack_states(states)
        if kind == "replace":
            new_state, overflow = eng.batch_apply_replaces(batched, slot, tok)
        elif kind == "insert":
            new_state, overflow = eng.batch_apply_inserts(batched, slot, tok,
                                                          pos)
        else:
            new_state, overflow = eng.batch_apply_deletes(batched, slot)
        overflow = np.asarray(overflow)
        self.stats.batch_steps += 1
        self.stats.batched_docs += len(chunk)
        # all three op kinds share one compiled step per (B, n_cap, C, R):
        # the op vector is data, so `kind` is NOT part of the traced shape
        self._count_shape(("edit", B_pad, n_cap, C, R))
        self.stats.kernel_launches += 1
        self._note_balance(loads)
        applied = 0
        for b, i in enumerate(rows):
            if i is None:
                continue
            doc = docs[i]
            applied += counts[i]
            self.stats.edits_applied += counts[i]
            if overflow[b]:
                self._fallback_full_forward(doc)
            else:
                self.store.set_hot(doc, unstack_state(new_state, b))
        return applied

    # ------------------------------------------------------------ slow paths

    def _reingest(self, doc: _BatchDoc) -> None:
        """Rebuild device state from the host mirrors (one full forward)."""
        eng = self.engine(self.C, self.R)
        # admit the replacement state up front (a grown buffer is bigger
        # than the one it replaces; an evicted doc brings wholly new bytes)
        new_bytes = state_nbytes_for(doc.n_cap, eng.L, eng.meta)
        resident = (self.store.nbytes(doc.doc_id)
                    if self.store.tier(doc.doc_id) == "hot" else 0)
        self.store.admit(max(new_bytes - resident, 0),
                         keep=frozenset((doc.doc_id,)))
        state = eng.full_forward(_device_copy(doc.tokens),
                                 _device_copy(doc.positions),
                                 _device_copy(doc.valid))
        self.store.set_hot(doc, state)
        # the state is a from-scratch full forward again: every exported
        # column is trustworthy for suggestion KV reuse
        doc.touched_from = None
        self.stats.full_forwards += 1
        self.stats.kernel_launches += 1
        self._count_shape(("full", doc.n_cap))

    def _fallback_full_forward(self, doc: _BatchDoc) -> None:
        """Overflow: discard the unreliable batched slice, recompute from the
        host mirrors, and double the document's row bucket."""
        self.stats.overflows += 1
        self._reingest(doc)
        if doc.row_capacity < doc.n_cap:
            doc.row_capacity = min(doc.row_capacity * 2, doc.n_cap)

    def _grow(self, doc: _BatchDoc) -> None:
        """Slot buffer full: step ``n_cap`` up to the next capacity class
        (slots keep their indices, new free slots appended). With
        ``device_grow`` the resident state is padded ON DEVICE
        (``pad_state``: appended slots are invalid with sentinel positions
        and zero activations, exactly the shape every masked step already
        ignores) — no full forward, and the incremental attention history
        survives, so ``touched_from`` is deliberately NOT cleared. The first
        dispatch in the bigger class re-jits — amortized across the
        fleet."""
        old_cap, new_cap = doc.n_cap, self.padded_cap(doc.n_cap + 1)
        for name, fill in (("tokens", 0), ("valid", False),
                           ("positions", self._pos_sentinel)):
            arr = getattr(doc, name)
            grown = np.full(new_cap, fill, arr.dtype)
            grown[:old_cap] = arr
            setattr(doc, name, grown)
        doc.free.extend(range(new_cap - 1, old_cap - 1, -1))
        doc.n_cap = new_cap
        self.stats.grows += 1
        if self._sugg is not None:  # capacity changed: cache shape unusable
            self._sugg.drop(doc.doc_id)
        if not self.device_grow:
            self._reingest(doc)
            return
        eng = self.engine(self.C, self.R)
        state = self.store.ensure_hot(doc, keep=frozenset((doc.doc_id,)))
        self.store.admit(
            state_nbytes_for(new_cap, eng.L, eng.meta)
            - state_nbytes_for(old_cap, eng.L, eng.meta),
            keep=frozenset((doc.doc_id,)))
        new_state = eng.pad_state(state, new_cap,
                                  pos_fill=self._pos_sentinel)
        self.store.set_hot(doc, new_state)
        self.stats.device_grows += 1
        self.stats.kernel_launches += 1
        self._count_shape(("pad", old_cap, new_cap))

    def _defrag(self, doc: _BatchDoc) -> None:
        """Gap exhaustion: re-spread every position id evenly (paper §3.3,
        "akin to defragmentation"). Every cached activation depends on its
        position embedding, so the full forward is unavoidable — but with
        ``device_defrag`` the slot compaction that precedes it runs ON
        DEVICE (``gather_slots`` permutes the resident buffers into
        sequence order) instead of shipping token mirrors through host
        memory, and the compacted layout feeds the SAME compiled
        ``full_forward`` a re-ingest would run — bitwise-identical output
        by construction (tested against the host re-ingest oracle in
        tests/test_fused_step.py)."""
        self.stats.defrags += 1
        if self._sugg is not None:  # every position id changed: nothing in
            self._sugg.drop(doc.doc_id)  # the doc's decode cache is reusable
        doc.invalid_from = 0
        self._stale(doc)
        if not self.device_defrag:
            doc.allocator.defragment()
            doc.positions[np.asarray(doc.slots, np.int64)] = \
                doc.allocator.snapshot()
            self._reingest(doc)
            return
        eng = self.engine(self.C, self.R)
        state = self.store.ensure_hot(doc, keep=frozenset((doc.doc_id,)))
        n = doc.n
        # compaction permutation: live slots in sequence order first, then
        # the free tail — slot i of the permuted buffers is token i of the
        # document, so the re-spread ids land 1:1
        order = np.concatenate([np.asarray(doc.slots, np.int32),
                                np.asarray(doc.free, np.int32)])
        doc.allocator.defragment()
        respread = doc.allocator.snapshot()
        permuted = eng.gather_slots(state, jnp.asarray(order))
        new_positions = np.full(doc.n_cap, self._pos_sentinel, np.int32)
        new_positions[:n] = respread
        new_valid = np.zeros(doc.n_cap, bool)
        new_valid[:n] = True
        new_state = eng.full_forward(permuted.tokens,
                                     _device_copy(new_positions),
                                     _device_copy(new_valid))
        self.store.set_hot(doc, new_state)
        # host mirrors follow the compaction so slot indices keep matching
        doc.tokens = doc.tokens[order]
        doc.valid = new_valid
        doc.positions = new_positions
        doc.slots = list(range(n))
        doc.free = list(range(doc.n_cap - 1, n - 1, -1))
        doc.touched_from = None
        self.stats.device_defrags += 1
        self.stats.full_forwards += 1
        self.stats.kernel_launches += 2
        self._count_shape(("full", doc.n_cap))

    # ------------------------------------------------------------ suggestions

    def submit_suggest(self, doc_id: str, n_new: int = 8) -> None:
        """Open a standing suggestion subscription: after every scheduling
        round, the document's greedy ``n_new``-token continuation is kept
        fresh (refreshed whenever edits made it stale, reusing every cache
        row before the earliest invalidated position). Cancel with
        ``cancel_suggest``."""
        doc = self.docs[doc_id]
        if n_new < 1:
            raise ValueError("n_new must be >= 1")
        if doc.suggest_n != n_new:
            doc.suggest_n = int(n_new)
            doc.suggest_fresh = False

    def cancel_suggest(self, doc_id: str) -> None:
        doc = self.docs[doc_id]
        doc.suggest_n = 0
        doc.suggestion = None
        doc.suggest_fresh = False

    def suggestion(self, doc_id: str) -> Optional[np.ndarray]:
        """The last refreshed continuation, or None while it is stale
        (a newer edit arrived and the next round has not served it yet)."""
        doc = self.docs[doc_id]
        return doc.suggestion.copy() if doc.suggest_fresh else None

    def suggest(self, doc_id: str, n_new: int = 8) -> np.ndarray:
        """Flush the document's pending edits and return a fresh greedy
        continuation (subscribing the document if it was not already).

        Redundant-refresh fast path: when nothing changed since the last
        refresh (no pending edits, ``invalid_from`` watermark clear) and the
        cached continuation covers ``n_new``, the cached tokens are returned
        WITHOUT re-entering the prefill/dispatch path — greedy decoding is
        deterministic, so an unchanged document has an unchanged
        continuation (regression-tested by
        tests/test_async_server.py::test_back_to_back_suggest_no_redispatch).
        """
        if n_new < 1:
            raise ValueError("n_new must be >= 1")
        doc = self.docs[doc_id]
        if (not doc.pending and doc.suggest_fresh and doc.invalid_from is None
                and doc.suggestion is not None
                and len(doc.suggestion) >= n_new):
            self.stats.suggest_cached_hits += 1
            return doc.suggestion[:n_new].copy()
        self.submit_suggest(doc_id, n_new)
        self.flush()
        if not doc.suggest_fresh:
            self._refresh_doc(doc)
        return doc.suggestion.copy()

    def _refresh_suggestions(self) -> None:
        """Serve stale suggestion subscriptions, grouped by capacity bucket
        (the same grouping the edit dispatcher uses, so refreshes ride the
        scheduling round). A document with queued edits stays stale — its
        pending suggestion was invalidated by the newer edits and refreshes
        only after they apply."""
        ready = [d for d in self.docs.values()
                 if d.suggest_n > 0 and not d.suggest_fresh and not d.pending]
        for doc in sorted(ready, key=lambda d: (d.n_cap, d.doc_id)):
            self._refresh_doc(doc)

    def _refresh_doc(self, doc: _BatchDoc) -> None:
        # Redundant-refresh fast path: the document's content watermarks are
        # unchanged since the suggestion it already holds (``invalid_from``
        # clear), so the deterministic greedy continuation cannot differ —
        # serve the cached tokens without any prefill/dispatch. Reached e.g.
        # by a re-subscription at an unchanged-or-shorter length.
        if (doc.invalid_from is None and doc.suggestion is not None
                and len(doc.suggestion) >= doc.suggest_n):
            doc.suggestion = doc.suggestion[:doc.suggest_n]
            doc.suggest_fresh = True
            self.stats.suggest_cached_hits += 1
            return
        sugg = self.suggester
        eng = self.engine(self.C, self.R)
        self.store.ensure_hot(doc)  # KV export reads the device state
        on_token = None
        if self.on_suggest_token is not None:
            serial, hook = doc.suggest_serial + 1, self.on_suggest_token

            def on_token(tok, _id=doc.doc_id, _serial=serial, _hook=hook):
                _hook(_id, _serial, int(np.asarray(tok).reshape(-1)[0]))
        try:
            toks = sugg.refresh(
                eng, doc.state, key=doc.doc_id, n_new=doc.suggest_n,
                invalid_from=doc.invalid_from,
                export_invalid_from=doc.touched_from, on_token=on_token)
        except PositionHeadroomError:
            # the tail gap is exhausted: re-spread the ids (a scheduled
            # defrag + full-forward re-ingest) and retry once
            self._defrag(doc)
            toks = sugg.refresh(
                eng, doc.state, key=doc.doc_id, n_new=doc.suggest_n,
                invalid_from=doc.invalid_from,
                export_invalid_from=doc.touched_from, on_token=on_token)
        doc.suggestion = toks
        doc.suggest_fresh = True
        doc.invalid_from = None
        doc.suggest_serial += 1
        self.stats.suggest_refreshes += 1

    # ------------------------------------------------------------- outputs

    def _flushed(self, doc_id: str) -> _BatchDoc:
        doc = self.docs[doc_id]
        if doc.pending:
            raise RuntimeError(
                f"document {doc_id!r} has {len(doc.pending)} unflushed edits")
        return doc

    def tokens(self, doc_id: str) -> np.ndarray:
        """The document's tokens in sequence order."""
        return self._flushed(doc_id).seq_tokens().copy()

    def state(self, doc_id: str) -> JitState:
        doc = self._flushed(doc_id)
        return self.store.ensure_hot(doc)

    def logits(self, doc_id: str) -> np.ndarray:
        doc = self._flushed(doc_id)
        eng = self.engine(self.C, self.R)
        state = self.store.ensure_hot(doc)
        return np.asarray(eng.logits_at(state, jnp.int32(doc.slots[-1])))

    # -------------------------------------------------- migration (DESIGN.md §11)

    def checkpoint_document(self, doc_id: str, path: str) -> None:
        """Write a flushed document's FULL serving snapshot to ``path``
        (atomic) while keeping it open: the JitState, the allocator ids, the
        host mirrors and — critically — the slot layout and free-list order.
        Attention reduces over the slot axis, so bit-exact adoption must
        reproduce the layout verbatim; ``import_document`` does. The
        document is rehydrated first, so a warm/cold resident checkpoints
        the same bits a hot one would."""
        doc = self._flushed(doc_id)
        # ensure_hot FIRST: it releases any cold holding (which may live at
        # this very path when the store shares the fleet's cold directory) —
        # writing before rehydrating would let the release delete the export
        state = self.store.ensure_hot(doc)
        save_serving_document(
            path, state_to_host(state),
            allocator_ids=doc.allocator.snapshot(),
            mirrors={
                "tokens": doc.tokens.copy(),
                "valid": doc.valid.copy(),
                "positions": doc.positions.copy(),
                "slots": np.asarray(doc.slots, np.int32),
                "free": np.asarray(doc.free, np.int32),
            },
            meta={
                "doc_id": doc_id,
                "row_capacity": int(doc.row_capacity),
                "n_virtual": int(doc.n_virtual),
                "suggest_n": int(doc.suggest_n),
                "pos_pool": int(self.pos_pool),
                "invalid_from": doc.invalid_from,
                "touched_from": doc.touched_from,
                "consistent": True,  # flushed + out-of-round by construction
            })

    def export_document(self, doc_id: str, path: str) -> None:
        """Hand a document off for migration: checkpoint, then close. The
        snapshot at ``path`` survives the close (checkpoints are ordinary
        files, not store-held cold spills) and a peer ``import_document``
        resumes the document bit-exactly (DESIGN.md §11)."""
        self.checkpoint_document(doc_id, path)
        self.close_document(doc_id)
        self.stats.exports += 1

    def import_document(self, doc_id: str, path: str, *,
                        remove: bool = True) -> None:
        """Adopt a document from a serving snapshot — the receiving half of
        migration and failover. A pure re-upload, never a recompute: the
        slot buffer, free-list order, allocator ids and device state are
        restored verbatim, so every subsequent dispatch, logits read and
        suggestion refresh is bitwise-identical to a server that never
        migrated the document (tests/test_fleet.py). Snapshots flagged
        ``consistent: False`` (captured mid-round by an eviction) are
        refused — their mirrors run ahead of their state."""
        if doc_id in self.docs:
            raise KeyError(f"document {doc_id!r} already open")
        state_h, ids, mirrors, meta = restore_serving_document(path)
        if not meta.get("consistent", True):
            raise ValueError(
                f"snapshot for {doc_id!r} is marked inconsistent (captured "
                "mid-round); re-open the document from its tokens instead")
        if meta.get("doc_id") not in (None, doc_id):
            raise ValueError(
                f"snapshot at {path} belongs to {meta['doc_id']!r}, "
                f"not {doc_id!r}")
        pool = meta.get("pos_pool")
        if pool is not None and int(pool) != self.pos_pool:
            raise ValueError(
                f"snapshot position pool {pool} != server pool "
                f"{self.pos_pool} — position ids would not be comparable")
        tokens = np.array(mirrors["tokens"], np.int32, copy=True)
        n_cap = int(tokens.shape[0])
        eng = self.engine(self.C, self.R)
        self.store.admit(state_nbytes_for(n_cap, eng.L, eng.meta))
        alloc = PositionAllocator(1, self.pos_pool)
        alloc.restore([int(i) for i in np.asarray(ids)])
        doc = _BatchDoc(
            doc_id=doc_id, tokens=tokens,
            valid=np.array(mirrors["valid"], bool, copy=True),
            positions=np.array(mirrors["positions"], np.int32, copy=True),
            slots=[int(s) for s in mirrors["slots"]],
            free=[int(s) for s in mirrors["free"]],
            n_cap=n_cap, row_capacity=int(meta["row_capacity"]),
            allocator=alloc, state=state_from_host(state_h),
            n_virtual=int(meta.get("n_virtual", len(mirrors["slots"]))),
            suggest_n=int(meta.get("suggest_n", 0)),
            invalid_from=meta.get("invalid_from"),
            touched_from=meta.get("touched_from"))
        self.docs[doc_id] = doc
        self.store.register(doc)
        self.stats.docs += 1
        self.stats.imports += 1
        if remove:
            os.remove(path)
