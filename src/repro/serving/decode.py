"""Batched decode serving step (used by the decode_32k / long_500k shapes).

``serve_step`` consumes ONE new token per sequence against per-layer KV /
recurrent-state caches of ``seq_len`` and returns next-token logits plus the
updated caches — the standard continuous-batching inner loop.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T


def make_serve_step(cfg: ArchConfig, *, sample: bool = False, temperature: float = 1.0):
    """Returns ``serve_step(params, caches, tokens, positions, rng?) ->
    (next_tokens_or_logits, caches)``."""

    def serve_step(params, caches, tokens, positions, rng: Optional[jax.Array] = None):
        logits, caches = T.decode_step(params, cfg, tokens, caches, positions)
        if not sample:
            return logits, caches
        if temperature == 0.0:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            assert rng is not None
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        return nxt.astype(jnp.int32), caches

    return serve_step


def greedy_continue(step, params, caches, logits_last: jax.Array,
                    gen_positions: jax.Array,
                    on_token=None) -> tuple[jax.Array, jax.Array]:
    """The greedy continuation inner loop shared by ``greedy_decode`` and
    the suggestion engine: ``logits_last`` [b, vocab] (audio [b, cb, vocab])
    are the logits of the last consumed token; ``gen_positions`` [b, n_new]
    the continuation position ids. Runs ``n_new - 1`` decode steps (the
    first token needs none). ``on_token``, when given, is called with each
    [b, 1] token array as the loop produces it — a streaming tap (the async
    front end forwards tokens to subscribers before the continuation is
    complete); it forces a device sync per token, so leave it None on
    latency-insensitive paths. Returns (tokens [b, n_new], caches)."""
    n_new = gen_positions.shape[1]
    cur = jnp.argmax(logits_last, axis=-1).astype(jnp.int32)[:, None]
    if on_token is not None:
        on_token(np.asarray(cur))
    out = [cur]
    for i in range(1, n_new):
        logits, caches = step(params, caches, cur, gen_positions[:, i - 1 : i])
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if on_token is not None:
            on_token(np.asarray(cur))
        out.append(cur)
    return jnp.concatenate(out, axis=1), caches


def greedy_decode(params, cfg: ArchConfig, prompt: jax.Array, n_new: int,
                  cache_len: int = 0, positions: Optional[jax.Array] = None,
                  gen_positions: Optional[jax.Array] = None):
    """Reference greedy decoding loop for tests/examples: prefill the prompt
    in ONE batched ``prefill_step`` (configs whose decode cache supports
    chunked writes — else a per-token fallback), then generate ``n_new``
    tokens. prompt: [b, n] (audio [b, n, cb]).

    ``positions`` ([b, n]) / ``gen_positions`` ([b, n_new]) override the
    default dense 0..n+n_new-1 position ids — gapped-id documents (the
    paper's sampled positional embeddings) pass their own. Returns
    (generated [b, n_new], caches)."""
    b, n = prompt.shape[:2]
    if cache_len and cache_len < n + n_new:
        # full (non-ring) caches clamp out-of-range writes: generating past
        # the cache end would silently stomp the last KV row
        raise ValueError(f"cache_len {cache_len} < prompt + n_new = {n + n_new}")
    caches = T.init_caches(cfg, b, cache_len or (n + n_new), dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg, sample=False))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    if gen_positions is None:
        gen_positions = positions[:, -1:] + 1 + jnp.arange(n_new, dtype=jnp.int32)
    if T.chunkable(cfg):
        prefill = jax.jit(lambda p, c, t, pos: T.prefill_step(p, cfg, t, c, pos))
        logits, caches = prefill(params, caches, prompt, positions)
        logits = logits[:, -1:]
    else:
        for i in range(n):
            logits, caches = step(params, caches, prompt[:, i : i + 1],
                                  positions[:, i : i + 1])
    return greedy_continue(step, params, caches, logits[:, -1], gen_positions)
