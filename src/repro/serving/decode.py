"""Batched decode serving step (used by the decode_32k / long_500k shapes).

``serve_step`` consumes ONE new token per sequence against per-layer KV /
recurrent-state caches of ``seq_len`` and returns next-token logits plus the
updated caches — the standard continuous-batching inner loop.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


def make_serve_step(cfg: ArchConfig, *, sample: bool = False, temperature: float = 1.0):
    """Returns ``serve_step(params, caches, tokens, positions, rng?) ->
    (next_tokens_or_logits, caches)``."""

    def serve_step(params, caches, tokens, positions, rng: Optional[jax.Array] = None):
        logits, caches = T.decode_step(params, cfg, tokens, caches, positions)
        if not sample:
            return logits, caches
        if temperature == 0.0:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            assert rng is not None
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        return nxt.astype(jnp.int32), caches

    return serve_step


def greedy_decode(params, cfg: ArchConfig, prompt: jax.Array, n_new: int,
                  cache_len: int = 0):
    """Reference greedy decoding loop for tests/examples: prefill the prompt
    token-by-token, then generate ``n_new`` tokens. prompt: [b, n]."""
    b, n = prompt.shape
    caches = T.init_caches(cfg, b, n + n_new, dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg, sample=False))
    tok = prompt[:, :1] if cfg.n_codebooks == 1 else prompt[:, :1]
    out = []
    cur = None
    for i in range(n + n_new):
        pos = jnp.full((b, 1), i, jnp.int32)
        if i < n:
            cur = prompt[:, i : i + 1]
        logits, caches = step(params, caches, cur, pos)
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if cur.ndim == 3:  # audio: [b, 1, cb]
            pass
        if i >= n - 1:
            out.append(cur)
    return jnp.concatenate(out[:n_new], axis=1), caches
