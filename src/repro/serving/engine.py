"""Incremental serving engine — the writing-assistant deployment of the paper.

Wraps ``repro.core.incremental.IncrementalEngine`` with:

* a per-document activation cache (the online setting keeps "a cache for the
  first input", paper §3);
* gapped position-id management with automatic defragmentation (§3.3) —
  defrags are *counted* as full forward passes;
* an offline batch path: align a new revision against the cached base with
  an edit script and apply it (replaces batched, inserts/deletes in order);
* op accounting per request, for the Table-2 / Fig-3/4 experiments.

Batched serving
---------------
This server is the *op-counting* single-worker deployment: one NumPy engine,
one document per request, dynamic shapes. The wall-clock, multi-tenant
deployment lives in ``repro.serving.batch_server.BatchServer``: documents
live in slot buffers padded into power-of-two capacity buckets, pending
edits of the FULL algebra (replace/insert/delete) from different documents
are grouped into typed ``(n_cap, C, R, op)`` buckets and served by ONE
vmapped fixed-shape jit step (``batch_engine.BatchedJitEngine``); defrag
and buffer growth are scheduled full-forward re-ingests, and a per-document
overflow flag triggers a full-forward fallback plus capacity-doubling
(R ← min(2R, n_cap)) re-jit. Use this class to *measure* the paper's op
claims; use ``BatchServer`` to *serve traffic*.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.edits import Edit, align, edit_script
from repro.core.incremental import DocState, IncrementalEngine
from repro.core.opcount import OpCounter, dense_transformer_forward_ops
from repro.core.positional import PositionAllocator


@dataclass
class ServerStats:
    requests: int = 0
    edits: int = 0
    defrags: int = 0
    incremental_ops: int = 0
    full_ops_equiv: int = 0  # what recompute-from-scratch would have cost

    @property
    def speedup(self) -> float:
        return self.full_ops_equiv / max(self.incremental_ops, 1)


@dataclass
class _Doc:
    state: DocState
    allocator: PositionAllocator


class IncrementalServer:
    def __init__(self, params: dict, cfg: ArchConfig, *, pos_pool: Optional[int] = None):
        self.cfg = cfg
        self.counter = OpCounter()
        self.engine = IncrementalEngine(params, cfg, self.counter)
        self.pos_pool = pos_pool or (cfg.pos_pool if cfg.pos_pool else cfg.max_seq * 100)
        self.docs: dict[str, _Doc] = {}
        self.stats = ServerStats()

    # ------------------------------------------------------------- helpers

    def _dense_ops(self, n: int) -> int:
        """Analytic from-scratch cost at the current length (the baseline an
        ordinary deployment would pay per request)."""
        c = self.cfg
        kinds = {l.ffn for l in c.layer_list()}
        return dense_transformer_forward_ops(
            n_layers=c.n_layers, d_model=c.d_model, n_heads=c.n_heads,
            n_kv_heads=c.n_kv_heads, d_ff=c.d_ff, vocab=c.vocab, seq_len=n,
            ffn_gated=kinds <= {"swiglu", "geglu"}, include_lm_head=False,
        )

    def _measured(self, fn, *args, **kwargs):
        before = self.counter.total
        out = fn(*args, **kwargs)
        return out, self.counter.total - before

    # ------------------------------------------------------------- API

    def open_document(self, doc_id: str, tokens: Sequence[int]) -> ServerStats:
        """Ingest a document from scratch (one full forward, cached)."""
        alloc = PositionAllocator(len(tokens), self.pos_pool)
        state, ops = self._measured(
            self.engine.full_forward, np.asarray(tokens), np.asarray(alloc.positions)
        )
        self.docs[doc_id] = _Doc(state, alloc)
        self.stats.requests += 1
        self.stats.incremental_ops += ops
        self.stats.full_ops_equiv += self._dense_ops(len(tokens))
        return self.stats

    def apply_edit(self, doc_id: str, edit: Edit) -> int:
        """Online path: one atomic edit. Returns the ops spent."""
        doc = self.docs[doc_id]
        defrags_before = doc.allocator.defrag_count
        new_state, ops = self._measured(self.engine.apply_edit, doc.state, edit, doc.allocator)
        doc.state = new_state
        self.stats.requests += 1
        self.stats.edits += 1
        self.stats.defrags += doc.allocator.defrag_count - defrags_before
        self.stats.incremental_ops += ops
        self.stats.full_ops_equiv += self._dense_ops(new_state.n)
        return ops

    def submit_revision(self, doc_id: str, new_tokens: Sequence[int]) -> int:
        """Offline path: align the revision against the cached base ONCE and
        share the alignment between the edit-count stats and the engine's
        batched revision algorithm (one column-patch sweep per layer)."""
        doc = self.docs[doc_id]
        opcodes = align(list(doc.state.tokens), list(new_tokens))
        script = edit_script(list(doc.state.tokens), list(new_tokens),
                             opcodes=opcodes)
        before = self.counter.total
        doc.state = self.engine.apply_revision(doc.state, new_tokens,
                                               doc.allocator, opcodes=opcodes)
        ops = self.counter.total - before
        self.stats.requests += 1
        self.stats.edits += len(script)
        self.stats.incremental_ops += ops
        self.stats.full_ops_equiv += self._dense_ops(doc.state.n)
        return ops

    def logits(self, doc_id: str) -> np.ndarray:
        return self.engine.logits_at(self.docs[doc_id].state)

    def tokens(self, doc_id: str) -> np.ndarray:
        return self.docs[doc_id].state.tokens.copy()
