"""Multi-replica fleet serving (DESIGN.md §11).

A fleet is N replica workers — each a subprocess owning one
``AsyncBatchServer`` — behind a ``FleetRouter`` that places documents by
load, keeps routing sticky, migrates documents across replicas through the
shared cold tier, and fails dead replicas' documents over to survivors.
"""
from repro.serving.fleet.router import (
    FleetRouter, RemoteOpError, ReplicaDiedError,
)

__all__ = ["FleetRouter", "RemoteOpError", "ReplicaDiedError"]
