"""Ownership leases over the shared cold directory (DESIGN.md §11).

The cold tier's *storage* contract — deterministic per-document file names
(``state_store.cold_path_for``) and atomic writes — lets any replica find
and read any document's spill. Leases add the *ownership* contract: at most
one replica serves a document at a time, so two replicas can never both
adopt (and then divergently edit) the same snapshot.

A lease is a sidecar file created with ``O_CREAT | O_EXCL`` — the classic
atomic-on-POSIX (and NFS-safe-enough for a CI fleet) mutual-exclusion
primitive; its payload names the owner for debuggability and for failover's
targeted ``break_lease``. Protocol:

* ``open`` / ``import`` on a replica acquires the document's lease first
  and refuses the document if another owner holds it;
* ``export`` (migration hand-off) and ``close`` release it;
* failover: the router — the single arbiter of replica death — breaks the
  dead owner's leases before reassigning its documents. Workers never break
  leases themselves.
"""
from __future__ import annotations

import json
import os

from repro.serving.state_store import cold_path_for  # noqa: F401  (re-export)


class LeaseHeldError(RuntimeError):
    """Another replica holds the document's lease."""


def lease_path_for(cold_dir: str, doc_id: str) -> str:
    return cold_path_for(cold_dir, doc_id) + ".lease"


def acquire_lease(cold_dir: str, doc_id: str, owner: str) -> None:
    """Take ownership of ``doc_id``. Idempotent for the same owner (a
    re-acquire after e.g. a retried import); raises ``LeaseHeldError`` when
    someone else holds it."""
    os.makedirs(cold_dir, exist_ok=True)
    path = lease_path_for(cold_dir, doc_id)
    payload = json.dumps({"owner": owner, "doc_id": doc_id}).encode()
    try:
        fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        holder = lease_owner(cold_dir, doc_id)
        if holder == owner:
            return
        raise LeaseHeldError(
            f"document {doc_id!r} is leased to {holder!r}") from None
    try:
        os.write(fd, payload)
    finally:
        os.close(fd)


def lease_owner(cold_dir: str, doc_id: str) -> str | None:
    """The current lease holder, or None. A vanished-mid-read lease (the
    owner released concurrently) reads as None."""
    try:
        with open(lease_path_for(cold_dir, doc_id)) as f:
            return json.load(f).get("owner")
    except (FileNotFoundError, json.JSONDecodeError):
        return None


def release_lease(cold_dir: str, doc_id: str, owner: str) -> None:
    """Give up ownership. Raises if someone ELSE holds the lease (releasing
    a peer's lease is always a bug); a missing lease is a no-op (release
    after a failover break)."""
    holder = lease_owner(cold_dir, doc_id)
    if holder is None:
        return
    if holder != owner:
        raise LeaseHeldError(
            f"cannot release {doc_id!r}: leased to {holder!r}, not {owner!r}")
    try:
        os.remove(lease_path_for(cold_dir, doc_id))
    except FileNotFoundError:
        pass


def break_lease(cold_dir: str, doc_id: str) -> None:
    """Forcibly clear a lease regardless of owner — the router's failover
    prerogative, used only for documents whose owning replica is dead."""
    try:
        os.remove(lease_path_for(cold_dir, doc_id))
    except FileNotFoundError:
        pass
