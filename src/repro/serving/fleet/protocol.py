"""Length-prefixed framing for the router <-> replica-worker RPC pipe.

A frame is a 4-byte big-endian length followed by a pickled payload. The
router sends request frames ``{"id": n, "ops": [op, ...]}`` (each op a dict
with an ``"op"`` kind plus operands); the worker answers with one response
frame ``{"id": n, "results": [r, ...]}`` aligned 1:1 with the ops, each
result ``{"ok": True, "value": ...}`` or ``{"ok": False, "error": str,
"cls": str}``. Batching many ops per frame is the wire-level analogue of
the server's deadline batching: a burst the router coalesced crosses the
pipe in one syscall and lands in the worker's scheduler together.

Pickle is safe here because both endpoints are the same codebase talking
over a private pipe the router itself spawned — this is an intra-fleet
protocol, not a public network surface.
"""
from __future__ import annotations

import pickle
import struct
from typing import Any, BinaryIO

_HEADER = struct.Struct(">I")
# A frame carries at most a batched op list with a few numpy token arrays —
# anything bigger is a framing bug (e.g. a stray print corrupting the pipe),
# better surfaced as a protocol error than as an absurd allocation.
MAX_FRAME = 256 * 1024 * 1024


class ProtocolError(RuntimeError):
    """The pipe closed mid-frame or carried a malformed frame."""


def send_msg(fp: BinaryIO, payload: Any) -> None:
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    if len(blob) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(blob)} bytes exceeds MAX_FRAME")
    fp.write(_HEADER.pack(len(blob)) + blob)
    fp.flush()


def _read_exact(fp: BinaryIO, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = fp.read(n - got)
        if not chunk:
            raise EOFError(f"pipe closed after {got}/{n} frame bytes")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(fp: BinaryIO) -> Any:
    """Read one frame; raises ``EOFError`` on a closed pipe (the router's
    replica-death signal) and ``ProtocolError`` on garbage."""
    header = fp.read(_HEADER.size)
    if not header:
        raise EOFError("pipe closed")
    if len(header) < _HEADER.size:
        raise EOFError("pipe closed mid-header")
    (length,) = _HEADER.unpack(header)
    if length > MAX_FRAME:
        raise ProtocolError(f"frame header claims {length} bytes")
    try:
        return pickle.loads(_read_exact(fp, length))
    except EOFError:
        raise
    except Exception as exc:  # corrupt pickle = corrupt pipe
        raise ProtocolError(f"undecodable frame: {exc}") from exc
