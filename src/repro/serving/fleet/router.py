"""``FleetRouter``: document placement, sticky routing, migration, failover.

The router is the fleet's single control point (DESIGN.md §11). It spawns N
replica workers (``fleet.worker`` subprocesses), speaks the framed RPC of
``fleet.protocol`` to each over its stdin/stdout pipes, and exposes the
familiar ``open / edit / suggest / tokens / close`` surface — every call
returns the same ``Ticket`` latch the async front end uses, so a client
cannot tell one replica from a fleet.

Placement and routing:

* **greedy least-loaded admission** — a new document lands on the replica
  with the smallest (estimated hot bytes, in-flight edits, open docs)
  triple; the byte estimate is ``state_nbytes_for_config`` at the
  document's capacity class, the same arithmetic the serving budget uses;
* **sticky routing** — after admission every request for a document goes to
  its owner (per-document FIFO order is the exactness contract), until an
  explicit ``migrate`` or a failover moves it.

Per replica, ONE rpc thread drains a queue of (op, ticket) pairs and ships
them as a single frame per round trip — the wire-level analogue of deadline
batching: a burst coalesces into one frame, lands in the worker's scheduler
together, and resolves as one response frame.

Acked-token mirrors and exactly-once failover: the router applies each
acked edit to a host-side token mirror of every document. When a replica
dies, each of its documents is reconstructed on a survivor **to exactly the
acked mirror** — by adopting the shared-cold-tier snapshot and applying a
repair edit script (snapshot -> mirror, which also REVERTS edits the dead
replica applied but never acked), or by re-opening from the mirror when no
usable snapshot exists. In-flight tickets fail with ``ReplicaDiedError``
and the client replays them; because recovery rolled the document to the
acked prefix, a replay can never double-apply (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import tempfile
import threading
from dataclasses import dataclass
from queue import Empty, Queue
from typing import Optional, Sequence

import numpy as np

from repro.common.bucketing import capacity_class, next_pow2
from repro.core.edits import Edit, apply_edit, edit_script
from repro.serving.async_server import Ticket
from repro.serving.fleet import cold_tier
from repro.serving.fleet.protocol import send_msg, recv_msg
from repro.serving.jit_engine import state_nbytes_for_config
from repro.serving.latency import LatencyStats
from repro.serving.state_store import cold_path_for

_FRAME_OPS = 64  # max ops coalesced per RPC frame
_READY_TIMEOUT_S = 600.0  # worker boot = jax import + params init
_RECOVER_TIMEOUT_S = 600.0  # failover import/reopen may pay a first compile


class ReplicaDiedError(RuntimeError):
    """The owning replica died before acknowledging this request. The
    document has been reconstructed on a survivor at its ACKED prefix, so
    replaying the failed request is safe (never double-applies)."""


class RemoteOpError(RuntimeError):
    """The worker served the op and reported an application error."""

    def __init__(self, message: str, cls: str = "Exception"):
        super().__init__(message)
        self.remote_cls = cls


@dataclass
class FleetStats:
    """Router-side counters; ``FleetRouter.stats()`` merges these with the
    per-replica ``BatchStats``/``AsyncStats`` aggregation."""

    replicas: int = 0
    replicas_dead: int = 0
    docs_opened: int = 0
    docs_closed: int = 0
    migrations: int = 0
    failovers: int = 0  # dead replicas recovered
    failover_rehydrations: int = 0  # docs adopted from a cold snapshot
    failover_reopens: int = 0  # docs re-opened from the acked token mirror
    repair_edits: int = 0  # snapshot -> acked-mirror repair ops applied


class _Replica:
    """Router-side handle: the subprocess, its RPC thread, and its load
    accounting (docs owned, in-flight edits, estimated hot bytes)."""

    def __init__(self, idx: int, proc: subprocess.Popen):
        self.idx = idx
        self.name = f"r{idx}"
        self.proc = proc
        self.queue: Queue = Queue()
        self.alive = True
        self.dead_event = threading.Event()  # set AFTER failover completes
        self.docs: set[str] = set()
        self.inflight = 0
        self.est_bytes = 0
        self.lock = threading.Lock()
        self.thread: Optional[threading.Thread] = None
        self._frame_id = 0

    def load_key(self) -> tuple:
        with self.lock:
            return (self.est_bytes, self.inflight, len(self.docs), self.idx)


class FleetRouter:
    """See module docstring. Typical use::

        with FleetRouter(2, cold_dir=shared) as fleet:
            fleet.open_document("a", tokens).result()
            fleet.submit_insert("a", 3, 17)
            toks = fleet.tokens("a").result()
            print(fleet.stats()["edits_applied"])
    """

    def __init__(self, n_replicas: int, *, arch: str = "vq-opt-125m",
                 smoke: bool = True, seed: int = 0,
                 cold_dir: Optional[str] = None,
                 server_kwargs: Optional[dict] = None,
                 max_batch_delay_ms: float = 5.0,
                 bucket_docs: Optional[int] = None,
                 heartbeat_interval_s: Optional[float] = 2.0,
                 worker_env: Optional[dict] = None):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self.arch = arch
        self.smoke = smoke
        self.seed = seed
        self.cold_dir = cold_dir or tempfile.mkdtemp(prefix="repro-fleet-")
        os.makedirs(self.cold_dir, exist_ok=True)
        self.server_kwargs = dict(server_kwargs or {})
        self.stats_fleet = FleetStats()
        self._route: dict[str, _Replica] = {}
        self._route_lock = threading.RLock()
        self._mirrors: dict[str, list[int]] = {}  # doc -> ACKED tokens
        self._suggest_n: dict[str, int] = {}  # doc -> standing request length
        self._doc_est: dict[str, int] = {}  # doc -> admission byte estimate
        self._mirror_lock = threading.Lock()
        self._closed = False
        # capacity-class arithmetic mirrors BatchServer's defaults so the
        # byte estimate matches what the replica will actually admit
        self._min_cap = next_pow2(self.server_kwargs.get("min_doc_capacity", 16))
        self._cap_step = self.server_kwargs.get("capacity_class_step", 4)
        from repro.configs import get_config
        self._cfg = get_config(arch, smoke=smoke)

        spec_common = {
            "arch": arch, "smoke": smoke, "seed": seed,
            "cold_dir": self.cold_dir,
            "server_kwargs": self.server_kwargs,
            "async_kwargs": {"max_batch_delay_ms": max_batch_delay_ms,
                             **({"bucket_docs": bucket_docs}
                                if bucket_docs else {})},
        }
        env = dict(os.environ)
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.update(worker_env or {})
        self.replicas: list[_Replica] = []
        for idx in range(n_replicas):
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.serving.fleet.worker"],
                stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                stderr=None, env=env)
            r = _Replica(idx, proc)
            send_msg(proc.stdin, {**spec_common, "replica": r.name})
            self.replicas.append(r)
        # readiness: workers boot in parallel (each pays jax import + param
        # init); collect the ready frames after all spawns
        for r in self.replicas:
            ready = self._recv_with_deadline(r, _READY_TIMEOUT_S)
            if not ready.get("ok"):
                self._kill_all()
                raise RuntimeError(
                    f"replica {r.name} failed to start: {ready.get('error')}")
            r.thread = threading.Thread(
                target=self._rpc_loop, args=(r,),
                name=f"repro-fleet-rpc-{r.name}", daemon=True)
            r.thread.start()
        self.stats_fleet.replicas = n_replicas
        self._hb_stop = threading.Event()
        self._hb_thread = None
        if heartbeat_interval_s:
            self._hb_thread = threading.Thread(
                target=self._heartbeat, args=(float(heartbeat_interval_s),),
                name="repro-fleet-heartbeat", daemon=True)
            self._hb_thread.start()

    # ------------------------------------------------------------ client API

    def open_document(self, doc_id: str, tokens: Sequence[int],
                      replica: Optional[int] = None) -> Ticket:
        toks = [int(t) for t in tokens]
        with self._route_lock:
            if self._closed:
                raise RuntimeError("fleet is closed")
            if doc_id in self._route:
                raise KeyError(f"document {doc_id!r} already open")
            r = (self.replicas[replica] if replica is not None
                 else self._least_loaded())
            r.docs.add(doc_id)
            self._doc_est[doc_id] = self._est_bytes(len(toks))
            with r.lock:
                r.est_bytes += self._doc_est[doc_id]
            self._route[doc_id] = r
            self.stats_fleet.docs_opened += 1
            return self._enqueue(r, {"op": "open", "doc_id": doc_id,
                                     "tokens": toks})

    def close_document(self, doc_id: str) -> Ticket:
        with self._route_lock:
            r = self._owner(doc_id)
            ticket = self._enqueue(r, {"op": "close", "doc_id": doc_id})
            r.docs.discard(doc_id)
            with r.lock:
                r.est_bytes -= self._doc_est.pop(doc_id, 0)
            self._route.pop(doc_id, None)
            self.stats_fleet.docs_closed += 1
            return ticket

    def submit_replace(self, doc_id: str, pos: int, tok: int) -> Ticket:
        return self._submit_edit(doc_id, ("replace", int(pos), int(tok)))

    def submit_insert(self, doc_id: str, pos: int, tok: int) -> Ticket:
        return self._submit_edit(doc_id, ("insert", int(pos), int(tok)))

    def submit_delete(self, doc_id: str, pos: int) -> Ticket:
        return self._submit_edit(doc_id, ("delete", int(pos), 0))

    def submit_edit(self, doc_id: str, e: Edit) -> Ticket:
        if e.op == "replace":
            return self.submit_replace(doc_id, e.pos, e.token)
        if e.op == "insert":
            return self.submit_insert(doc_id, e.pos, e.token)
        return self.submit_delete(doc_id, e.pos)

    def suggest(self, doc_id: str, n_new: int = 8) -> Ticket:
        with self._route_lock:
            r = self._owner(doc_id)
            with self._mirror_lock:
                self._suggest_n[doc_id] = int(n_new)
            return self._enqueue(r, {"op": "suggest", "doc_id": doc_id,
                                     "n_new": int(n_new)})

    def tokens(self, doc_id: str) -> Ticket:
        with self._route_lock:
            return self._enqueue(self._owner(doc_id),
                                 {"op": "tokens", "doc_id": doc_id})

    def logits(self, doc_id: str) -> Ticket:
        with self._route_lock:
            return self._enqueue(self._owner(doc_id),
                                 {"op": "logits", "doc_id": doc_id})

    def evict(self, doc_id: str, tier: str = "warm") -> Ticket:
        with self._route_lock:
            return self._enqueue(self._owner(doc_id),
                                 {"op": "evict", "doc_id": doc_id,
                                  "tier": tier})

    def owner_of(self, doc_id: str) -> int:
        with self._route_lock:
            return self._owner(doc_id).idx

    def flush(self, timeout: Optional[float] = None) -> None:
        """Block until every request admitted before this call is acked on
        every live replica."""
        with self._route_lock:
            tickets = [self._enqueue(r, {"op": "barrier"})
                       for r in self.replicas if r.alive]
        for t in tickets:
            t.result(timeout)

    def ping(self, timeout: Optional[float] = None) -> list:
        with self._route_lock:
            tickets = [(r.name, self._enqueue(r, {"op": "ping"}))
                       for r in self.replicas if r.alive]
        return [(name, t.result(timeout)) for name, t in tickets]

    # ------------------------------------------------------------- migration

    def migrate(self, doc_id: str, to_replica: int) -> None:
        """Move a live document: flush + snapshot + close on the owner
        (``export``), adopt on the target (``import``) — PR 5's evict/
        rehydrate machinery pointed across processes, so the move is
        bit-exact. Blocking; concurrent submissions for the document are
        held (the routing lock) until the new owner has adopted it."""
        with self._route_lock:
            src = self._owner(doc_id)
            dst = self.replicas[to_replica]
            if not dst.alive:
                raise ReplicaDiedError(f"target replica r{to_replica} is dead")
            if src is dst:
                return
            self._enqueue(src, {"op": "export",
                                "doc_id": doc_id}).result(_RECOVER_TIMEOUT_S)
            self._enqueue(dst, {"op": "import", "doc_id": doc_id,
                                "remove": True}).result(_RECOVER_TIMEOUT_S)
            nbytes = self._doc_est.get(doc_id, 0)
            src.docs.discard(doc_id)
            with src.lock:
                src.est_bytes -= nbytes
            dst.docs.add(doc_id)
            with dst.lock:
                dst.est_bytes += nbytes
            self._route[doc_id] = dst
            self.stats_fleet.migrations += 1

    def reset_latency(self, timeout: Optional[float] = None) -> None:
        """Zero every live replica's per-request latency histograms — the
        benchmark timing protocol (warmup pays the jit compiles, then the
        measured pass restarts the histograms; cf. benchmarks.async_load)."""
        with self._route_lock:
            tickets = [self._enqueue(r, {"op": "reset_latency"})
                       for r in self.replicas if r.alive]
        for t in tickets:
            t.result(timeout)

    def checkpoint(self, timeout: Optional[float] = None) -> None:
        """Snapshot every open document to the shared cold tier (each
        replica flushes first). Bounds failover's reopen-and-replay to the
        edits acked since this call."""
        with self._route_lock:
            tickets = [self._enqueue(r, {"op": "checkpoint"})
                       for r in self.replicas if r.alive]
        for t in tickets:
            t.result(timeout)

    def kill_replica(self, idx: int, timeout: float = _RECOVER_TIMEOUT_S) -> None:
        """Hard-kill a replica (failover test/chaos hook) and block until
        its documents have been reassigned to survivors."""
        r = self.replicas[idx]
        r.proc.kill()
        # the rpc thread may be idle on queue.get: a ping makes it touch the
        # dead pipe and discover the EOF
        try:
            self._enqueue(r, {"op": "ping"})
        except ReplicaDiedError:
            pass
        if not r.dead_event.wait(timeout):
            raise TimeoutError(f"replica r{idx} failover did not complete")

    # ------------------------------------------------------------- lifecycle

    def close_fleet(self, timeout: float = 60.0) -> None:
        """Close every document, shut every worker down, reap processes.
        Leak-free: afterwards no subprocess survives and the shared cold
        directory holds no document files or leases
        (tests/test_fleet.py)."""
        with self._route_lock:
            if self._closed:
                return
            self._closed = True
        self._hb_stop.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=5)
        for doc_id in list(self._route):
            try:
                self.close_document(doc_id).result(timeout)
            except (ReplicaDiedError, RemoteOpError):
                pass
        for r in self.replicas:
            if r.alive:
                try:
                    self._enqueue(r, {"op": "shutdown"})
                except ReplicaDiedError:
                    pass
            r.queue.put(None)  # rpc-thread sentinel
        for r in self.replicas:
            if r.thread is not None:
                r.thread.join(timeout)
            try:
                if r.proc.stdin:
                    r.proc.stdin.close()
            except OSError:
                pass
            try:
                r.proc.wait(timeout)
            except subprocess.TimeoutExpired:
                r.proc.kill()
                r.proc.wait(10)

    def __enter__(self) -> "FleetRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close_fleet()

    # ------------------------------------------------------------- aggregation

    def stats(self, timeout: Optional[float] = None) -> dict:
        """Fleet-level aggregation: sums of every replica's ``BatchStats``
        counters, concatenated latency reservoirs (fleet p50/p99), the
        fleet hot-hit rate, and the router's own counters. Each replica
        drains before reporting, so the totals reconcile exactly with the
        sum of acked work (tests/test_fleet.py::test_stats_reconcile)."""
        with self._route_lock:
            tickets = [self._enqueue(r, {"op": "stats"})
                       for r in self.replicas if r.alive]
        per_replica = [t.result(timeout) for t in tickets]
        agg: dict = {"per_replica": per_replica,
                     "router": dataclasses.asdict(self.stats_fleet),
                     "docs_open": len(self._route),
                     "replicas_alive": len(per_replica)}
        for field_name in ("edits_applied", "edits_submitted", "docs",
                           "closes", "batch_steps", "full_forwards",
                           "suggest_refreshes", "suggest_cached_hits",
                           "evictions", "spills", "rehydrations",
                           "hot_hits", "state_touches", "exports",
                           "imports", "kernel_launches"):
            agg[field_name] = sum(s["batch"][field_name] for s in per_replica)
        for field_name in ("rounds", "deadline_rounds", "full_rounds",
                           "admitted_edits", "admitted_suggests",
                           "requests_failed"):
            agg[field_name] = sum(s["async"][field_name] for s in per_replica)
        agg["hot_hit_rate"] = (agg["hot_hits"] / agg["state_touches"]
                               if agg["state_touches"] else 1.0)
        for lat in ("edit_latency", "suggest_latency"):
            merged = LatencyStats()
            samples: list[float] = []
            for s in per_replica:
                rec = s["batch"][lat]
                merged.count += rec["count"]
                merged.total_ms += rec["total_ms"]
                merged.max_ms = max(merged.max_ms, rec["max_ms"])
                samples.extend(rec["samples"])
            merged.samples = samples
            agg[lat] = merged.summary()
        return agg

    # ------------------------------------------------------------- internals

    def _owner(self, doc_id: str) -> _Replica:
        r = self._route.get(doc_id)
        if r is None:
            raise KeyError(f"document {doc_id!r} is not open on this fleet")
        return r

    def _least_loaded(self) -> _Replica:
        live = [r for r in self.replicas if r.alive]
        if not live:
            raise ReplicaDiedError("no live replicas")
        return min(live, key=_Replica.load_key)

    def _est_bytes(self, n_tokens: int) -> int:
        n_cap = capacity_class(max(n_tokens, 1), self._min_cap, self._cap_step)
        return state_nbytes_for_config(self._cfg, n_cap)

    def _submit_edit(self, doc_id: str, e: tuple) -> Ticket:
        with self._route_lock:
            r = self._owner(doc_id)
            with r.lock:
                r.inflight += 1
            return self._enqueue(r, {"op": "edit", "doc_id": doc_id,
                                     "edit": e, "track": True})

    def _enqueue(self, r: _Replica, op: dict) -> Ticket:
        if not r.alive:
            raise ReplicaDiedError(f"replica {r.name} is dead")
        ticket = Ticket(op.get("doc_id"))
        r.queue.put((op, ticket))
        return ticket

    def _recv_with_deadline(self, r: _Replica, timeout: float):
        """Blocking ready-frame read with a watchdog that kills the worker
        if it never reports (a hung import would otherwise hang the
        router)."""
        timer = threading.Timer(timeout, r.proc.kill)
        timer.start()
        try:
            return recv_msg(r.proc.stdout)
        except EOFError:
            return {"ok": False, "error": "worker exited before ready"}
        finally:
            timer.cancel()

    def _kill_all(self) -> None:
        for r in self.replicas:
            try:
                r.proc.kill()
            except OSError:
                pass

    # --------------------------------------------------------- rpc thread

    def _rpc_loop(self, r: _Replica) -> None:
        while True:
            item = r.queue.get()
            if item is None:
                return
            batch = [item]
            while len(batch) < _FRAME_OPS:
                try:
                    nxt = r.queue.get_nowait()
                except Empty:
                    break
                if nxt is None:
                    r.queue.put(None)  # keep the sentinel for after this frame
                    break
                batch.append(nxt)
            r._frame_id += 1
            try:
                send_msg(r.proc.stdin,
                         {"id": r._frame_id, "ops": [op for op, _ in batch]})
                resp = recv_msg(r.proc.stdout)
                results = resp["results"]
                if len(results) != len(batch):
                    raise RuntimeError(
                        f"frame {r._frame_id}: {len(results)} results for "
                        f"{len(batch)} ops")
            except Exception:
                self._replica_died(r, batch)
                return
            for (op, ticket), res in zip(batch, results):
                self._settle(r, op, ticket, res)

    def _settle(self, r: _Replica, op: dict, ticket: Ticket, res: dict) -> None:
        if op["op"] == "edit":
            with r.lock:
                r.inflight -= 1
        if res.get("ok"):
            if op["op"] == "edit" and op.get("track"):
                self._mirror_apply(op["doc_id"], op["edit"])
            elif op["op"] == "open":
                with self._mirror_lock:
                    self._mirrors[op["doc_id"]] = list(op["tokens"])
            elif op["op"] == "close":
                with self._mirror_lock:
                    self._mirrors.pop(op["doc_id"], None)
                    self._suggest_n.pop(op["doc_id"], None)
            ticket._resolve(res.get("value"))
        else:
            ticket._fail(RemoteOpError(res.get("error", "remote failure"),
                                       res.get("cls", "Exception")))

    def _mirror_apply(self, doc_id: str, e: tuple) -> None:
        kind, pos, tok = e
        with self._mirror_lock:
            toks = self._mirrors.get(doc_id)
            if toks is None:
                return
            self._mirrors[doc_id] = apply_edit(toks, Edit(kind, pos, tok))

    # ----------------------------------------------------------- failover

    def _replica_died(self, r: _Replica, inflight_batch: list) -> None:
        """RPC-thread death handler: fail everything in flight FIRST (so a
        blocked ``migrate``/``flush`` holding the routing lock unblocks),
        then reassign the dead replica's documents under the routing lock."""
        r.alive = False
        self.stats_fleet.replicas_dead += 1
        try:
            r.proc.kill()
        except OSError:
            pass
        for _, ticket in inflight_batch:
            ticket._fail(ReplicaDiedError(
                f"replica {r.name} died before acking"))
        self._drain_dead_queue(r)
        try:
            with self._route_lock:
                if not self._closed:
                    self._recover_documents(r)
                    self.stats_fleet.failovers += 1
        finally:
            # late enqueues that raced the death: fail them too
            self._drain_dead_queue(r)
            r.dead_event.set()

    def _drain_dead_queue(self, r: _Replica) -> None:
        while True:
            try:
                item = r.queue.get_nowait()
            except Empty:
                return
            if item is None:
                continue
            op, ticket = item
            if op["op"] == "edit":
                with r.lock:
                    r.inflight -= 1
            ticket._fail(ReplicaDiedError(
                f"replica {r.name} died before acking"))

    def _recover_documents(self, dead: _Replica) -> None:
        """Reassign every document the dead replica owned. Target state is
        the ACKED token mirror exactly — snapshot adoption is followed by a
        repair edit script (which also reverts applied-but-unacked edits),
        and a missing/unusable snapshot falls back to a re-open from the
        mirror. Suggestion subscriptions re-establish on next request."""
        for doc_id in sorted(dead.docs):
            with self._mirror_lock:
                target = list(self._mirrors.get(doc_id, ()))
            try:
                self._recover_one(doc_id, target)
            except (RemoteOpError, ReplicaDiedError):
                # double failure mid-recovery: one retry on whatever
                # survivor remains, else the document is lost (its next
                # touch raises KeyError and the client re-opens)
                try:
                    self._recover_one(doc_id, target)
                except (RemoteOpError, ReplicaDiedError):
                    self._route.pop(doc_id, None)
                    self._doc_est.pop(doc_id, None)
        dead.docs.clear()

    def _recover_one(self, doc_id: str, target: list) -> None:
        dst = self._least_loaded()
        cold_tier.break_lease(self.cold_dir, doc_id)
        adopted = False
        if os.path.exists(cold_path_for(self.cold_dir, doc_id)):
            try:
                self._enqueue(dst, {"op": "import", "doc_id": doc_id,
                                    "remove": True}
                              ).result(_RECOVER_TIMEOUT_S)
                adopted = True
            except RemoteOpError:
                adopted = False  # inconsistent/corrupt snapshot: re-open
        if adopted:
            snap = list(self._enqueue(
                dst, {"op": "tokens", "doc_id": doc_id}
            ).result(_RECOVER_TIMEOUT_S))
            repairs = edit_script(snap, target) if snap != target else []
            for e in repairs:
                # track=False: the mirror already IS the repair target
                self._enqueue(dst, {"op": "edit", "doc_id": doc_id,
                                    "edit": (e.op, int(e.pos), int(e.token)),
                                    "track": False}
                              ).result(_RECOVER_TIMEOUT_S)
            self.stats_fleet.repair_edits += len(repairs)
            self.stats_fleet.failover_rehydrations += 1
        else:
            if not target:
                self._route.pop(doc_id, None)
                self._doc_est.pop(doc_id, None)
                return  # opened but never acked: nothing to recover
            self._enqueue(dst, {"op": "open", "doc_id": doc_id,
                                "tokens": target}
                          ).result(_RECOVER_TIMEOUT_S)
            self.stats_fleet.failover_reopens += 1
        n = self._suggest_n.get(doc_id)
        if n:
            self._enqueue(dst, {"op": "suggest", "doc_id": doc_id,
                                "n_new": n})
        dst.docs.add(doc_id)
        self._doc_est[doc_id] = self._est_bytes(len(target))
        with dst.lock:
            dst.est_bytes += self._doc_est[doc_id]
        self._route[doc_id] = dst

    # ---------------------------------------------------------- heartbeat

    def _heartbeat(self, interval: float) -> None:
        """Probe liveness: an exited process is discovered even when its
        rpc thread is idle (the ping forces a touch of the dead pipe)."""
        while not self._hb_stop.wait(interval):
            for r in self.replicas:
                if not r.alive or self._closed:
                    continue
                # a ping per beat is the whole probe: EOF/EPIPE on the pipe
                # is the death detector (never a timeout — a long jit
                # compile must not read as a dead replica), and it wakes an
                # idle rpc thread so an exited process is noticed promptly
                try:
                    self._enqueue(r, {"op": "ping"})
                except ReplicaDiedError:
                    pass


def fleet_tokens_exact(fleet_tokens: dict, oracle_tokens: dict) -> bool:
    """Convenience for harnesses: every document's final tokens match."""
    if set(fleet_tokens) != set(oracle_tokens):
        return False
    return all(np.array_equal(np.asarray(fleet_tokens[d]),
                              np.asarray(oracle_tokens[d]))
               for d in fleet_tokens)
