"""Replica worker: a subprocess owning one ``AsyncBatchServer``.

Spawned by ``FleetRouter`` as ``python -m repro.serving.fleet.worker``. The
RPC transport is the stdin/stdout pipe pair in the length-prefixed framing
of ``fleet.protocol``; the FIRST frame on stdin is the replica spec (arch,
seed, cold directory, server knobs), after which the worker answers request
frames until stdin closes or a ``shutdown`` op arrives.

Determinism contract: every replica builds its parameters as
``init_params(PRNGKey(seed), cfg)`` on the same machine and jax build, so
all replicas (and the router-side oracle) hold bitwise-identical weights —
that, plus the serving-snapshot migration format, is what makes a migrated
document indistinguishable from one that never moved (DESIGN.md §11).

Two op families:

* **ticket ops** (``open`` / ``edit`` / ``suggest`` / ``tokens``) admit into
  the async front end and resolve when its scheduler serves them — many per
  frame pipeline into one deadline-batched round;
* **control ops** (``close`` / ``export`` / ``import`` / ``checkpoint`` /
  ``logits`` / ``evict`` / ``barrier`` / ``stats`` / ``shutdown``) first
  drain everything admitted before them (``AsyncBatchServer.flush``), then
  touch the inner ``BatchServer`` directly — safe because this process is
  the server's only client, and the drain preserves per-document order.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import traceback

from repro.serving.fleet import cold_tier
from repro.serving.fleet.protocol import recv_msg, send_msg

# tickets admitted by this worker resolve after at most one drain of its own
# scheduler; an hour means the scheduler thread is gone, not slow
_TICKET_TIMEOUT_S = 3600.0


class _Worker:
    def __init__(self, spec: dict):
        # jax lands here (not at module import) so spec-derived env decisions
        # could still be made by the parent before the heavyweight import
        import jax

        from repro.common.compile_cache import (
            enable_persistent_compilation_cache,
        )
        from repro.configs import get_config
        from repro.models import transformer
        from repro.serving.async_server import AsyncBatchServer
        from repro.serving.batch_server import BatchServer

        # workers inherit REPRO_COMPILE_CACHE_DIR from the router's env —
        # on CI every replica replays the same compiled steps (no-op when
        # the env var is unset)
        enable_persistent_compilation_cache()
        self.replica = spec["replica"]
        self.cold_dir = spec["cold_dir"]
        cfg = get_config(spec.get("arch", "vq-opt-125m"),
                         smoke=spec.get("smoke", True))
        params = transformer.init_params(
            jax.random.PRNGKey(spec.get("seed", 0)), cfg)
        self.srv = BatchServer(params, cfg, spill_dir=self.cold_dir,
                               **spec.get("server_kwargs", {}))
        self.asrv = AsyncBatchServer(self.srv, **spec.get("async_kwargs", {}))

    # ---------------------------------------------------------------- ops

    def _cold_path(self, doc_id: str) -> str:
        return cold_tier.cold_path_for(self.cold_dir, doc_id)

    def handle_frame(self, ops: list) -> tuple[list, bool]:
        """Serve one request frame. Returns (results, keep_running)."""
        results: list = [None] * len(ops)
        tickets: list = []  # (index, ticket) — resolved before returning
        shutdown = False

        def drain() -> None:
            """Order barrier before a control op: everything admitted so far
            (this frame's tickets included) is served."""
            self.asrv.flush()
            for i, t in tickets:
                results[i] = self._collect(t)
            tickets.clear()

        for i, op in enumerate(ops):
            kind = op["op"]
            try:
                if kind == "open":
                    cold_tier.acquire_lease(self.cold_dir, op["doc_id"],
                                            self.replica)
                    tickets.append(
                        (i, self.asrv.open_document(op["doc_id"],
                                                    op["tokens"])))
                elif kind == "edit":
                    doc, e = op["doc_id"], op["edit"]
                    if e[0] == "replace":
                        t = self.asrv.submit_replace(doc, e[1], e[2])
                    elif e[0] == "insert":
                        t = self.asrv.submit_insert(doc, e[1], e[2])
                    elif e[0] == "delete":
                        t = self.asrv.submit_delete(doc, e[1])
                    else:
                        raise ValueError(f"unknown edit kind {e[0]!r}")
                    tickets.append((i, t))
                elif kind == "suggest":
                    tickets.append(
                        (i, self.asrv.suggest(op["doc_id"], op["n_new"])))
                elif kind == "tokens":
                    tickets.append((i, self.asrv.tokens(op["doc_id"])))
                elif kind == "ping":
                    results[i] = {"ok": True, "value": {
                        "pid": os.getpid(), "replica": self.replica}}
                elif kind == "barrier":
                    drain()
                    results[i] = {"ok": True, "value": None}
                elif kind == "close":
                    drain()
                    self.asrv.close_document(op["doc_id"]).result(
                        _TICKET_TIMEOUT_S)
                    # a session close retires the document everywhere: any
                    # residual shared-tier snapshot and the lease go with it
                    path = self._cold_path(op["doc_id"])
                    if os.path.exists(path):
                        os.remove(path)
                    cold_tier.release_lease(self.cold_dir, op["doc_id"],
                                            self.replica)
                    results[i] = {"ok": True, "value": None}
                elif kind == "export":
                    drain()
                    path = self._cold_path(op["doc_id"])
                    self.srv.export_document(op["doc_id"], path)
                    cold_tier.release_lease(self.cold_dir, op["doc_id"],
                                            self.replica)
                    results[i] = {"ok": True, "value": path}
                elif kind == "import":
                    drain()
                    cold_tier.acquire_lease(self.cold_dir, op["doc_id"],
                                            self.replica)
                    try:
                        self.srv.import_document(
                            op["doc_id"], self._cold_path(op["doc_id"]),
                            remove=op.get("remove", True))
                    except Exception:
                        cold_tier.release_lease(self.cold_dir, op["doc_id"],
                                                self.replica)
                        raise
                    results[i] = {"ok": True, "value": None}
                elif kind == "checkpoint":
                    drain()
                    doc_ids = op.get("doc_ids") or list(self.srv.docs)
                    for d in doc_ids:
                        self.srv.checkpoint_document(d, self._cold_path(d))
                    results[i] = {"ok": True, "value": list(doc_ids)}
                elif kind == "logits":
                    drain()
                    import numpy as np  # device array -> picklable host copy
                    results[i] = {"ok": True,
                                  "value": np.asarray(
                                      self.srv.logits(op["doc_id"]))}
                elif kind == "evict":
                    drain()
                    results[i] = {"ok": True, "value": self.srv.evict(
                        op["doc_id"], op.get("tier", "warm"))}
                elif kind == "stats":
                    drain()
                    results[i] = {"ok": True, "value": self._stats()}
                elif kind == "reset_latency":
                    # benchmark timing protocol: warmup pays the compiles,
                    # then the histograms restart for the measured pass
                    drain()
                    from repro.serving.latency import LatencyStats
                    self.srv.stats.edit_latency = LatencyStats()
                    self.srv.stats.suggest_latency = LatencyStats()
                    results[i] = {"ok": True, "value": None}
                elif kind == "shutdown":
                    drain()
                    self.asrv.close()
                    shutdown = True
                    results[i] = {"ok": True, "value": None}
                else:
                    raise ValueError(f"unknown op {kind!r}")
            except Exception as exc:
                results[i] = _err(exc)
            if shutdown:
                break
        for i, t in tickets:
            results[i] = self._collect(t)
        # ops after a shutdown in the same frame are refused, not dropped
        for i in range(len(ops)):
            if results[i] is None:
                results[i] = _err(RuntimeError("worker is shutting down"))
        return results, not shutdown

    def _collect(self, ticket) -> dict:
        try:
            return {"ok": True, "value": ticket.result(_TICKET_TIMEOUT_S)}
        except Exception as exc:
            return _err(exc)

    def _stats(self) -> dict:
        out = {
            "replica": self.replica,
            "batch": dataclasses.asdict(self.srv.stats),
            "async": dataclasses.asdict(self.asrv.stats),
            "docs_open": len(self.srv.docs),
            "hot_hit_rate": self.srv.stats.hot_hit_rate,
        }
        if self.srv._sugg is not None:
            out["suggest"] = dataclasses.asdict(self.srv.suggest_stats)
        return out


def _err(exc: BaseException) -> dict:
    return {"ok": False, "error": f"{type(exc).__name__}: {exc}",
            "cls": type(exc).__name__}


def main() -> int:
    # Claim the RPC pipe BEFORE anything can print: frames go out on a dup
    # of the original stdout, while fd 1 is redirected to stderr so stray
    # writes (jax warnings, user prints) cannot corrupt the framing.
    rpc_out = os.fdopen(os.dup(1), "wb")
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    rpc_in = os.fdopen(os.dup(0), "rb")

    try:
        spec = recv_msg(rpc_in)
        worker = _Worker(spec)
    except Exception as exc:
        traceback.print_exc(file=sys.stderr)
        try:
            send_msg(rpc_out, {"ok": False, "error": str(exc)})
        except Exception:
            pass
        return 1
    send_msg(rpc_out, {"ok": True, "pid": os.getpid(),
                       "replica": worker.replica})
    running = True
    while running:
        try:
            req = recv_msg(rpc_in)
        except EOFError:
            # router gone (or clean stdin close): drain and exit quietly so
            # a crashed router never leaves orphan replicas behind
            try:
                worker.asrv.close()
            except Exception:
                pass
            break
        results, running = worker.handle_frame(req.get("ops", []))
        send_msg(rpc_out, {"id": req.get("id"), "results": results})
    return 0


if __name__ == "__main__":
    sys.exit(main())
