"""TPU-native incremental inference: the static-shape, jit-able version of
``repro.core.incremental`` (DESIGN.md §3 "dirty-slot buffers").

The host-side NumPy engine uses dynamic dirty sets and dynamically grows /
shrinks its arrays on insert and delete — ideal for op counting, impossible
to jit. This module implements the same algorithm for the FULL edit algebra
(replace / insert / delete) with **static capacities** over a **slot-buffer
document layout**:

* ``n_cap`` — slot capacity: every document lives in a fixed-size buffer of
  ``n_cap`` slots with a ``valid`` mask and an ``n_real`` count. Sequence
  order is derived from the *gapped position ids* (paper §3.3), never from
  the array index: slot j precedes slot i iff ``positions[j] <= positions[i]``
  and both are valid. Inserting a token claims any free slot and a mid-gap
  position id; deleting invalidates a slot in place. No data moves.
* ``C`` — edit capacity: how many slots change per step (the edit bucket);
* ``R`` — propagation capacity: how many rows may change per layer.

Every step is one fixed-shape computation: gather dirty rows → dense
per-location ops → column patch over all rows (the ``incr_patch`` Pallas
kernel's math, ΔT with the old contribution subtracted and the new one
added) → re-quantize (the ``vq_assign`` trick in score space) → scatter
updates. Inserts add a column whose *old* contribution is exactly zero
(the claimed slot's ``k``/``vc`` are zeroed first; ``gelu(0)·0 = 0``),
deletes subtract their column via the same ΔT patch with the *new*
contribution zeroed — so all three ops share one compiled step. The count
renormalization that inserts/deletes imply is automatic: counts are
recomputed from the valid mask and position order each step. If more than
``R`` rows change at any layer, the step reports ``overflow=True`` and the
caller re-runs a full forward (the capacity-doubling / re-jit policy of
serving systems).

State layout (per document, all jnp, layer-stacked where possible):
  tokens:    [n_cap]  int32  (free slots hold garbage)
  positions: [n_cap]  int32  gapped ids; unique among valid slots
  valid:     [n_cap]  bool
  n_real:    []       int32  == valid.sum()
  x:      [L+1, n_cap, d]   residual stream snapshots
  q/k/v:  [L, n_cap, H, dh]
  vc:     [L, n_cap, H, Q]  per-head value·codebook products
  T:      [L, n_cap, H, Q]  accumulated scores
  codes:  [L, n_cap, hq]

Free/invalid slots carry garbage activations; every mask (causal, counts,
changed-row detection) ANDs with ``valid`` so garbage never reaches a valid
row. Exactness: identical codes / float-tolerance states vs the NumPy
engine over mixed edit streams (tests/test_jit_engine.py,
tests/test_mixed_edit_streams.py).

On top of the VQ code-match gate sits an optional **sigma-delta tier**
(``delta_threshold``, DESIGN.md §10): a code-flipped row propagates
downstream only when its recomputed hidden state drifts more than the
threshold (L∞) from the value it last transmitted. ``delta_threshold=0.0``
is bit-identical to the ungated engine by construction
(tests/test_delta_threshold.py); > 0 trades bounded activation drift for
fewer propagated rows — the tolerance knob between bit-exact serving and
aggressive reuse.

Batched serving
---------------
Because every step is a fixed-shape pure function of ``(JitState, edit
bucket)``, a fleet of documents that share the same capacities
``(n_cap, C, R)`` can be served as ONE vmapped step: stack their states
along a leading batch axis and vmap ``_full_forward_impl`` /
``_apply_edits_impl`` (``repro.serving.batch_engine.BatchedJitEngine``).
Overflow is reported per-document — the scheduler
(``repro.serving.batch_server.BatchServer``) re-runs only the overflowed
documents with a full forward and doubles their row capacity ``R`` (a
re-jit, amortized over the fleet). The un-jitted ``*_impl`` methods exist
precisely so the batched engine can wrap them in ``jit(vmap(...))``
without nesting jit caches.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

# Edit opcodes for the generic ``apply_edits`` step (int32 bucket entries).
OP_REPLACE = 0
OP_INSERT = 1
OP_DELETE = 2


class JitState(NamedTuple):
    tokens: jax.Array  # [n_cap] int32
    positions: jax.Array  # [n_cap] int32 (gapped ids; order == sequence order)
    valid: jax.Array  # [n_cap] bool
    n_real: jax.Array  # [] int32
    x: jax.Array  # [L+1, n_cap, d]
    q: jax.Array  # [L, n_cap, H, dh]
    k: jax.Array
    v: jax.Array
    vc: jax.Array  # [L, n_cap, H, Q]
    T: jax.Array  # [L, n_cap, H, Q]
    codes: jax.Array  # [L, n_cap, hq]


class KVExport(NamedTuple):
    """Position-ordered view of a slot buffer's cached keys/values — the
    bridge from the incremental engine to a standard decode KV cache
    (DESIGN.md §5 "suggestion serving").

    All arrays keep the fixed ``n_cap`` extent (jit-friendly): the first
    ``n_real`` rows are the document's valid slots in sequence (position-id)
    order, the tail rows are invalid slots' garbage — a decode cache built
    from this export masks them with its length counter. Every layer column
    the incremental passes left untouched is bit-exact against the
    document's last full forward; touched columns are float-close (the ΔT
    patch accumulates in a different order), which is why the suggestion
    engine re-prefills from the earliest invalidated position instead of
    trusting them bitwise.
    """

    tokens: jax.Array  # [n_cap] int32, sequence-ordered (valid rows first)
    positions: jax.Array  # [n_cap] int32
    order: jax.Array  # [n_cap] int32 — slot index per sequence rank
    k: jax.Array  # [L, n_cap, H, dh] sequence-ordered cached keys
    v: jax.Array  # [L, n_cap, H, dh] sequence-ordered cached values
    n_real: jax.Array  # [] int32 — rows 0..n_real-1 are real


def state_to_host(state: JitState) -> JitState:
    """Snapshot a device-resident ``JitState`` into host-owned numpy arrays.

    The copy is eager (``np.array(..., copy=True)``) so the returned leaves
    share no storage with device buffers — evicting the device state frees
    its memory immediately instead of keeping it alive through a zero-copy
    view (the CPU backend hands out views from ``device_get``). The host
    snapshot is the warm tier of ``repro.serving.state_store`` and the
    payload of its cold (disk) tier; ``state_from_host`` re-uploads it
    bit-exactly."""
    import numpy as np

    return JitState(*(np.array(jax.device_get(leaf), copy=True)
                      for leaf in state))


def state_from_host(host_state: JitState) -> JitState:
    """Re-upload a ``state_to_host`` snapshot. Bit-exact: every leaf is a
    plain dtype round-trip (no recompute), so a rehydrated document is
    indistinguishable from one that was never evicted. The host arrays are
    store-owned and never mutated after the snapshot, so the asynchronous
    device read (see ``batch_server._device_copy``) cannot race anything."""
    return JitState(*(jnp.asarray(leaf) for leaf in host_state))


def state_nbytes(state: JitState) -> int:
    """Exact byte footprint of one document's state (any tier: the device
    layout, the host snapshot and the npz payload all share dtypes)."""
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(state))


def state_nbytes_for(n_cap: int, n_layers: int, meta: dict) -> int:
    """``state_nbytes`` from shapes alone — what a capacity-``n_cap``
    document WILL occupy, before its state exists (the store admits new
    documents and ``n_cap``-doubling re-ingests against this). ``meta`` is
    the engine's weight metadata (``JitIncrementalEngine.meta``). Must match
    ``state_nbytes`` of a real state leaf-for-leaf
    (tests/test_state_store.py::test_state_nbytes_formula_matches)."""
    L, d, H, dh, Q, hq = (n_layers, meta["d"], meta["H"], meta["dh"],
                          meta["Q"], meta["hq"])
    f32 = 4
    return (
        n_cap * 4            # tokens int32
        + n_cap * 4          # positions int32
        + n_cap * 1          # valid bool
        + 4                  # n_real int32
        + (L + 1) * n_cap * d * f32          # x
        + 3 * L * n_cap * H * dh * f32       # q, k, v
        + 2 * L * n_cap * H * Q * f32        # vc, T
        + L * n_cap * hq * 4                 # codes int32
    )


def state_nbytes_for_config(cfg: ArchConfig, n_cap: int) -> int:
    """``state_nbytes_for`` straight from an ``ArchConfig`` — for sizing a
    device budget BEFORE any engine (and its weight flattening) exists,
    e.g. ``BatchServer(device_budget_bytes=k * state_nbytes_for_config(...))``.
    Uses the same field mapping as ``core.incremental.IncrementalEngine``."""
    if cfg.vqt is None:
        raise ValueError("state sizing requires a VQT config")
    meta = dict(d=cfg.d_model, H=cfg.n_heads, dh=cfg.resolved_head_dim,
                Q=cfg.vqt.codebook_size, hq=cfg.vqt.n_heads)
    return state_nbytes_for(n_cap, cfg.n_layers, meta)


def _weights_from_params(params: dict, cfg: ArchConfig):
    """Flatten stage params into per-layer stacked arrays (the engine's
    LayerWeights, vectorized over L)."""
    import numpy as np

    from repro.core.incremental import IncrementalEngine

    eng = IncrementalEngine(params, cfg)  # reuse its (validated) extraction
    stack = lambda f: jnp.asarray(np.stack([f(W) for W in eng.layers]))
    W = {
        "ln1_s": stack(lambda w: w.ln1_s), "ln1_b": stack(lambda w: w.ln1_b),
        "wq": stack(lambda w: w.wq), "bq": stack(lambda w: w.bq),
        "wk": stack(lambda w: w.wk), "bk": stack(lambda w: w.bk),
        "wv": stack(lambda w: w.wv), "bv": stack(lambda w: w.bv),
        "bo": stack(lambda w: w.bo),
        "ln2_s": stack(lambda w: w.ln2_s), "ln2_b": stack(lambda w: w.ln2_b),
        "w_up": stack(lambda w: w.w_up), "b_up": stack(lambda w: w.b_up),
        "w_down": stack(lambda w: w.w_down), "b_down": stack(lambda w: w.b_down),
        "cb_per_head": stack(
            lambda w: w.codebook.reshape(eng.hq, eng.Q, eng.heads_per_vq, eng.dh)
            .transpose(0, 2, 1, 3).reshape(eng.H, eng.Q, eng.dh)
        ),
        "vq_bias": stack(lambda w: w.vq_bias),
        "c_wo": stack(lambda w: w.c_wo),
    }
    meta = dict(H=eng.H, dh=eng.dh, d=eng.d, hq=eng.hq, Q=eng.Q,
                heads_per_vq=eng.heads_per_vq, scale=float(eng.scale))
    extras = {
        "tok_emb": jnp.asarray(eng.tok_emb), "pos_emb": jnp.asarray(eng.pos_emb),
        "fn_s": jnp.asarray(eng.fn_s), "fn_b": jnp.asarray(eng.fn_b),
        "head_w": jnp.asarray(eng.head_w),
    }
    return W, extras, meta


def _ln(x, s, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * s + b


def _gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True)


def _order_masks(positions: jax.Array, valid: jax.Array):
    """Causal structure of a slot buffer, derived from position-id order.

    causal[i, j] = valid[j] & (positions[j] <= positions[i]) — slot j is an
    attended (past-or-self) column of slot i. Position ids are unique among
    valid slots (the allocator's invariant), so <= is a strict order plus
    self. counts[i] = number of columns row i attends (clamped to 1 so
    invalid rows' garbage normalization never divides by zero).
    """
    causal = ((positions[None, :] <= positions[:, None])
              & valid[None, :]).astype(jnp.float32)  # [n, n] rows=i, cols=j
    counts = jnp.maximum(causal.sum(-1), 1.0)  # [n]
    return causal, counts


class JitIncrementalEngine:
    """Static-capacity incremental engine for the full VQT edit algebra."""

    def __init__(self, params: dict, cfg: ArchConfig, *, edit_capacity: int = 8,
                 row_capacity: int = 64, use_patch_kernel: bool = False,
                 use_fused_kernel: bool = False, delta_threshold: float = 0.0,
                 _weights=None):
        self.cfg = cfg
        self.C = edit_capacity
        self.R = row_capacity
        # Route the column patch through the incr_patch Pallas kernel instead
        # of the inline einsum (same math; the kernel adds a batch grid
        # dimension under vmap — see batch_engine.py).
        self.use_patch_kernel = use_patch_kernel
        # Fuse column patch + T accumulate + requantize into ONE Pallas
        # launch per layer (kernels/fused_step, DESIGN.md §9). Wins over
        # use_patch_kernel, which it subsumes.
        self.use_fused_kernel = use_fused_kernel
        # Sigma-delta propagation gate (DESIGN.md §10): a VQ-code-flipped
        # row propagates downstream only when its recomputed next-layer
        # value drifts more than this (L∞) from the value it last
        # transmitted. 0.0 (the default) traces the EXACT pre-threshold
        # jaxpr — bit-identical serving — because the gate is guarded at
        # the Python level, never by a traced compare. The engine is a jit
        # static arg, so the Python float is a compile-time constant.
        if delta_threshold < 0.0:
            raise ValueError("delta_threshold must be >= 0")
        self.delta_threshold = float(delta_threshold)
        if _weights is not None:
            self.W, self.extras, self.meta = _weights
        else:
            self.W, self.extras, self.meta = _weights_from_params(params, cfg)
        self.L = self.W["wq"].shape[0]

    @property
    def weights(self):
        """(W, extras, meta) — pass as ``_weights=`` to share the extracted
        parameter stacks between sibling engines (e.g. per-capacity-bucket
        re-jits in the batch server)."""
        return self.W, self.extras, self.meta

    # ------------------------------------------------------------ full pass

    @functools.partial(jax.jit, static_argnums=0)
    def full_forward(self, tokens: jax.Array, positions: jax.Array,
                     valid: Optional[jax.Array] = None) -> JitState:
        """Ingest a slot buffer. ``valid=None`` means every slot is real (the
        plain fixed-length document of the replace-only path)."""
        return self._full_forward_impl(tokens, positions, valid)

    def _full_forward_impl(self, tokens: jax.Array, positions: jax.Array,
                           valid: Optional[jax.Array] = None) -> JitState:
        m = self.meta
        n = tokens.shape[0]
        if valid is None:
            valid = jnp.ones((n,), bool)
        valid = valid.astype(bool)
        x0 = self.extras["tok_emb"][tokens] + self.extras["pos_emb"][positions]
        causal, counts = _order_masks(positions, valid)

        def layer(x, Wl):
            h = _ln(x, Wl["ln1_s"], Wl["ln1_b"])
            q = jnp.einsum("nd,dhe->nhe", h, Wl["wq"]) + Wl["bq"]
            k = jnp.einsum("nd,dhe->nhe", h, Wl["wk"]) + Wl["bk"]
            v = jnp.einsum("nd,dhe->nhe", h, Wl["wv"]) + Wl["bv"]
            vc = jnp.einsum("nhe,hqe->nhq", v, Wl["cb_per_head"])
            w = _gelu(jnp.einsum("nhe,jhe->hnj", q, k) * m["scale"]) * causal[None]
            T = jnp.einsum("hnj,jhq->nhq", w, vc)
            s = T.reshape(n, m["hq"], m["heads_per_vq"], m["Q"]).sum(2)
            s = s / counts[:, None, None] + Wl["vq_bias"][None]
            codes = jnp.argmax(s, axis=-1).astype(jnp.int32)
            attn = Wl["bo"][None] + sum(
                Wl["c_wo"][hh][codes[:, hh]] for hh in range(m["hq"])
            )
            x_mid = x + attn
            h2 = _ln(x_mid, Wl["ln2_s"], Wl["ln2_b"])
            ffn = _gelu(h2 @ Wl["w_up"] + Wl["b_up"]) @ Wl["w_down"] + Wl["b_down"]
            return x_mid + ffn, (q, k, v, vc, T, codes)

        xs = [x0]
        qs, ks, vs, vcs, Ts, cds = [], [], [], [], [], []
        x = x0
        for li in range(self.L):
            Wl = jax.tree.map(lambda a: a[li], self.W)
            x, (q, k, v, vc, T, codes) = layer(x, Wl)
            xs.append(x)
            qs.append(q); ks.append(k); vs.append(v)
            vcs.append(vc); Ts.append(T); cds.append(codes)
        st = lambda l: jnp.stack(l)
        return JitState(tokens.astype(jnp.int32), positions.astype(jnp.int32),
                        valid, valid.sum(dtype=jnp.int32),
                        st(xs), st(qs), st(ks), st(vs), st(vcs), st(Ts), st(cds))

    # ------------------------------------------------------------ edit step

    @functools.partial(jax.jit, static_argnums=0)
    def apply_edits(self, state: JitState, slot: jax.Array, tok: jax.Array,
                    pos_id: jax.Array, op: jax.Array
                    ) -> tuple[JitState, jax.Array]:
        """The generic fixed-shape edit step: up to ``C`` typed edits at once.

        slot:   [C] int32 — target slot (pad unused entries with -1);
        tok:    [C] int32 — new token (replace/insert; ignored for delete);
        pos_id: [C] int32 — fresh gapped position id (insert only);
        op:     [C] int32 — OP_REPLACE / OP_INSERT / OP_DELETE.

        Bucket invariants (the scheduler's job): slots are distinct within a
        bucket; an insert targets a *free* slot with a position id strictly
        between its sequence neighbours'; replace/delete target valid slots.
        Returns (new_state, overflow) — overflow=True means the propagation
        bucket R was exceeded at some layer and the result is UNRELIABLE
        (caller must full_forward). Overflow is detected on the PRE-gate
        changed set, so a ``delta_threshold`` never masks an overflow —
        thresholding only ever makes the flag conservative."""
        return self._apply_edits_impl(state, slot, tok, pos_id, op)

    @functools.partial(jax.jit, static_argnums=0)
    def apply_replaces(self, state: JitState, edit_pos: jax.Array,
                       edit_tok: jax.Array) -> tuple[JitState, jax.Array]:
        """Replace-only bucket (back-compat surface). edit_pos: [C] int32
        slot indices (pad with -1); edit_tok: [C] int32."""
        z = jnp.zeros_like(edit_pos)
        return self._apply_edits_impl(state, edit_pos, edit_tok, z, z)

    @functools.partial(jax.jit, static_argnums=0)
    def apply_inserts(self, state: JitState, slot: jax.Array, tok: jax.Array,
                      pos_id: jax.Array) -> tuple[JitState, jax.Array]:
        """Insert-only bucket: claim free slots ``slot`` (pad with -1), give
        them tokens ``tok`` and fresh mid-gap position ids ``pos_id``."""
        op = jnp.where(slot >= 0, OP_INSERT, 0).astype(jnp.int32)
        return self._apply_edits_impl(state, slot, tok, pos_id, op)

    @functools.partial(jax.jit, static_argnums=0)
    def apply_deletes(self, state: JitState,
                      slot: jax.Array) -> tuple[JitState, jax.Array]:
        """Delete-only bucket: invalidate slots ``slot`` (pad with -1) and
        subtract their column contributions."""
        z = jnp.zeros_like(slot)
        op = jnp.where(slot >= 0, OP_DELETE, 0).astype(jnp.int32)
        return self._apply_edits_impl(state, slot, z, z, op)

    def _apply_edits_impl(self, state: JitState, slot: jax.Array,
                          tok: jax.Array, pos_id: jax.Array, op: jax.Array
                          ) -> tuple[JitState, jax.Array]:
        m = self.meta
        R = self.R
        n = state.tokens.shape[0]
        valid_e = slot >= 0
        slot_safe = jnp.where(valid_e, slot, 0)
        opv = jnp.where(valid_e, op, -1)
        is_ins = opv == OP_INSERT
        is_del = opv == OP_DELETE
        has_new = valid_e & ~is_del  # slot holds a (new) token afterwards
        had_old = valid_e & ~is_ins  # slot contributed a column before

        # -------- slot metadata: tokens / positions / valid / n_real
        # Masked bucket entries scatter to index n — out of bounds, so
        # mode="drop" discards them (NOT -1, which jnp wraps to the last
        # slot) — no read-modify-write dance, no duplicate-index hazards.
        drop = jnp.int32(n)
        tokens = state.tokens.at[jnp.where(has_new, slot, drop)].set(
            tok, mode="drop")
        positions = state.positions.at[jnp.where(is_ins, slot, drop)].set(
            pos_id, mode="drop")
        # Deleted slots keep their position id: the ΔT patch below still
        # needs it to address the rows that used to attend the column.
        valid = state.valid.at[jnp.where(is_ins, slot, drop)].set(
            True, mode="drop")
        valid = valid.at[jnp.where(is_del, slot, drop)].set(False, mode="drop")
        n_real = (state.n_real + is_ins.sum(dtype=jnp.int32)
                  - is_del.sum(dtype=jnp.int32))

        causal, counts = _order_masks(positions, valid)

        # Inserted slots may hold a stale tenant's activations. Zero their
        # k/vc across all layers so the "old contribution" the ΔT patch
        # subtracts is exactly zero (gelu(0)·0 = 0) — the slot-buffer
        # analogue of the NumPy engine inserting a zero row.
        ins_slot = jnp.where(is_ins, slot, drop)
        k_base = state.k.at[:, ins_slot].set(0.0, mode="drop")
        vc_base = state.vc.at[:, ins_slot].set(0.0, mode="drop")

        # layer-0 dirty bucket = the edit bucket
        x_rows = (self.extras["tok_emb"][tokens[slot_safe]]
                  + self.extras["pos_emb"][positions[slot_safe]])
        new_x = [state.x[0].at[jnp.where(has_new, slot, drop)].set(
            x_rows, mode="drop")]
        # rows to recompute this layer (gathered indices + occupancy mask)
        dirty_idx = slot_safe  # [C]
        new_mask = has_new
        # columns to patch this layer. A deleted slot contributes an
        # old-only column at EVERY layer (its cached k/vc still sit in every
        # layer's T sums), but it is never a recomputed row — so the column
        # set is the row set at layer 0 and row-set ∪ delete-slots below.
        col_idx = slot_safe
        col_old = had_old  # subtract the old contribution of these columns
        col_new = has_new  # add the new contribution of these columns

        new_q, new_k, new_v, new_vc, new_T, new_codes = [], [], [], [], [], []
        overflow = jnp.asarray(False)

        for li in range(self.L):
            Wl = jax.tree.map(lambda a: a[li], self.W)
            x_in = new_x[li]
            # per-location at dirty rows (garbage lanes are masked out below)
            h = _ln(x_in[dirty_idx], Wl["ln1_s"], Wl["ln1_b"])
            q_n = jnp.einsum("cd,dhe->che", h, Wl["wq"]) + Wl["bq"]
            k_n = jnp.einsum("cd,dhe->che", h, Wl["wk"]) + Wl["bk"]
            v_n = jnp.einsum("cd,dhe->che", h, Wl["wv"]) + Wl["bv"]
            vc_n = jnp.einsum("che,hqe->chq", v_n, Wl["cb_per_head"])

            upd = jnp.where(new_mask, dirty_idx, drop)
            q_all = state.q[li].at[upd].set(q_n, mode="drop")
            k_all = k_base[li].at[upd].set(k_n, mode="drop")
            v_all = state.v[li].at[upd].set(v_n, mode="drop")
            vc_all = vc_base[li].at[upd].set(vc_n, mode="drop")
            k_old = k_base[li][col_idx]
            vc_old = vc_base[li][col_idx] * col_old[:, None, None]
            k_new = k_all[col_idx]
            vc_new = vc_all[col_idx] * col_new[:, None, None]

            # column patch over ALL rows: ΔT = new − old contributions.
            # Column order comes from position ids; rows are masked by the
            # valid mask so free slots never accumulate patches.
            col_mask = (
                (col_old | col_new)[None, :]
                & (positions[col_idx][None, :] <= positions[:, None])
            ).astype(jnp.float32)  # [n, Cd]
            row_valid = valid.astype(jnp.float32)
            # dirty rows: full row recompute (their causal row of the
            # position-order mask already reflects inserts/deletes). Hoisted
            # before the patch so the fused path can pre-scatter it and
            # exclude those rows from the patch mask — per row the result is
            # identical to patch-then-overwrite (a dirty row's patch was
            # discarded by the overwrite; a clean row's patch is unchanged).
            causal_rows = causal[dirty_idx]  # [Cd, n]
            w_rows = _gelu(jnp.einsum("che,jhe->hcj", q_all[dirty_idx], k_all)
                           * m["scale"]) * causal_rows[None]
            T_rows = jnp.einsum("hcj,jhq->chq", w_rows, vc_all)
            if self.use_fused_kernel:
                from repro.kernels.fused_step import fused_patch_assign

                # patch + T accumulate + requantize in ONE launch: the mask
                # folds every gate (live columns, causal order, row
                # validity, dirty-row exclusion), so the compiled shape is
                # blind to which rows/columns are live — the ragged
                # capacity-class contract (DESIGN.md §9)
                dirty_dense = jnp.zeros((n,), jnp.float32).at[upd].set(
                    1.0, mode="drop")
                pmask = col_mask * (row_valid * (1.0 - dirty_dense))[:, None]
                T_base = state.T[li].at[upd].set(T_rows, mode="drop")
                T_all, codes = fused_patch_assign(
                    state.q[li],
                    k_new.transpose(1, 0, 2),
                    k_old.transpose(1, 0, 2),
                    vc_new.transpose(1, 0, 2),
                    vc_old.transpose(1, 0, 2),
                    pmask, T_base, counts, Wl["vq_bias"],
                    heads_per_vq=m["heads_per_vq"],
                )
            else:
                if self.use_patch_kernel:
                    from repro.kernels.incr_patch import incr_patch

                    dT = incr_patch(
                        state.q[li],
                        k_new.transpose(1, 0, 2),
                        k_old.transpose(1, 0, 2),
                        vc_new.transpose(1, 0, 2),
                        vc_old.transpose(1, 0, 2),
                        col_mask,
                        row_valid=row_valid,
                    )
                else:
                    cm = col_mask * row_valid[:, None]
                    s_new = jnp.einsum("nhe,che->nhc", state.q[li],
                                       k_new) * m["scale"]
                    s_old = jnp.einsum("nhe,che->nhc", state.q[li],
                                       k_old) * m["scale"]
                    dT = jnp.einsum("nhc,chq->nhq",
                                    _gelu(s_new) * cm[:, None, :],
                                    vc_new) - jnp.einsum(
                        "nhc,chq->nhq", _gelu(s_old) * cm[:, None, :], vc_old)
                T_all = state.T[li] + dT
                T_all = T_all.at[upd].set(T_rows, mode="drop")

                # re-quantize all rows (cheap: O(n·Q)); counts
                # renormalization after inserts/deletes is automatic —
                # counts came from the mask
                s = T_all.reshape(n, m["hq"], m["heads_per_vq"], m["Q"]).sum(2)
                s = s / counts[:, None, None] + Wl["vq_bias"][None]
                codes = jnp.argmax(s, axis=-1).astype(jnp.int32)

            changed = jnp.any(codes != state.codes[li], axis=-1) & valid
            changed = changed.at[upd].set(True, mode="drop")
            n_changed = changed.sum()
            overflow = overflow | (n_changed > R)

            # gather up to R changed rows into the next dirty bucket
            scores = jnp.where(changed, 1.0, 0.0)
            _, next_idx = jax.lax.top_k(scores, min(R, n))
            next_valid = changed[next_idx]

            attn = Wl["bo"][None] + sum(
                Wl["c_wo"][hh][codes[next_idx][:, hh]] for hh in range(m["hq"])
            )
            x_mid = x_in[next_idx] + attn
            h2 = _ln(x_mid, Wl["ln2_s"], Wl["ln2_b"])
            ffn = _gelu(h2 @ Wl["w_up"] + Wl["b_up"]) @ Wl["w_down"] + Wl["b_down"]
            x_out_rows = x_mid + ffn

            keep = next_valid
            if self.delta_threshold > 0.0:
                # Sigma-delta gate (DESIGN.md §10): compare each selected
                # row's fresh recompute against the value it LAST
                # TRANSMITTED — the stored x[li+1] row — so sub-threshold
                # drift accumulates across steps and is re-examined on
                # every later code flip. Suppressed rows still take their
                # new T/codes at THIS layer (the quantizer state advances;
                # only the transmission is withheld), write nothing to
                # x[li+1], and are excluded from the next layer's dirty
                # bucket and patch columns — i.e. the keep bits fold into
                # the next layer's engine-built mask. The Python-level
                # guard keeps the threshold-0 jaxpr untouched.
                x_prev_rows = state.x[li + 1][next_idx]
                if self.use_fused_kernel:
                    from repro.kernels.fused_step import delta_gate

                    moved = delta_gate(x_out_rows, x_prev_rows,
                                       self.delta_threshold)
                else:
                    moved = (jnp.max(jnp.abs(x_out_rows - x_prev_rows),
                                     axis=-1) > self.delta_threshold)
                keep = next_valid & moved

            x_next = state.x[li + 1].at[jnp.where(keep, next_idx,
                                                   drop)].set(
                x_out_rows, mode="drop")
            new_x.append(x_next)
            new_q.append(q_all); new_k.append(k_all); new_v.append(v_all)
            new_vc.append(vc_all); new_T.append(T_all); new_codes.append(codes)
            dirty_idx = next_idx
            new_mask = keep
            # deeper layers: propagated rows patch old→new; deleted slots
            # keep riding along as old-only columns
            col_idx = jnp.concatenate([next_idx, slot_safe])
            col_old = jnp.concatenate([keep, is_del])
            col_new = jnp.concatenate([keep,
                                       jnp.zeros_like(is_del)])

        st = lambda l: jnp.stack(l)
        return JitState(tokens, positions, valid, n_real, st(new_x), st(new_q),
                        st(new_k), st(new_v), st(new_vc), st(new_T),
                        st(new_codes)), overflow

    # ------------------------------------------------------- state surgery

    @functools.partial(jax.jit, static_argnums=(0, 2, 3))
    def pad_state(self, state: JitState, new_cap: int,
                  pos_fill: int = 0) -> JitState:
        """Grow a document's device buffers to a larger capacity class — the
        device-side replacement for the grow-time host re-ingest.

        Appended slots are free (``valid=False``, position ``pos_fill`` —
        the scheduler's pool sentinel — token 0, zero activations): exactly
        the reserve slots a fresh ingest at the bigger class would carry, so
        the first insert into one takes the ordinary insert-into-free-slot
        path (``apply_edits`` zeroes the claimed slot's k/vc itself).
        Existing slots keep their bits untouched — valid rows stay exactly
        what the incremental history produced, no full forward, no host
        round-trip. O(state bytes) device copy; the first dispatch at the
        new class re-jits (the capacity-class-doubling policy)."""
        n = state.tokens.shape[0]
        if new_cap < n:
            raise ValueError(f"pad_state cannot shrink ({n} -> {new_cap})")
        extra = new_cap - n
        tail = lambda a: [(0, 0)] * (a.ndim - 2)
        pad_slot = lambda a: jnp.pad(a, [(0, 0), (0, extra)] + tail(a))
        return JitState(
            tokens=jnp.pad(state.tokens, (0, extra)),
            positions=jnp.pad(state.positions, (0, extra),
                              constant_values=pos_fill),
            valid=jnp.pad(state.valid, (0, extra)),
            n_real=state.n_real,
            x=pad_slot(state.x), q=pad_slot(state.q), k=pad_slot(state.k),
            v=pad_slot(state.v), vc=pad_slot(state.vc), T=pad_slot(state.T),
            codes=pad_slot(state.codes),
        )

    @functools.partial(jax.jit, static_argnums=0)
    def gather_slots(self, state: JitState, order: jax.Array) -> JitState:
        """Permute the slot axis of every leaf by ``order`` ([n_cap] int32,
        a permutation) — the device-side slot rearrangement primitive
        (defrag compaction: valid slots to the front in sequence order, free
        slots to the tail). One fused gather, no host mirror round-trip.
        ``n_real`` is order-invariant. Position ids still name the OLD
        layout's embeddings, so a defrag follows this with the re-spread +
        ``full_forward`` (see ``BatchServer._defrag``)."""
        return JitState(
            tokens=state.tokens[order],
            positions=state.positions[order],
            valid=state.valid[order],
            n_real=state.n_real,
            x=jnp.take(state.x, order, axis=1),
            q=jnp.take(state.q, order, axis=1),
            k=jnp.take(state.k, order, axis=1),
            v=jnp.take(state.v, order, axis=1),
            vc=jnp.take(state.vc, order, axis=1),
            T=jnp.take(state.T, order, axis=1),
            codes=jnp.take(state.codes, order, axis=1),
        )

    # ------------------------------------------------------------ kv export

    @functools.partial(jax.jit, static_argnums=0)
    def export_kv(self, state: JitState) -> KVExport:
        """Gather the slot buffer's cached k/v into sequence order — the
        ``JitState -> KV cache`` bridge for continuation ("suggestion")
        decoding. One fixed-shape gather; see ``KVExport`` for the
        exactness contract."""
        return self._export_kv_impl(state)

    def _export_kv_impl(self, state: JitState) -> KVExport:
        # Invalid slots sort last: their position ids may hold the pool
        # sentinel (which a valid slot could in principle share), so the
        # sort key is lifted above every real id instead of trusting it.
        big = jnp.iinfo(jnp.int32).max
        order = jnp.argsort(jnp.where(state.valid, state.positions, big))
        return KVExport(
            tokens=state.tokens[order],
            positions=state.positions[order],
            order=order.astype(jnp.int32),
            k=jnp.take(state.k, order, axis=1),
            v=jnp.take(state.v, order, axis=1),
            n_real=state.n_real,
        )

    # ------------------------------------------------------------ outputs

    @functools.partial(jax.jit, static_argnums=0)
    def logits_last(self, state: JitState) -> jax.Array:
        return self._logits_at_impl(state, -1)

    @functools.partial(jax.jit, static_argnums=0)
    def logits_at(self, state: JitState, index: jax.Array) -> jax.Array:
        """Logits at an arbitrary slot — the batched server pads documents to
        a capacity bucket, so "last token" is the slot holding the
        largest-position valid row (the host scheduler tracks it), not -1."""
        return self._logits_at_impl(state, index)

    def _logits_at_impl(self, state: JitState, index: jax.Array) -> jax.Array:
        h = _ln(state.x[-1][index][None], self.extras["fn_s"],
                self.extras["fn_b"])[0]
        return h @ self.extras["head_w"]
