"""TPU-native incremental inference: the static-shape, jit-able version of
``repro.core.incremental`` (DESIGN.md §3 "dirty-slot buffers").

The host-side NumPy engine uses dynamic dirty sets — ideal for op counting,
impossible to jit. This module implements the same algorithm for REPLACE
edits with **static capacities**:

* ``C`` — edit capacity: how many columns change per step (the edit bucket);
* ``R`` — propagation capacity: how many rows may change per layer.

Every step is one fixed-shape computation: gather dirty rows → dense
per-location ops → column patch over all rows (the ``incr_patch`` Pallas
kernel's math) → re-quantize (the ``vq_assign`` trick in score space) →
scatter updates. If more than ``R`` rows change at any layer, the step
reports ``overflow=True`` and the caller re-runs a full forward (the
capacity-doubling / re-jit policy of serving systems).

State layout (per document, all jnp, layer-stacked where possible):
  x:      [L+1, n, d]   residual stream snapshots
  q/k/v:  [L, n, H, dh]
  vc:     [L, n, H, Q]  per-head value·codebook products
  T:      [L, n, H, Q]  accumulated scores
  codes:  [L, n, hq]

Exactness: identical codes / float-tolerance states vs the NumPy engine
(tested in tests/test_jit_engine.py).

Batched serving
---------------
Because every step is a fixed-shape pure function of ``(JitState, edit
bucket)``, a fleet of documents that share the same capacities ``(n, C, R)``
can be served as ONE vmapped step: stack their states along a leading batch
axis and vmap ``_full_forward_impl`` / ``_apply_replaces_impl``
(``repro.serving.batch_engine.BatchedJitEngine``). Overflow is reported
per-document — the scheduler (``repro.serving.batch_server.BatchServer``)
re-runs only the overflowed documents with a full forward and doubles their
row capacity ``R`` (a re-jit, amortized over the fleet). The un-jitted
``*_impl`` methods exist precisely so the batched engine can wrap them in
``jit(vmap(...))`` without nesting jit caches.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.common.pytree import pytree_dataclass


class JitState(NamedTuple):
    tokens: jax.Array  # [n] int32
    positions: jax.Array  # [n] int32
    x: jax.Array  # [L+1, n, d]
    q: jax.Array  # [L, n, H, dh]
    k: jax.Array
    v: jax.Array
    vc: jax.Array  # [L, n, H, Q]
    T: jax.Array  # [L, n, H, Q]
    codes: jax.Array  # [L, n, hq]


def _weights_from_params(params: dict, cfg: ArchConfig):
    """Flatten stage params into per-layer stacked arrays (the engine's
    LayerWeights, vectorized over L)."""
    import numpy as np

    from repro.core.incremental import IncrementalEngine

    eng = IncrementalEngine(params, cfg)  # reuse its (validated) extraction
    stack = lambda f: jnp.asarray(np.stack([f(W) for W in eng.layers]))
    W = {
        "ln1_s": stack(lambda w: w.ln1_s), "ln1_b": stack(lambda w: w.ln1_b),
        "wq": stack(lambda w: w.wq), "bq": stack(lambda w: w.bq),
        "wk": stack(lambda w: w.wk), "bk": stack(lambda w: w.bk),
        "wv": stack(lambda w: w.wv), "bv": stack(lambda w: w.bv),
        "bo": stack(lambda w: w.bo),
        "ln2_s": stack(lambda w: w.ln2_s), "ln2_b": stack(lambda w: w.ln2_b),
        "w_up": stack(lambda w: w.w_up), "b_up": stack(lambda w: w.b_up),
        "w_down": stack(lambda w: w.w_down), "b_down": stack(lambda w: w.b_down),
        "cb_per_head": stack(
            lambda w: w.codebook.reshape(eng.hq, eng.Q, eng.heads_per_vq, eng.dh)
            .transpose(0, 2, 1, 3).reshape(eng.H, eng.Q, eng.dh)
        ),
        "vq_bias": stack(lambda w: w.vq_bias),
        "c_wo": stack(lambda w: w.c_wo),
    }
    meta = dict(H=eng.H, dh=eng.dh, d=eng.d, hq=eng.hq, Q=eng.Q,
                heads_per_vq=eng.heads_per_vq, scale=float(eng.scale))
    extras = {
        "tok_emb": jnp.asarray(eng.tok_emb), "pos_emb": jnp.asarray(eng.pos_emb),
        "fn_s": jnp.asarray(eng.fn_s), "fn_b": jnp.asarray(eng.fn_b),
        "head_w": jnp.asarray(eng.head_w),
    }
    return W, extras, meta


def _ln(x, s, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * s + b


def _gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True)


class JitIncrementalEngine:
    """Static-capacity incremental engine for VQT replace-edits."""

    def __init__(self, params: dict, cfg: ArchConfig, *, edit_capacity: int = 8,
                 row_capacity: int = 64, use_patch_kernel: bool = False,
                 _weights=None):
        self.cfg = cfg
        self.C = edit_capacity
        self.R = row_capacity
        # Route the column patch through the incr_patch Pallas kernel instead
        # of the inline einsum (same math; the kernel adds a batch grid
        # dimension under vmap — see batch_engine.py).
        self.use_patch_kernel = use_patch_kernel
        if _weights is not None:
            self.W, self.extras, self.meta = _weights
        else:
            self.W, self.extras, self.meta = _weights_from_params(params, cfg)
        self.L = self.W["wq"].shape[0]

    @property
    def weights(self):
        """(W, extras, meta) — pass as ``_weights=`` to share the extracted
        parameter stacks between sibling engines (e.g. per-capacity-bucket
        re-jits in the batch server)."""
        return self.W, self.extras, self.meta

    # ------------------------------------------------------------ full pass

    @functools.partial(jax.jit, static_argnums=0)
    def full_forward(self, tokens: jax.Array, positions: jax.Array) -> JitState:
        return self._full_forward_impl(tokens, positions)

    def _full_forward_impl(self, tokens: jax.Array, positions: jax.Array) -> JitState:
        m = self.meta
        n = tokens.shape[0]
        x0 = self.extras["tok_emb"][tokens] + self.extras["pos_emb"][positions]
        counts = jnp.arange(1, n + 1, dtype=jnp.float32)
        causal = (jnp.arange(n)[None, :] <= jnp.arange(n)[:, None]).astype(jnp.float32)

        def layer(x, Wl):
            h = _ln(x, Wl["ln1_s"], Wl["ln1_b"])
            q = jnp.einsum("nd,dhe->nhe", h, Wl["wq"]) + Wl["bq"]
            k = jnp.einsum("nd,dhe->nhe", h, Wl["wk"]) + Wl["bk"]
            v = jnp.einsum("nd,dhe->nhe", h, Wl["wv"]) + Wl["bv"]
            vc = jnp.einsum("nhe,hqe->nhq", v, Wl["cb_per_head"])
            w = _gelu(jnp.einsum("nhe,jhe->hnj", q, k) * m["scale"]) * causal[None]
            T = jnp.einsum("hnj,jhq->nhq", w, vc)
            s = T.reshape(n, m["hq"], m["heads_per_vq"], m["Q"]).sum(2)
            s = s / counts[:, None, None] + Wl["vq_bias"][None]
            codes = jnp.argmax(s, axis=-1).astype(jnp.int32)
            attn = Wl["bo"][None] + sum(
                Wl["c_wo"][hh][codes[:, hh]] for hh in range(m["hq"])
            )
            x_mid = x + attn
            h2 = _ln(x_mid, Wl["ln2_s"], Wl["ln2_b"])
            ffn = _gelu(h2 @ Wl["w_up"] + Wl["b_up"]) @ Wl["w_down"] + Wl["b_down"]
            return x_mid + ffn, (q, k, v, vc, T, codes)

        xs = [x0]
        qs, ks, vs, vcs, Ts, cds = [], [], [], [], [], []
        x = x0
        for li in range(self.L):
            Wl = jax.tree.map(lambda a: a[li], self.W)
            x, (q, k, v, vc, T, codes) = layer(x, Wl)
            xs.append(x)
            qs.append(q); ks.append(k); vs.append(v)
            vcs.append(vc); Ts.append(T); cds.append(codes)
        st = lambda l: jnp.stack(l)
        return JitState(tokens.astype(jnp.int32), positions.astype(jnp.int32),
                        st(xs), st(qs), st(ks), st(vs), st(vcs), st(Ts), st(cds))

    # ------------------------------------------------------------ edit step

    @functools.partial(jax.jit, static_argnums=0)
    def apply_replaces(self, state: JitState, edit_pos: jax.Array,
                       edit_tok: jax.Array) -> tuple[JitState, jax.Array]:
        """edit_pos: [C] int32 (pad with -1); edit_tok: [C] int32.
        Returns (new_state, overflow) — overflow=True means the propagation
        bucket R was exceeded at some layer and the result is UNRELIABLE
        (caller must full_forward)."""
        return self._apply_replaces_impl(state, edit_pos, edit_tok)

    def _apply_replaces_impl(self, state: JitState, edit_pos: jax.Array,
                             edit_tok: jax.Array) -> tuple[JitState, jax.Array]:
        m = self.meta
        C, R = self.C, self.R
        n = state.tokens.shape[0]
        counts = jnp.arange(1, n + 1, dtype=jnp.float32)
        valid_e = edit_pos >= 0
        pos_safe = jnp.where(valid_e, edit_pos, 0)

        tokens = state.tokens.at[pos_safe].set(
            jnp.where(valid_e, edit_tok, state.tokens[pos_safe]))
        x_rows = (self.extras["tok_emb"][tokens[pos_safe]]
                  + self.extras["pos_emb"][state.positions[pos_safe]])

        # dirty bucket for layer 0 = the edit bucket
        dirty_idx = pos_safe  # [R0 = C]
        dirty_valid = valid_e
        dirty_rows = x_rows  # new residual-stream rows at dirty_idx

        new_x = [state.x[0].at[dirty_idx].set(
            jnp.where(dirty_valid[:, None], dirty_rows, state.x[0][dirty_idx]))]
        new_q, new_k, new_v, new_vc, new_T, new_codes = [], [], [], [], [], []
        overflow = jnp.asarray(False)

        for li in range(self.L):
            Wl = jax.tree.map(lambda a: a[li], self.W)
            x_in = new_x[li]
            Cd = dirty_idx.shape[0]
            vmask = dirty_valid
            # per-location at dirty rows
            h = _ln(x_in[dirty_idx], Wl["ln1_s"], Wl["ln1_b"])
            q_n = jnp.einsum("cd,dhe->che", h, Wl["wq"]) + Wl["bq"]
            k_n = jnp.einsum("cd,dhe->che", h, Wl["wk"]) + Wl["bk"]
            v_n = jnp.einsum("cd,dhe->che", h, Wl["wv"]) + Wl["bv"]
            vc_n = jnp.einsum("che,hqe->chq", v_n, Wl["cb_per_head"])
            k_old = state.k[li][dirty_idx]
            vc_old = state.vc[li][dirty_idx]

            q_all = state.q[li].at[dirty_idx].set(
                jnp.where(vmask[:, None, None], q_n, state.q[li][dirty_idx]))
            k_all = state.k[li].at[dirty_idx].set(
                jnp.where(vmask[:, None, None], k_n, state.k[li][dirty_idx]))
            v_all = state.v[li].at[dirty_idx].set(
                jnp.where(vmask[:, None, None], v_n, state.v[li][dirty_idx]))
            vc_all = state.vc[li].at[dirty_idx].set(
                jnp.where(vmask[:, None, None], vc_n, state.vc[li][dirty_idx]))

            # column patch over ALL rows (masked): ΔT = new − old contributions
            col_mask = (
                vmask[None, :]
                & (dirty_idx[None, :] <= jnp.arange(n)[:, None])
            ).astype(jnp.float32)  # [n, Cd]
            if self.use_patch_kernel:
                from repro.kernels.incr_patch import incr_patch

                dT = incr_patch(
                    state.q[li],
                    k_all[dirty_idx].transpose(1, 0, 2),
                    k_old.transpose(1, 0, 2),
                    vc_all[dirty_idx].transpose(1, 0, 2),
                    vc_old.transpose(1, 0, 2),
                    col_mask,
                )
            else:
                s_new = jnp.einsum("nhe,che->nhc", state.q[li], k_all[dirty_idx]) * m["scale"]
                s_old = jnp.einsum("nhe,che->nhc", state.q[li], k_old) * m["scale"]
                dT = jnp.einsum("nhc,chq->nhq", _gelu(s_new) * col_mask[:, None, :],
                                vc_all[dirty_idx]) - jnp.einsum(
                    "nhc,chq->nhq", _gelu(s_old) * col_mask[:, None, :], vc_old)
            T_all = state.T[li] + dT
            # dirty rows: full row recompute
            causal_rows = (jnp.arange(n)[None, :] <= dirty_idx[:, None]).astype(
                jnp.float32)  # [Cd, n]
            w_rows = _gelu(jnp.einsum("che,jhe->hcj", q_all[dirty_idx], k_all)
                           * m["scale"]) * causal_rows[None]
            T_rows = jnp.einsum("hcj,jhq->chq", w_rows, vc_all)
            T_all = T_all.at[dirty_idx].set(
                jnp.where(vmask[:, None, None], T_rows, T_all[dirty_idx]))

            # re-quantize all rows (cheap: O(n·Q))
            s = T_all.reshape(n, m["hq"], m["heads_per_vq"], m["Q"]).sum(2)
            s = s / counts[:, None, None] + Wl["vq_bias"][None]
            codes = jnp.argmax(s, axis=-1).astype(jnp.int32)

            changed = jnp.any(codes != state.codes[li], axis=-1)
            changed = changed.at[dirty_idx].set(
                jnp.where(vmask, True, changed[dirty_idx]))
            n_changed = changed.sum()
            overflow = overflow | (n_changed > R)

            # gather up to R changed rows into the next dirty bucket
            scores = jnp.where(changed, 1.0, 0.0)
            _, next_idx = jax.lax.top_k(scores, R)
            next_valid = changed[next_idx]

            attn = Wl["bo"][None] + sum(
                Wl["c_wo"][hh][codes[next_idx][:, hh]] for hh in range(m["hq"])
            )
            x_mid = x_in[next_idx] + attn
            h2 = _ln(x_mid, Wl["ln2_s"], Wl["ln2_b"])
            ffn = _gelu(h2 @ Wl["w_up"] + Wl["b_up"]) @ Wl["w_down"] + Wl["b_down"]
            x_out_rows = x_mid + ffn

            x_next = state.x[li + 1].at[next_idx].set(
                jnp.where(next_valid[:, None], x_out_rows,
                          state.x[li + 1][next_idx]))
            new_x.append(x_next)
            new_q.append(q_all); new_k.append(k_all); new_v.append(v_all)
            new_vc.append(vc_all); new_T.append(T_all); new_codes.append(codes)
            dirty_idx, dirty_valid = next_idx, next_valid

        st = lambda l: jnp.stack(l)
        return JitState(tokens, state.positions, st(new_x), st(new_q), st(new_k),
                        st(new_v), st(new_vc), st(new_T), st(new_codes)), overflow

    # ------------------------------------------------------------ outputs

    @functools.partial(jax.jit, static_argnums=0)
    def logits_last(self, state: JitState) -> jax.Array:
        return self._logits_at_impl(state, -1)

    @functools.partial(jax.jit, static_argnums=0)
    def logits_at(self, state: JitState, index: jax.Array) -> jax.Array:
        """Logits at an arbitrary row — the batched server pads documents to a
        capacity bucket, so "last token" is ``index = n_real - 1``, not -1."""
        return self._logits_at_impl(state, index)

    def _logits_at_impl(self, state: JitState, index: jax.Array) -> jax.Array:
        h = _ln(state.x[-1][index][None], self.extras["fn_s"],
                self.extras["fn_b"])[0]
        return h @ self.extras["head_w"]
