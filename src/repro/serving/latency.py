"""Latency accounting for the serving SLOs (DESIGN.md §8).

``LatencyStats`` is a streaming accumulator of millisecond samples with
percentile readout — the p50/p99 per-edit and per-suggestion numbers the
async front end records into ``BatchStats``. Exact counts/sums are kept for
every sample; the percentile estimate runs over a bounded reservoir so a
long-lived server cannot grow its stats without bound (uniform reservoir
sampling keeps the retained samples an unbiased draw of the whole stream).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field

import numpy as np


@dataclass
class LatencyStats:
    """Millisecond latency accumulator with p50/p99 readout."""

    count: int = 0
    total_ms: float = 0.0
    max_ms: float = 0.0
    sample_cap: int = 8192
    samples: list = field(default_factory=list)

    def record(self, ms: float) -> None:
        ms = float(ms)
        self.count += 1
        self.total_ms += ms
        if ms > self.max_ms:
            self.max_ms = ms
        if len(self.samples) < self.sample_cap:
            self.samples.append(ms)
        else:  # uniform reservoir: each sample retained with P = cap/count
            j = random.randrange(self.count)
            if j < self.sample_cap:
                self.samples[j] = ms

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), q))

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def mean_ms(self) -> float:
        return self.total_ms / max(self.count, 1)

    def summary(self) -> dict:
        """JSON-ready snapshot (benchmark emissions)."""
        return {"count": self.count, "mean_ms": self.mean_ms,
                "p50_ms": self.p50, "p99_ms": self.p99, "max_ms": self.max_ms}
