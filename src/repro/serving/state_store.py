"""Tiered document-state store: device state as a managed, budgeted resource
(DESIGN.md §7 "State as a tiered resource").

The paper's value proposition is that a document's incremental state (VQ
codes, cached k/v, layer sums) is durable across edits — but durable state
that can only live on the device caps the fleet at whatever fits in device
memory, forever. This module makes residency a first-class scheduling
concern. Every open document's ``JitState`` lives in exactly one tier:

* **hot** — device-resident, exactly the pre-store behavior. The only tier
  a dispatch / KV export / logits read can serve from.
* **warm** — a host-RAM numpy snapshot (``jit_engine.state_to_host``; the
  eager-copy discipline of ``batch_server._device_copy`` — store-owned
  buffers are never mutated, so the re-upload's asynchronous device read
  cannot race anything).
* **cold** — an npz on disk (``checkpoint.save_serving_document``: the full
  ``JitState`` plus the allocator's position-id snapshot, the suggestion
  watermarks, AND the server's host mirrors/slot layout, all captured at
  eviction time so the file is internally consistent), so a fleet can exceed
  host RAM too — and a process restart or a fleet peer (DESIGN.md §11) can
  readopt its flushed sessions. Writes are atomic (temp file + ``os.replace``
  in the same directory) and file names deterministic per document
  (``cold_path_for``), which is what lets fleets share one cold directory.

Rehydration is a pure re-upload — **bit-exact, never a recompute**: the
device state is a pure function of the snapshot, so a document that was
evicted and touched again is indistinguishable from one that never left
(tests/test_state_store.py's differential churn harness). Contrast the
naive fallback — drop the state and ``full_forward`` on next touch — which
costs a full pass and perturbs low-order float bits.

Budget policy (``admit``): a configurable device budget in bytes covers
resident document states (``bytes_hot``) plus suggestion decode caches
(``bytes_suggest``). When an admission would exceed it, the store reclaims
in LRU order, cheapest casualty first:

1. drop suggestion decode caches of non-protected documents — *soft state*:
   a dropped cache re-prefills from the KV export on the next refresh
   (token-identical suggestions, DESIGN.md §5), so it is always evictable —
   even for pinned documents;
2. demote unpinned, non-protected hot documents to warm (the LRU-with-
   pinning core);
3. drop the protected documents' own suggestion caches;
4. raise ``DeviceBudgetError`` — only pins and the active dispatch's keep
   set can force this, so the message says which.

A host budget bounds the warm tier the same way: overflowing warm snapshots
spill to disk (LRU again). Dispatch-transient copies (the stacked batch
pytree) are intentionally outside the budget — they exist for one step and
scale with ``max_batch``, not with the fleet.

The store mutates the server's ``BatchStats`` counters directly
(``bytes_hot/warm/cold/suggest``, per-tier doc counts, ``evictions`` /
``spills`` / ``rehydrations`` / ``hot_hits`` / ``state_touches``) — they
reconcile exactly against a recount of the underlying objects
(tests/test_state_store.py::test_stats_reconcile).
"""
from __future__ import annotations

import hashlib
import os
import re
import tempfile
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.checkpoint.store import restore_document_state, save_serving_document
from repro.serving.jit_engine import (
    JitState, state_from_host, state_nbytes, state_to_host,
)


def cold_path_for(cold_dir: str, doc_id: str) -> str:
    """Deterministic per-document spill path — the cross-process contract of
    the shared cold tier (DESIGN.md §11): every replica pointed at the same
    directory computes the same file name for a document, so migration and
    failover can find each other's spills without a catalog. The sanitized
    id keeps names debuggable; the hash disambiguates ids that sanitize
    identically."""
    safe = re.sub(r"[^A-Za-z0-9._-]", "_", doc_id)[:80]
    digest = hashlib.sha1(doc_id.encode()).hexdigest()[:8]
    return os.path.join(cold_dir, f"{safe}-{digest}.state.npz")

TIER_HOT = "hot"
TIER_WARM = "warm"
TIER_COLD = "cold"
# Not a storage tier: NO copy exists anywhere and the document must be
# rebuilt from its host mirrors (a full forward) on next touch. Only the
# dispatch-failure rollback corner produces this — a doc that entered a
# take evicted and whose warm/cold copy a mid-take re-ingest consumed —
# so rollback itself never computes (and never raises); the rebuild runs
# at ordinary touch time through the server's re-ingest callback.
TIER_VOID = "void"


class DeviceBudgetError(RuntimeError):
    """The device budget cannot admit the requested bytes: everything
    evictable has been evicted and what remains is pinned or belongs to the
    dispatch being served. Raise the budget, unpin documents, or lower
    ``max_batch`` (a dispatch needs its whole chunk hot at once)."""


@dataclass
class _Entry:
    doc_id: str
    nbytes: int  # state footprint (identical across tiers)
    tier: str = TIER_HOT
    lru: int = 0  # last-touch tick (monotonic store clock)
    pinned: bool = False
    suggest_bytes: int = 0  # device-resident decode cache (soft state)
    warm: Optional[JitState] = None  # host snapshot (warm tier payload)
    # (allocator ids, invalid_from, touched_from) captured at EVICTION time,
    # i.e. the same instant as the state snapshot — a later spill writes
    # these, not the live doc's (whose host mirrors may already be mid-take),
    # so the npz is internally consistent with its state payload
    warm_meta: Optional[tuple] = None
    # full host-mirror snapshot (tokens/valid/positions/slots/free + scalar
    # meta) captured at the same eviction instant — what a spill writes so
    # ANOTHER process can adopt the file as a complete serving document
    # (fleet failover, DESIGN.md §11). In-process rehydration ignores it.
    warm_mirrors: Optional[dict] = None
    cold_path: Optional[str] = None  # npz path (cold tier payload)
    cold_ids: Optional[np.ndarray] = None  # allocator ids recorded at spill


class StateStore:
    """Residency manager for ``BatchServer`` documents.

    ``docs`` is the server's live ``doc_id -> _BatchDoc`` dict (the store
    reads/writes ``doc.state`` through it); ``stats`` the server's
    ``BatchStats`` (authoritative byte/doc/eviction counters);
    ``drop_suggest`` a callback that drops one document's suggestion decode
    cache (the suggester's listener reports the freed bytes back through
    ``note_suggest_bytes``).
    """

    def __init__(self, *, docs: dict, stats, drop_suggest, reingest=None,
                 device_budget_bytes: Optional[int] = None,
                 host_budget_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 in_round: Optional[Callable[[], bool]] = None):
        if device_budget_bytes is not None and device_budget_bytes <= 0:
            raise ValueError("device_budget_bytes must be positive (or None)")
        if host_budget_bytes is not None and host_budget_bytes <= 0:
            raise ValueError("host_budget_bytes must be positive (or None)")
        self.device_budget_bytes = device_budget_bytes
        self.host_budget_bytes = host_budget_bytes
        # spill_dir doubles as the SHARED cold tier when a fleet points every
        # replica's store at one directory (DESIGN.md §11): per-document file
        # names are deterministic (cold_path_for) and writes are atomic, so
        # peers can adopt spills; ownership is arbitrated by the fleet's
        # lease protocol, not by this class.
        self._spill_dir = spill_dir
        self._docs = docs
        self._stats = stats
        self._drop_suggest = drop_suggest
        self._reingest = reingest  # rebuild-from-mirrors (TIER_VOID recovery)
        # truthy while the server is inside a scheduling round: host mirrors
        # of a mid-take document run AHEAD of its device state, so snapshots
        # captured then are marked consistent=False (usable for in-process
        # rehydration, not for cross-process adoption)
        self._in_round = in_round
        self._entries: dict[str, _Entry] = {}
        self._clock = 0

    # ------------------------------------------------------------- queries

    def __contains__(self, doc_id: str) -> bool:
        return doc_id in self._entries

    def tier(self, doc_id: str) -> str:
        return self._entries[doc_id].tier

    def tiers(self) -> dict[str, str]:
        """doc_id -> tier, for every managed document (test introspection)."""
        return {d: e.tier for d, e in self._entries.items()}

    def nbytes(self, doc_id: str) -> int:
        return self._entries[doc_id].nbytes

    def pinned(self, doc_id: str) -> bool:
        return self._entries[doc_id].pinned

    # ------------------------------------------------------------- plumbing

    def _tick(self, e: _Entry) -> None:
        self._clock += 1
        e.lru = self._clock

    def _budget_used(self) -> int:
        return self._stats.bytes_hot + self._stats.bytes_suggest

    def _spill_path(self, doc_id: str) -> str:
        if self._spill_dir is None:
            self._spill_dir = tempfile.mkdtemp(prefix="repro-state-store-")
        os.makedirs(self._spill_dir, exist_ok=True)
        return cold_path_for(self._spill_dir, doc_id)

    def _drop_holdings(self, e: _Entry) -> None:
        """Forget whatever tier payload the entry holds (accounting too).
        TIER_VOID holds nothing."""
        if e.tier == TIER_HOT:
            self._stats.bytes_hot -= e.nbytes
            self._stats.docs_hot -= 1
        elif e.tier == TIER_WARM:
            self._stats.bytes_warm -= e.nbytes
            self._stats.docs_warm -= 1
            e.warm = None
            e.warm_meta = None
            e.warm_mirrors = None
        elif e.tier == TIER_COLD:
            self._stats.bytes_cold -= e.nbytes
            self._stats.docs_cold -= 1
            if e.cold_path and os.path.exists(e.cold_path):
                os.remove(e.cold_path)
            e.cold_path = None
            e.cold_ids = None

    # ------------------------------------------------------------- lifecycle

    def register(self, doc) -> None:
        """Adopt a freshly ingested document (its ``state`` is hot)."""
        if doc.doc_id in self._entries:
            raise KeyError(f"document {doc.doc_id!r} already in the store")
        e = _Entry(doc_id=doc.doc_id, nbytes=state_nbytes(doc.state))
        self._entries[doc.doc_id] = e
        self._stats.bytes_hot += e.nbytes
        self._stats.docs_hot += 1
        self._tick(e)

    def set_hot(self, doc, state: JitState) -> None:
        """Adopt a REPLACED device state (dispatch result, re-ingest, grow).
        Discards any warm/cold copy — they describe the superseded state —
        and bumps the doc's ``state_epoch`` so the rollback path can tell a
        content-changing replacement from a content-preserving rehydration."""
        e = self._entries[doc.doc_id]
        self._drop_holdings(e)
        e.nbytes = state_nbytes(state)
        e.tier = TIER_HOT
        doc.state = state
        doc.state_epoch += 1
        self._stats.bytes_hot += e.nbytes
        self._stats.docs_hot += 1
        self._tick(e)

    def close(self, doc) -> None:
        """Release every holding of a closing document (any tier)."""
        e = self._entries.pop(doc.doc_id)
        self._drop_holdings(e)
        self._stats.bytes_suggest -= e.suggest_bytes
        doc.state = None

    def pin(self, doc_id: str) -> None:
        """Exempt the document from eviction (and make it hot now, so a
        pinned doc is always dispatch-ready). Suggestion decode caches stay
        evictable even when pinned — they are soft state."""
        self.ensure_hot(self._docs[doc_id])
        self._entries[doc_id].pinned = True

    def unpin(self, doc_id: str) -> None:
        self._entries[doc_id].pinned = False

    # ------------------------------------------------------------- admission

    def admit(self, nbytes: int, keep: frozenset = frozenset()) -> None:
        """Make room for ``nbytes`` of incoming device state. ``keep`` names
        documents that must stay hot (the dispatch chunk being assembled)."""
        if self.device_budget_bytes is None:
            return

        def over() -> bool:
            return self._budget_used() + nbytes > self.device_budget_bytes

        if not over():
            return
        by_lru = sorted(self._entries.values(), key=lambda e: e.lru)
        # 1. soft state first: non-protected suggestion decode caches
        for e in by_lru:
            if not over():
                return
            if e.suggest_bytes and e.doc_id not in keep:
                self._drop_suggest(e.doc_id)
        # 2. LRU-with-pinning: demote hot documents to warm
        for e in by_lru:
            if not over():
                return
            if e.tier == TIER_HOT and not e.pinned and e.doc_id not in keep:
                self._evict_hot(e)
        # 3. last resort: the protected documents' own decode caches
        for e in by_lru:
            if not over():
                return
            if e.suggest_bytes:
                self._drop_suggest(e.doc_id)
        if over():
            pinned = sum(e.nbytes for e in self._entries.values() if e.pinned)
            kept = sum(e.nbytes for e in self._entries.values()
                       if e.doc_id in keep and e.tier == TIER_HOT)
            raise DeviceBudgetError(
                f"cannot admit {nbytes} bytes under a device budget of "
                f"{self.device_budget_bytes}: {self._stats.bytes_hot} hot "
                f"({pinned} pinned, {kept} held by the active dispatch) + "
                f"{self._stats.bytes_suggest} suggestion-cache bytes remain")

    def note_suggest_bytes(self, doc_id: str, nbytes: int) -> None:
        """Suggestion decode-cache accounting (the suggester's listener).
        Growth may push the budget over — reclaim immediately, protecting
        the document whose refresh just produced the cache."""
        e = self._entries.get(doc_id)
        if e is None:
            return  # unmanaged key (oracle harnesses)
        delta = int(nbytes) - e.suggest_bytes
        e.suggest_bytes = int(nbytes)
        self._stats.bytes_suggest += delta
        if delta > 0:
            self.admit(0, keep=frozenset((doc_id,)))

    # ------------------------------------------------------------- movement

    def ensure_hot(self, doc, keep: frozenset = frozenset()) -> JitState:
        """The transparent-rehydration entry point: every device-state read
        (dispatch stacking, KV export, logits, re-ingest bases) goes through
        here — it is also the LRU clock. Hot documents just touch the
        clock; warm/cold documents re-upload their snapshot — bit-exact, no
        recompute; a void document (rollback corner) rebuilds from its host
        mirrors through the server's re-ingest callback."""
        e = self._entries[doc.doc_id]
        self._tick(e)
        self._stats.state_touches += 1
        if e.tier == TIER_HOT:
            self._stats.hot_hits += 1
            return doc.state
        if e.tier == TIER_VOID:
            self._reingest(doc)  # admits, recomputes, adopts via set_hot
            self._stats.rollback_rebuilds += 1
            return doc.state
        self.admit(e.nbytes, keep=keep | frozenset((doc.doc_id,)))
        if e.tier == TIER_COLD:
            host_state, ids, _meta = restore_document_state(e.cold_path)
            if e.cold_ids is not None and not np.array_equal(
                    np.asarray(ids), e.cold_ids):
                raise RuntimeError(
                    f"cold-tier corruption for {doc.doc_id!r}: allocator ids "
                    "in the spill file do not match the ids recorded at "
                    "spill time")
        else:
            host_state = e.warm
        self._drop_holdings(e)  # releases the snapshot / spill file + bytes
        # content-preserving re-upload: doc.state_epoch does NOT bump
        doc.state = state_from_host(host_state)
        e.tier = TIER_HOT
        self._stats.bytes_hot += e.nbytes
        self._stats.docs_hot += 1
        self._stats.rehydrations += 1
        return doc.state

    def mark_void(self, doc) -> None:
        """Rollback corner: the document's pre-take copy no longer exists in
        any tier (a mid-take re-ingest consumed it) and the host mirrors are
        the only source of truth. Never computes — the rebuild happens at
        the next touch (``ensure_hot``), where admission and a full forward
        can fail at ordinary, recoverable times."""
        e = self._entries[doc.doc_id]
        self._drop_holdings(e)
        e.tier = TIER_VOID
        doc.state = None

    def demote(self, doc, tier: str) -> str:
        """Force-evict a document to ``tier`` (tests, benchmarks, and the
        admission passes). No-op if the document is already at or below the
        target tier. Returns the resulting tier."""
        if tier not in (TIER_WARM, TIER_COLD):
            raise ValueError(f"cannot demote to tier {tier!r}")
        e = self._entries[doc.doc_id]
        if e.pinned:
            raise ValueError(f"document {doc.doc_id!r} is pinned")
        if e.tier == TIER_HOT:
            self._evict_hot(e)
        if tier == TIER_COLD and e.tier == TIER_WARM:
            self._spill_warm(e)
        return e.tier

    # ------------------------------------------------------------- internals

    def _evict_hot(self, e: _Entry) -> None:
        doc = self._docs[e.doc_id]
        e.warm = state_to_host(doc.state)
        e.warm_meta = (doc.allocator.snapshot(), doc.invalid_from,
                       doc.touched_from)
        # full serving snapshot for cross-process adoption (only spills read
        # it). Mirrors are copied NOW, same instant as the state snapshot;
        # consistent=False when captured mid-round (a peeled take means the
        # mirrors run ahead of the state — fine for in-process rehydration,
        # poison for adoption).
        e.warm_mirrors = {
            "mirrors": {
                "tokens": doc.tokens.copy(),
                "valid": doc.valid.copy(),
                "positions": doc.positions.copy(),
                "slots": np.asarray(doc.slots, np.int32),
                "free": np.asarray(doc.free, np.int32),
            },
            "meta": {
                "doc_id": doc.doc_id,
                "row_capacity": int(doc.row_capacity),
                "n_virtual": int(doc.n_virtual),
                "suggest_n": int(doc.suggest_n),
                "pos_pool": int(doc.allocator.pool_size),
                "consistent": not (self._in_round is not None
                                   and self._in_round()),
            },
        }
        doc.state = None
        e.tier = TIER_WARM
        self._stats.bytes_hot -= e.nbytes
        self._stats.docs_hot -= 1
        self._stats.bytes_warm += e.nbytes
        self._stats.docs_warm += 1
        self._stats.evictions += 1
        if e.suggest_bytes:
            # the decode cache references this state's export lineage; it is
            # device memory with no document on device — always drop it
            self._drop_suggest(e.doc_id)
        self._spill_over_host_budget()

    def _spill_over_host_budget(self) -> None:
        if self.host_budget_bytes is None:
            return
        warm = sorted((e for e in self._entries.values()
                       if e.tier == TIER_WARM), key=lambda e: e.lru)
        for e in warm:
            if self._stats.bytes_warm <= self.host_budget_bytes:
                return
            self._spill_warm(e)

    def _spill_warm(self, e: _Entry) -> None:
        path = self._spill_path(e.doc_id)
        # companions captured at eviction time, NOT read from the live doc:
        # between eviction and spill a take may have mutated the host-side
        # allocator/watermarks past the snapshotted state. The spill is a
        # FULL serving snapshot (mirrors + meta, also eviction-time) so a
        # fleet peer can adopt it on failover; its meta carries the
        # consistency flag recorded at eviction. Write is atomic
        # (checkpoint.atomic_savez): a crash mid-spill never leaves a
        # truncated file at the visible path.
        ids, invalid_from, touched_from = e.warm_meta
        meta = dict(e.warm_mirrors["meta"])
        meta["invalid_from"] = invalid_from
        meta["touched_from"] = touched_from
        save_serving_document(path, e.warm, allocator_ids=ids,
                              mirrors=e.warm_mirrors["mirrors"], meta=meta)
        e.cold_path = path
        e.cold_ids = np.asarray(ids, np.int32).copy()
        e.warm = None
        e.warm_meta = None
        e.warm_mirrors = None
        e.tier = TIER_COLD
        self._stats.bytes_warm -= e.nbytes
        self._stats.docs_warm -= 1
        self._stats.bytes_cold += e.nbytes
        self._stats.docs_cold += 1
        self._stats.spills += 1
