"""Suggestion decoding: continuation generation over an edited document's
incremental state (the paper's motivating scenario — an AI writing assistant
that "update[s] its suggestions in real time as a document is edited").

The serving problem: after each ``apply_edits`` the jit engine holds exact
per-layer caches for the *document*, but a greedy continuation ("suggestion")
still needs a standard decode loop — and restarting that loop from scratch
re-prefills the whole document per keystroke. This module closes the gap
with prefix reuse (DESIGN.md §5):

1. ``JitIncrementalEngine.export_kv`` gathers the slot buffer's cached
   ``k``/``v`` into sequence order — a ready-made decode KV cache. Columns
   the incremental passes never touched are bit-exact against a full
   forward; touched columns are float-close only (ΔT accumulation order).
2. ``SuggestionEngine.refresh`` re-prefills **only from the earliest
   invalidated position**: rows strictly before the earliest edited
   position id depend, by causal masking, only on other untouched rows, so
   their cache entries are reused verbatim (from the previous refresh's
   decode cache when one exists, else from the KV export). Rows at/after
   it are recomputed through ``models.transformer.prefill_step`` in ONE
   fixed-shape chunk (chunk lengths bucketed to powers of two).
3. The continuation itself is ``serving.decode.make_serve_step`` greedy
   steps — the ordinary continuous-batching inner loop.

Exactness contract (tests/test_suggest_differential.py): the suggestion
token sequence equals a from-scratch full-recompute decode oracle on the
edited document, for every prefix of a mixed insert/delete/replace stream —
including defrag and buffer-growth re-ingests, which drop all reuse.

The contract survives thresholded propagation (``delta_threshold > 0``,
DESIGN.md §10) unchanged: a sigma-delta-suppressed row is always at a
position id >= the earliest edited pid (causality), i.e. at/after the
``invalid_from`` / ``touched_from`` boundary — and every row at/after the
boundary is re-prefilled here through the EXACT transformer math, never
read from the (possibly drifted) engine caches. Reused prefix rows were
never touched by any incremental pass, so they carry no drift at any
threshold. Suggestions therefore stay oracle-token-exact for the served
tolerance (tests/test_delta_threshold.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.bucketing import next_pow2
from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.serving.decode import greedy_continue, make_serve_step
from repro.serving.jit_engine import JitIncrementalEngine, JitState


class PositionHeadroomError(RuntimeError):
    """The continuation's position ids would run past the embedding pool —
    the caller must defragment (re-spread ids, which restores tail headroom)
    before refreshing the suggestion."""


@dataclass
class SuggestStats:
    refreshes: int = 0
    rebuilds: int = 0  # decode cache (re)built from the KV export
    prefill_rows_reused: int = 0  # rows served from cached prefix state
    prefill_rows_recomputed: int = 0  # real rows re-prefilled
    prefill_rows_launched: int = 0  # incl. bucket padding (fixed shapes)
    decode_steps: int = 0

    @property
    def prefill_rows_total(self) -> int:
        return self.prefill_rows_reused + self.prefill_rows_recomputed

    @property
    def reused_fraction(self) -> float:
        return self.prefill_rows_reused / max(self.prefill_rows_total, 1)


@dataclass
class _SuggestCache:
    """Per-document decode caches persisted across refreshes. Rows
    ``0..n-1`` of the cache arrays hold the document's sequence-ordered
    state as of the last refresh (suggestion rows beyond ``n`` are stale —
    the next refresh rewinds the length counter past them)."""

    caches: list
    tokens: np.ndarray  # [n] sequence-ordered, as of the last refresh
    positions: np.ndarray  # [n]
    n: int
    n_cap: int
    n_new_cap: int


class SuggestionEngine:
    """Greedy continuation decoding with edited-prefix reuse.

    One instance serves many documents (pass a distinct ``key`` per
    document to persist its decode cache across refreshes); jit caches for
    the prefill/decode steps are shared, keyed by shape — chunk lengths
    are bucketed to powers of two, so a capacity-``n_cap`` document compiles
    O(log n_cap) prefill shapes total.
    """

    def __init__(self, params: dict, cfg: ArchConfig, *, default_new: int = 8,
                 dtype=jnp.float32, on_cache_bytes=None):
        if cfg.pos not in ("learned", "sampled"):
            raise ValueError("suggestion serving expects absolute position ids")
        self.params = params
        self.cfg = cfg
        self.default_new = int(default_new)
        self.dtype = dtype
        self._step = jax.jit(make_serve_step(cfg, sample=False))
        self._prefill = jax.jit(
            lambda p, c, t, pos: T.prefill_step(p, cfg, t, c, pos))
        self._cache: dict = {}
        # residency listener (the state store's budget accounting): called
        # with (key, nbytes) whenever a document's persisted decode cache is
        # stored or dropped — decode caches are device memory and count
        # toward the serving budget as SOFT state (re-prefillable)
        self._on_cache_bytes = on_cache_bytes
        self.stats = SuggestStats()

    # ------------------------------------------------------------- cache mgmt

    def cache_nbytes(self, key) -> int:
        """Device bytes held by a document's persisted decode cache (0 when
        none) — length counters included; the budget does not care which
        rows are live."""
        entry = self._cache.get(key)
        if entry is None:
            return 0
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(entry.caches))

    def cached_keys(self) -> list:
        """Keys with a persisted decode cache (leak tests / reconciliation)."""
        return list(self._cache)

    def _notify(self, key, nbytes: int) -> None:
        if self._on_cache_bytes is not None:
            self._on_cache_bytes(key, nbytes)

    def drop(self, key) -> None:
        """Forget a document's persisted decode cache (defrag re-spreads
        every position id, so nothing in it is reusable; the state store
        also drops caches under budget pressure — soft state, the next
        refresh rebuilds from the KV export)."""
        if self._cache.pop(key, None) is not None:
            self._notify(key, 0)

    def pos_headroom(self, last_pos: int) -> int:
        """How many continuation ids fit after ``last_pos``."""
        return int(self.params["embed"]["pos"].shape[0]) - 1 - int(last_pos)

    # ------------------------------------------------------------- refresh

    def refresh(self, engine: JitIncrementalEngine, state: JitState, *,
                key=None, n_new: Optional[int] = None,
                invalid_from: Optional[int] = None,
                export_invalid_from: Optional[int] = None,
                on_token=None) -> np.ndarray:
        """Recompute the greedy continuation of the document in ``state``.

        ``invalid_from`` — earliest *position id* edited since the last
        refresh of ``key`` (None = nothing changed); governs prefix reuse of
        the persisted decode cache. ``export_invalid_from`` — earliest
        position id touched by incremental passes since the document's last
        full forward (None = the state IS a full forward); governs reuse
        when the cache must be (re)built from the KV export (first refresh,
        or capacity change). Rows before the relevant boundary are reused;
        rows at/after it — whose values an edit may have changed, directly
        or through count renormalization / VQ code flips, or whose
        propagation a ``delta_threshold`` suppressed (DESIGN.md §10; such
        rows never sit before the boundary) — are re-prefilled
        through the decode path. ``on_token`` streams each decoded token as
        it is produced (see ``serving.decode.greedy_continue``). Returns the
        ``n_new`` greedy tokens."""
        n_new = self.default_new if n_new is None else int(n_new)
        if n_new < 1:
            raise ValueError("n_new must be >= 1")
        n_new_cap = next_pow2(n_new)
        n = int(state.n_real)
        if n < 1:
            raise ValueError("cannot suggest over an empty document")
        n_cap = int(state.tokens.shape[0])
        # Sequence ordering from the small host-side leaves; the heavy k/v
        # gather (export_kv) runs only when the decode cache must be rebuilt.
        # Same sort key as _export_kv_impl (both stable), so the row order
        # matches the export's on the rebuild path — garbage tail included.
        host_valid = np.asarray(state.valid)
        host_positions = np.asarray(state.positions)
        order = np.argsort(np.where(host_valid, host_positions,
                                    np.iinfo(np.int32).max), kind="stable")
        seq_tokens = np.asarray(state.tokens)[order]
        seq_positions = host_positions[order]
        last_pos = int(seq_positions[n - 1])
        if self.pos_headroom(last_pos) < n_new:
            raise PositionHeadroomError(
                f"{n_new} continuation ids after position {last_pos} exceed "
                f"the embedding pool of {self.params['embed']['pos'].shape[0]}"
                " — defragment the document first")

        def boundary(watermark: Optional[int]) -> int:
            # first sequence row whose position id the edits may have
            # invalidated; the last row is always recomputed so the refresh
            # yields last-token logits
            if watermark is None:
                return n - 1
            return int(np.searchsorted(seq_positions[:n], watermark, "left"))

        entry = self._cache.get(key) if key is not None else None
        if entry is not None and (entry.n_cap != n_cap
                                  or entry.n_new_cap != n_new_cap):
            entry = None
        if entry is not None:
            p = min(boundary(invalid_from), n - 1)
            # the reused prefix must be the exact rows the cache encodes
            if not (np.array_equal(entry.positions[:p], seq_positions[:p])
                    and np.array_equal(entry.tokens[:p], seq_tokens[:p])):
                p = 0
            caches = entry.caches
        else:
            p = min(boundary(export_invalid_from), n - 1)
            exp = engine.export_kv(state)
            caches = T.caches_from_kv(
                self.cfg, exp.k[:, None], exp.v[:, None],
                jnp.zeros((1,), jnp.int32),
                seq_len=n_cap + n_new_cap, dtype=self.dtype)
            self.stats.rebuilds += 1

        # -------- re-prefill rows [p_eff, n) in one bucketed chunk. The
        # bucket extends the chunk *downward* (recomputing extra reusable
        # rows) so every launched row is a real cache slot; when even the
        # full document underfills its bucket, the chunk covers the whole
        # exported buffer — the garbage tail rows land beyond the final
        # length counter, where attention never sees them.
        M = next_pow2(n - p)
        p_eff = n - M
        if p_eff < 0:
            p_eff, M = 0, n_cap
        caches = T.set_cache_length(caches, p_eff)
        chunk_t = jnp.asarray(seq_tokens[p_eff:p_eff + M])[None]
        chunk_p = jnp.asarray(seq_positions[p_eff:p_eff + M])[None]
        logits, caches = self._prefill(self.params, caches, chunk_t, chunk_p)
        caches = T.set_cache_length(caches, n)
        last_logits = logits[:, n - 1 - p_eff]  # [1, vocab]

        # -------- greedy continuation on fresh tail position ids
        gen_pos = jnp.asarray(
            last_pos + 1 + np.arange(n_new, dtype=np.int32))[None]
        toks, caches = greedy_continue(self._step, self.params, caches,
                                       last_logits, gen_pos,
                                       on_token=on_token)
        out = np.asarray(toks[0], np.int32)

        if key is not None:
            self._cache[key] = _SuggestCache(
                caches=caches, tokens=seq_tokens[:n].copy(),
                positions=seq_positions[:n].copy(), n=n, n_cap=n_cap,
                n_new_cap=n_new_cap)
            self._notify(key, self.cache_nbytes(key))
        self.stats.refreshes += 1
        self.stats.prefill_rows_reused += p_eff
        self.stats.prefill_rows_recomputed += n - p_eff
        self.stats.prefill_rows_launched += M
        self.stats.decode_steps += n_new - 1
        return out


def oracle_suggestion(params: dict, cfg: ArchConfig,
                      engine: JitIncrementalEngine, tokens, positions, valid,
                      n_new: int,
                      suggester: Optional[SuggestionEngine] = None
                      ) -> np.ndarray:
    """The from-scratch full-recompute decode oracle: ingest the padded slot
    buffers with a full forward, then decode the continuation with ZERO
    prefix reuse (``export_invalid_from=0`` re-prefills every row through
    the decode path). The differential harness compares ``SuggestionEngine``
    outputs against this token-for-token. Pass a reusable ``suggester`` to
    share jit caches across oracle calls."""
    # eager host copies: callers pass LIVE server host mirrors, which jax
    # reads asynchronously (and may zero-copy) — a later edit would race
    # the deferred ingest read (see batch_server._device_copy)
    state = engine.full_forward(jnp.asarray(np.array(tokens, copy=True)),
                                jnp.asarray(np.array(positions, copy=True)),
                                jnp.asarray(np.array(valid, copy=True)))
    s = suggester or SuggestionEngine(params, cfg)
    return s.refresh(engine, state, n_new=n_new, export_invalid_from=0)
