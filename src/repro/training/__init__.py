from repro.training.optimizer import AdamWState, adamw_init, adamw_update, make_schedule
from repro.training.losses import next_token_loss, distill_loss
from repro.training.step import TrainState, make_train_step, make_distill_step, train_state_init
