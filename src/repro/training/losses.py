"""Training losses: next-token LM, distillation (Sanh et al. 2020), classification."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _log_softmax(x):
    return jax.nn.log_softmax(x.astype(jnp.float32), axis=-1)


def next_token_loss(logits: jax.Array, tokens: jax.Array, mask=None) -> jax.Array:
    """logits [b, n, V] (audio: [b, n, cb, V]); tokens [b, n] (or [b, n, cb]).
    Predict token t+1 from position t."""
    logp = _log_softmax(logits[:, :-1])
    targets = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if nll.ndim == 3:  # audio codebooks: average over the codebook axis
        nll = nll.mean(-1)
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)


def distill_loss(
    student_logits: jax.Array,
    teacher_logits: jax.Array,
    tokens: jax.Array,
    *,
    alpha_ce: float = 5.0,
    alpha_lm: float = 2.0,
    temperature: float = 2.0,
) -> tuple[jax.Array, dict]:
    """DistilBERT-style loss: KL(teacher‖student) at temperature + hard LM
    loss (paper §4 follows Sanh et al. 2020)."""
    t = temperature
    s_logp = _log_softmax(student_logits[:, :-1] / t)
    t_logp = _log_softmax(teacher_logits[:, :-1] / t)
    t_p = jnp.exp(t_logp)
    kl = jnp.sum(t_p * (t_logp - s_logp), axis=-1).mean() * t * t
    lm = next_token_loss(student_logits, tokens)
    loss = alpha_ce * kl + alpha_lm * lm
    return loss, {"kl": kl, "lm": lm}


def classification_loss(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """logits [b, C]; labels [b]. Returns (loss, accuracy)."""
    logp = _log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(nll), acc
