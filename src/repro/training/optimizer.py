"""AdamW + LR schedules in pure JAX (no optax dependency)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.common.pytree import pytree_dataclass, static_field


@pytree_dataclass
class AdamWState:
    step: jax.Array
    mu: dict
    nu: dict


@pytree_dataclass
class AdamWConfig:
    b1: float = static_field(default=0.9)
    b2: float = static_field(default=0.95)
    eps: float = static_field(default=1e-8)
    weight_decay: float = static_field(default=0.1)
    grad_clip: float = static_field(default=1.0)


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda a: jnp.zeros_like(a, dtype=jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(a.astype(jnp.float32))) for a in jax.tree.leaves(tree))
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.nu, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {"grad_norm": gnorm}


def make_schedule(
    *,
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    final_lr: float = 0.0,
    kind: str = "cosine",
) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup then cosine (or linear) decay — paper §4 uses 5K warmup
    to 5e-4 then cosine to 5e-5."""

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        if kind == "cosine":
            decay = final_lr + 0.5 * (peak_lr - final_lr) * (1 + jnp.cos(jnp.pi * prog))
        else:
            decay = peak_lr + (final_lr - peak_lr) * prog
        return jnp.where(step < warmup_steps, warm, decay)

    return schedule
