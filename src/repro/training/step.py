"""Train / distill steps with gradient accumulation and mixed precision."""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import pytree_dataclass
from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.training.losses import distill_loss, next_token_loss
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


@pytree_dataclass
class TrainState:
    params: dict
    opt: AdamWState
    rng: jax.Array


def train_state_init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> TrainState:
    pkey, rkey = jax.random.split(key)
    params = T.init_params(pkey, cfg, dtype)
    return TrainState(params=params, opt=adamw_init(params), rng=rkey)


def _lm_loss_fn(params, cfg: ArchConfig, batch: dict, rng, *, aux_weight: float = 1.0):
    logits, aux = T.forward(
        params,
        cfg,
        batch["tokens"],
        batch.get("positions"),
        patch_embeds=batch.get("patch_embeds"),
        train=True,
        rng=rng,
    )
    n_text = batch["tokens"].shape[1]
    lm = next_token_loss(logits[:, -n_text:], batch["tokens"], batch.get("mask"))
    loss = lm + aux_weight * aux["aux_loss"]
    if "mtp_logits" in aux:
        # predict token t+2 from position t (shift targets by one extra)
        mtp = next_token_loss(aux["mtp_logits"][:, :-1], batch["tokens"][:, 1:])
        loss = loss + 0.3 * mtp
    return loss, {"lm_loss": lm, "aux_loss": aux["aux_loss"]}


def make_train_step(
    cfg: ArchConfig,
    schedule: Callable[[jax.Array], jax.Array],
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    accum_steps: int = 1,
    donate: bool = True,
):
    """Returns jit-able ``step(state, batch) -> (state, metrics)``.

    With ``accum_steps > 1`` the batch's leading axis is split into
    microbatches and gradients are averaged under ``lax.scan`` (keeps live
    activation memory to one microbatch)."""

    def step(state: TrainState, batch: dict):
        rng, new_rng = jax.random.split(state.rng)
        grad_fn = jax.grad(_lm_loss_fn, has_aux=True)
        if accum_steps == 1:
            grads, metrics = grad_fn(state.params, cfg, batch, rng)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(accum_steps, a.shape[0] // accum_steps, *a.shape[1:]),
                batch,
            )

            def body(acc, mb):
                g, m = grad_fn(state.params, cfg, mb, rng)
                return jax.tree.map(jnp.add, acc, g), m

            zero = jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), state.params)
            grads, metrics = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        lr = schedule(state.opt.step)
        params, opt, om = adamw_update(state.params, grads, state.opt, lr, opt_cfg)
        metrics = {**metrics, **om, "lr": lr}
        return TrainState(params=params, opt=opt, rng=new_rng), metrics

    return step


def make_distill_step(
    student_cfg: ArchConfig,
    teacher_cfg: ArchConfig,
    schedule: Callable[[jax.Array], jax.Array],
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    aux_weight: float = 1.0,
):
    """Distillation step (paper §4: adapt OPT to VQ-OPT via Sanh et al.).

    ``step(state, teacher_params, batch) -> (state, metrics)``. The teacher
    runs in eval mode; the student trains with KL + LM + VQ auxiliary loss.
    """

    def loss_fn(params, teacher_params, batch, rng):
        t_logits, _ = T.forward(
            teacher_params, teacher_cfg, batch["tokens"], batch.get("teacher_positions")
        )
        s_logits, aux = T.forward(
            params, student_cfg, batch["tokens"], batch.get("positions"),
            train=True, rng=rng,
        )
        loss, parts = distill_loss(s_logits, t_logits, batch["tokens"])
        loss = loss + aux_weight * aux["aux_loss"]
        return loss, {**parts, "aux_loss": aux["aux_loss"]}

    def step(state: TrainState, teacher_params: dict, batch: dict):
        rng, new_rng = jax.random.split(state.rng)
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, teacher_params, batch, rng
        )
        lr = schedule(state.opt.step)
        params, opt, om = adamw_update(state.params, grads, state.opt, lr, opt_cfg)
        return TrainState(params=params, opt=opt, rng=new_rng), {
            "loss": loss, **parts, **om, "lr": lr,
        }

    return step
