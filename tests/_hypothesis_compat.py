"""Import shim: real hypothesis when installed, pytest.skip stubs otherwise.

The property-based suites (`test_edits`, `test_vq`, ...) must *collect* on a
bare interpreter — CI and the tier-1 command install the ``test`` extra, but
a minimal environment may not have hypothesis. Test modules import
``given`` / ``settings`` / ``st`` from here instead of from hypothesis:

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

With hypothesis present these are the real objects. Without it, ``st.*``
returns inert placeholder strategies and ``@given`` replaces the test body
with ``pytest.skip``, so every module still collects and the rest of each
suite runs.
"""
from __future__ import annotations

try:
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder: supports the combinator surface used by the
        suites (map/filter/flatmap chaining) but never draws values."""

        def __init__(self, desc: str):
            self.desc = desc

        def __repr__(self) -> str:
            return self.desc

        def map(self, f):
            return _Strategy(f"{self.desc}.map(...)")

        def filter(self, f):
            return _Strategy(f"{self.desc}.filter(...)")

        def flatmap(self, f):
            return _Strategy(f"{self.desc}.flatmap(...)")

    class _StrategiesModule:
        def __getattr__(self, name: str):
            def make(*args, **kwargs) -> _Strategy:
                return _Strategy(f"st.{name}(...)")

            return make

    st = _StrategiesModule()

    def given(*strategy_args, **strategy_kwargs):
        def decorate(fn):
            import inspect

            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed — pip install '.[test]'")

            # Hide strategy-filled parameters from pytest's signature
            # introspection, or it would go looking for fixtures named
            # after them; real fixtures (e.g. module setups) stay visible.
            # Positional strategies fill the RIGHTMOST parameters (hypothesis
            # semantics), keyword strategies fill by name.
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strategy_kwargs]
            if strategy_args:
                keep = keep[:-len(strategy_args)]
            skipper.__name__ = fn.__name__
            skipper.__qualname__ = fn.__qualname__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            skipper.__signature__ = sig.replace(parameters=keep)
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    def assume(condition) -> bool:
        return True
