import os
import sys

# tests must see the single real CPU device (the 512-device override is
# strictly dryrun.py-local, per the spec)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# the tests dir itself, so suites can import the _hypothesis_compat shim
sys.path.insert(0, os.path.dirname(__file__))

import jax

jax.config.update("jax_enable_x64", False)
