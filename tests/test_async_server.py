"""Deadline-batching async front end == sequential BatchServer (ISSUE 6).

The exactness ladder for ``serving.async_server``:

1. concurrent-load differential — client threads interleaving edits and
   suggestion requests through ``AsyncBatchServer`` must produce final
   documents and suggestion tokens identical to a sequential ``BatchServer``
   fed each document's requests in the same per-document order;
2. both dispatch triggers exercised explicitly — deadline expiry (partial
   bucket, a huge ``bucket_docs``) and bucket-full (a huge delay);
3. the re-ingest paths mid-stream — forced slot-buffer grow and forced
   defrag — stay token-exact through the async path;
4. streaming subscriptions deliver per-token events that reassemble into
   exactly the completed continuation, serials strictly increasing;
5. the satellite regressions: back-to-back ``suggest`` with unchanged
   watermarks must not re-enter the dispatch path, and the latency
   histograms (``serving.latency``) must populate with sane percentiles.
"""
import threading

import jax
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.models import transformer as T
from repro.serving.async_server import AsyncBatchServer
from repro.serving.batch_server import BatchServer
from repro.serving.jit_engine import JitIncrementalEngine
from repro.serving.latency import LatencyStats
from repro.serving.suggest import SuggestionEngine, oracle_suggestion

N_NEW = 4
WAIT = 300.0  # generous ticket timeout: jit compiles land on first rounds


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    oracle_eng = JitIncrementalEngine(params, cfg, edit_capacity=4,
                                      row_capacity=16)
    oracle_sugg = SuggestionEngine(params, cfg)
    return cfg, params, oracle_eng, oracle_sugg


def _server(setup, **kw):
    cfg, params, _, _ = setup
    kw.setdefault("edit_capacity", 4)
    kw.setdefault("row_capacity", 16)
    kw.setdefault("max_batch", 4)
    kw.setdefault("min_doc_capacity", 16)
    return BatchServer(params, cfg, **kw)


def _oracle(setup, srv, doc_id, n_new=N_NEW):
    cfg, params, oracle_eng, oracle_sugg = setup
    doc = srv.docs[doc_id]
    return oracle_suggestion(params, cfg, oracle_eng, doc.tokens,
                             doc.positions, doc.valid, n_new,
                             suggester=oracle_sugg)


# --------------------------------------------------------------- LatencyStats


def test_latency_stats_percentiles():
    ls = LatencyStats()
    for v in range(1, 101):
        ls.record(float(v))
    assert ls.count == 100
    assert ls.max_ms == 100.0
    assert ls.mean_ms == pytest.approx(50.5)
    assert ls.p50 == pytest.approx(50.5)
    assert 99.0 <= ls.p99 <= 100.0
    s = ls.summary()
    assert set(s) == {"count", "mean_ms", "p50_ms", "p99_ms", "max_ms"}
    assert s["count"] == 100 and s["p50_ms"] <= s["p99_ms"] <= s["max_ms"]


def test_latency_stats_reservoir_bounded():
    ls = LatencyStats(sample_cap=64)
    for v in range(1000):
        ls.record(float(v))
    # exact aggregates over ALL samples; reservoir stays bounded
    assert ls.count == 1000 and ls.max_ms == 999.0
    assert len(ls.samples) == 64
    assert 0.0 <= ls.p50 <= 999.0


def test_latency_stats_empty():
    ls = LatencyStats()
    assert ls.p50 == 0.0 and ls.p99 == 0.0 and ls.mean_ms == 0.0


# ---------------------------------------------- satellite: cached suggestions


def test_back_to_back_suggest_no_redispatch(setup):
    """Unchanged watermarks => ``suggest`` serves the cached continuation
    without re-entering the prefill/dispatch path (ISSUE 6 satellite)."""
    cfg = setup[0]
    srv = _server(setup)
    rng = np.random.default_rng(3)
    srv.open_document("d", list(rng.integers(0, cfg.vocab, 12)))
    first = srv.suggest("d", N_NEW)
    before = (srv.stats.batch_steps, srv.stats.full_forwards,
              srv.stats.suggest_refreshes, srv.suggest_stats.refreshes,
              srv.suggest_stats.decode_steps)
    hits0 = srv.stats.suggest_cached_hits

    again = srv.suggest("d", N_NEW)
    np.testing.assert_array_equal(again, first)
    shorter = srv.suggest("d", 2)  # prefix of the cached continuation
    np.testing.assert_array_equal(shorter, first[:2])
    after = (srv.stats.batch_steps, srv.stats.full_forwards,
             srv.stats.suggest_refreshes, srv.suggest_stats.refreshes,
             srv.suggest_stats.decode_steps)
    assert after == before, "cached suggest re-entered the dispatch path"
    assert srv.stats.suggest_cached_hits == hits0 + 2

    # an edit invalidates the watermark: the next suggest really refreshes
    srv.submit_replace("d", 2, int(rng.integers(cfg.vocab)))
    refreshed = srv.suggest("d", N_NEW)
    assert srv.suggest_stats.refreshes == before[3] + 1
    np.testing.assert_array_equal(refreshed, _oracle(setup, srv, "d"))


# ------------------------------------------------ concurrent-load differential


def _drive_client(asrv, cfg, doc_id, seed, ops_log, sugg_log, n_rounds=3):
    """One client session: bursts of edits, then a blocking suggestion.
    Edits are generated against a local reference document, so the stream
    is deterministic per document no matter how rounds interleave."""
    rng = np.random.default_rng(seed)
    ref = ops_log[doc_id][0]
    for _ in range(n_rounds):
        burst = []
        for _ in range(int(rng.integers(1, 4))):
            kind = str(rng.choice(["replace", "insert", "delete"],
                                  p=[0.6, 0.3, 0.1]))
            if kind == "delete" and len(ref) <= 6:
                kind = "replace"
            tok = int(rng.integers(cfg.vocab))
            if kind == "insert":
                pos = int(rng.integers(len(ref) + 1))
                asrv.submit_insert(doc_id, pos, tok)
                ref.insert(pos, tok)
            elif kind == "delete":
                pos = int(rng.integers(len(ref)))
                asrv.submit_delete(doc_id, pos)
                del ref[pos]
            else:
                pos = int(rng.integers(len(ref)))
                asrv.submit_replace(doc_id, pos, tok)
                ref[pos] = tok
            burst.append((kind, pos, tok))
        ops_log[doc_id].append(burst)
        # blocking read: the suggestion reflects every edit of this burst
        sugg_log[doc_id].append(asrv.suggest(doc_id, N_NEW).result(WAIT))


def test_concurrent_load_matches_sequential_oracle(setup):
    """Threads interleaving edits + suggestions through the async front end
    match a sequential BatchServer replay token-exactly — under forced
    deadline-expiry dispatch (bucket_docs too large to ever fill)."""
    cfg = setup[0]
    srv = _server(setup)
    rng = np.random.default_rng(7)
    doc_ids = [f"c{i}" for i in range(3)]
    inits = {d: list(rng.integers(0, cfg.vocab, 10 + 2 * i))
             for i, d in enumerate(doc_ids)}
    ops_log = {d: [list(inits[d])] for d in doc_ids}  # [0] mutates into ref
    sugg_log = {d: [] for d in doc_ids}

    with AsyncBatchServer(srv, max_batch_delay_ms=5.0,
                          bucket_docs=64) as asrv:
        for t in [asrv.open_document(d, inits[d]) for d in doc_ids]:
            t.result(WAIT)
        threads = [threading.Thread(
            target=_drive_client,
            args=(asrv, cfg, d, 100 + i, ops_log, sugg_log))
            for i, d in enumerate(doc_ids)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        final_tokens = {d: asrv.tokens(d).result(WAIT) for d in doc_ids}
        astats = asrv.stats

    # the bucket (64 docs) can never fill: every round was deadline-cut
    assert astats.deadline_rounds > 0 and astats.full_rounds == 0
    assert astats.requests_failed == 0
    n_edits = sum(len(b) for d in doc_ids for b in ops_log[d][1:])
    assert astats.admitted_edits == n_edits
    assert astats.admitted_suggests == sum(len(s) for s in sugg_log.values())

    # sequential oracle: a fresh BatchServer fed each document's requests in
    # the same per-document order
    srv2 = _server(setup)
    for d in doc_ids:
        srv2.open_document(d, inits[d])
        for burst in ops_log[d][1:]:
            for kind, pos, tok in burst:
                getattr(srv2, f"submit_{kind}")(
                    *((d, pos) if kind == "delete" else (d, pos, tok)))
            want = srv2.suggest(d, N_NEW)
            got = sugg_log[d].pop(0)
            np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(final_tokens[d], srv2.tokens(d))
        assert list(final_tokens[d]) == ops_log[d][0]

    # latency SLO fields populated (per-edit and per-suggestion histograms)
    assert srv.stats.edit_latency.count == n_edits
    assert srv.stats.suggest_latency.count > 0
    for h in (srv.stats.edit_latency, srv.stats.suggest_latency):
        assert h.p50 <= h.p99 <= h.max_ms and h.mean_ms > 0


def test_bucket_full_dispatches_before_deadline(setup):
    """With an hour-long deadline, rounds still dispatch the moment
    ``bucket_docs`` distinct documents have admitted work."""
    cfg = setup[0]
    srv = _server(setup)
    rng = np.random.default_rng(5)
    asrv = AsyncBatchServer(srv, max_batch_delay_ms=3_600_000.0,
                            bucket_docs=2)
    try:
        opens = [asrv.open_document(d, list(rng.integers(0, cfg.vocab, 8)))
                 for d in ("a", "b")]
        for t in opens:  # 2 opens = full bucket: served despite the deadline
            t.result(WAIT)
        edits = [asrv.submit_replace(d, 1, int(rng.integers(cfg.vocab)))
                 for d in ("a", "b")]
        for t in edits:
            t.result(WAIT)
        assert asrv.stats.full_rounds >= 2
        assert asrv.stats.deadline_rounds == 0
    finally:
        asrv.close()
    np.testing.assert_array_equal(srv.suggest("a", N_NEW),
                                  _oracle(setup, srv, "a"))


def test_failed_request_does_not_stall_the_loop(setup):
    """A bad request fails ITS ticket; the scheduler keeps serving."""
    cfg = setup[0]
    srv = _server(setup)
    rng = np.random.default_rng(9)
    with AsyncBatchServer(srv, max_batch_delay_ms=2.0) as asrv:
        bad = asrv.submit_replace("nope", 0, 1)
        good = asrv.open_document("ok", list(rng.integers(0, cfg.vocab, 8)))
        with pytest.raises(KeyError):
            bad.result(WAIT)
        good.result(WAIT)
        out = asrv.suggest("ok", N_NEW).result(WAIT)
        assert asrv.stats.requests_failed == 1
    np.testing.assert_array_equal(out, _oracle(setup, srv, "ok"))


def test_opens_coalesce_into_one_round(setup):
    """Opens admitted within one deadline window land in a single round
    (and therefore a single batched open_documents ingest)."""
    cfg = setup[0]
    srv = _server(setup)
    rng = np.random.default_rng(2)
    with AsyncBatchServer(srv, max_batch_delay_ms=100.0,
                          bucket_docs=64) as asrv:
        docs = {f"o{i}": list(rng.integers(0, cfg.vocab, 9))
                for i in range(3)}
        tickets = [asrv.open_document(d, toks) for d, toks in docs.items()]
        for t in tickets:
            t.result(WAIT)
        assert asrv.stats.rounds == 1
        assert asrv.stats.admitted_opens == 3
        for d, toks in docs.items():
            assert list(asrv.tokens(d).result(WAIT)) == toks


# -------------------------------------------------- re-ingests via async path


def test_async_forced_grow_matches_oracle(setup):
    """Insert bursts over a min-capacity-8 document force an n_cap-doubling
    re-ingest mid-stream; the async path stays token-exact through it."""
    cfg = setup[0]
    srv = _server(setup, min_doc_capacity=8)
    rng = np.random.default_rng(11)
    ref = list(rng.integers(0, cfg.vocab, 7))
    with AsyncBatchServer(srv, max_batch_delay_ms=3.0) as asrv:
        asrv.open_document("g", ref).result(WAIT)
        for i in range(8):
            pos = int(rng.integers(len(ref) + 1))
            tok = int(rng.integers(cfg.vocab))
            asrv.submit_insert("g", pos, tok)
            ref.insert(pos, tok)
            got = asrv.suggest("g", N_NEW).result(WAIT)
            np.testing.assert_array_equal(got, _oracle(setup, srv, "g"),
                                          err_msg=f"insert {i}")
        assert list(asrv.tokens("g").result(WAIT)) == ref
    assert srv.stats.grows >= 1


def test_async_forced_defrag_matches_oracle(setup):
    """A tiny position pool exhausts insertion gaps mid-stream: ids
    re-spread (defrag + full re-ingest) and all suggestion reuse drops; the
    async path stays token-exact through it."""
    cfg = setup[0]
    srv = _server(setup, max_batch=2, pos_pool=64)
    rng = np.random.default_rng(13)
    ref = list(rng.integers(0, cfg.vocab, 8))
    with AsyncBatchServer(srv, max_batch_delay_ms=3.0) as asrv:
        asrv.open_document("d", ref).result(WAIT)
        for i in range(7):
            tok = int(rng.integers(cfg.vocab))
            asrv.submit_insert("d", 3, tok)
            ref.insert(3, tok)
            got = asrv.suggest("d", N_NEW).result(WAIT)
            np.testing.assert_array_equal(got, _oracle(setup, srv, "d"),
                                          err_msg=f"insert {i}")
        assert list(asrv.tokens("d").result(WAIT)) == ref
    assert srv.stats.defrags >= 1


# ------------------------------------------------------------------- streaming


def test_subscription_streams_tokens_then_suggestions(setup):
    """A subscription delivers per-token events as the decode loop runs,
    then the completed continuation; token events reassemble into exactly
    the suggestion, serials strictly increase across refreshes."""
    cfg = setup[0]
    srv = _server(setup)
    rng = np.random.default_rng(17)
    ref = list(rng.integers(0, cfg.vocab, 10))
    with AsyncBatchServer(srv, max_batch_delay_ms=3.0) as asrv:
        asrv.open_document("s", ref).result(WAIT)
        stream = asrv.subscribe("s", N_NEW)
        serial0, sugg0 = stream.next_suggestion(WAIT)
        np.testing.assert_array_equal(sugg0, _oracle(setup, srv, "s"))

        # two edit bursts -> two (or more) edit-triggered refreshes
        for _ in range(2):
            pos = int(rng.integers(len(ref)))
            tok = int(rng.integers(cfg.vocab))
            asrv.submit_replace("s", pos, tok).result(WAIT)
            ref[pos] = tok
            asrv.flush(WAIT)
        np.testing.assert_array_equal(
            asrv.suggest("s", N_NEW).result(WAIT), _oracle(setup, srv, "s"))
        asrv.unsubscribe(stream)

    # replay the event stream: per refresh, n_new token events indexed
    # 0..n-1 whose tokens equal the completed continuation that follows
    events, tokens, last_serial = [], {}, serial0
    while True:
        kind, serial, *rest = stream.get(timeout=1.0)
        if kind == "closed":
            break
        events.append((kind, serial, rest))
        if kind == "token":
            idx, tok = rest
            tokens.setdefault(serial, [])
            assert idx == len(tokens[serial]), "token events out of order"
            tokens[serial].append(tok)
        else:
            assert kind == "suggestion"
            assert serial > last_serial or serial == serial0
            last_serial = max(last_serial, serial)
            assert tokens[serial] == list(rest[0]), \
                "streamed tokens disagree with the completed continuation"
    refreshes = [e for e in events if e[0] == "suggestion"]
    assert len(refreshes) >= 2  # both bursts produced a delivery
    assert srv.stats.suggest_latency.count > 0


def test_close_document_closes_streams(setup):
    cfg = setup[0]
    srv = _server(setup)
    rng = np.random.default_rng(19)
    with AsyncBatchServer(srv, max_batch_delay_ms=3.0) as asrv:
        asrv.open_document("z", list(rng.integers(0, cfg.vocab, 8))).result(
            WAIT)
        stream = asrv.subscribe("z", N_NEW)
        stream.next_suggestion(WAIT)
        asrv.close_document("z").result(WAIT)
        with pytest.raises(RuntimeError, match="closed"):
            stream.next_suggestion(5.0)
        assert "z" not in srv.docs
