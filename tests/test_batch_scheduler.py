"""Scheduler/bucketing invariants for the batch server (ISSUE 1 satellites).

Three invariants, checked both property-based (hypothesis, via the
`_hypothesis_compat` shim) and with always-run deterministic seeds:

1. every submitted edit is applied exactly once;
2. every capacity the scheduler buckets by (n_cap, C, R) is a power of two;
3. final per-document token buffers equal the edit-replayed reference under
   random interleavings of submits and flushes.

The model here is tiny (smoke config) but real — dispatches go through the
vmapped jit engine, so these also exercise stacking/unstacking and the
overflow path under adversarial schedules.
"""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.vq_opt_125m import smoke_config
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
    return cfg, params


def _is_pow2(v: int) -> bool:
    return v >= 1 and (v & (v - 1)) == 0


def _run_interleaving(cfg, params, seed: int, n_docs: int, n_ops: int,
                      row_capacity: int = 16, max_batch: int = 3) -> None:
    """Random schedule of submits and flushes; assert all three invariants."""
    rng = np.random.default_rng(seed)
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=row_capacity,
                      max_batch=max_batch, min_doc_capacity=16)
    ref: dict[str, list[int]] = {}
    for i in range(n_docs):
        n = int(rng.integers(4, 36))
        toks = rng.integers(0, cfg.vocab, n)
        ref[f"d{i}"] = list(toks)
        srv.open_document(f"d{i}", toks)
    submitted = 0
    for _ in range(n_ops):
        if rng.random() < 0.25:
            srv.step()  # partial flush mid-stream
        else:
            did = f"d{int(rng.integers(n_docs))}"
            pos = int(rng.integers(len(ref[did])))
            tok = int(rng.integers(cfg.vocab))
            srv.submit_replace(did, pos, tok)
            ref[did][pos] = tok  # replay reference, submission order
            submitted += 1
    srv.flush()

    # invariant 1: exactly-once application
    assert srv.pending_count() == 0
    assert srv.stats.edits_submitted == submitted
    assert srv.stats.edits_applied == submitted

    # invariant 2: power-of-two capacities everywhere the scheduler buckets
    assert _is_pow2(srv.C)
    for doc in srv.docs.values():
        assert _is_pow2(doc.n_cap) and doc.n_cap >= doc.n
        assert _is_pow2(doc.row_capacity) and doc.row_capacity <= doc.n_cap
    for (C, R) in srv._engines:
        assert _is_pow2(C) and _is_pow2(R)

    # invariant 3: final buffers == edit-replayed references
    for did, toks in ref.items():
        assert list(srv.tokens(did)) == toks, did


# ------------------------------------------------------- deterministic seeds


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_interleaving_invariants_deterministic(setup, seed):
    cfg, params = setup
    _run_interleaving(cfg, params, seed=seed, n_docs=3, n_ops=30)


def test_conflicting_writes_same_position_fifo(setup):
    """Two queued writes to one position must land in submission order even
    though a single scatter bucket cannot hold both."""
    cfg, params = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      min_doc_capacity=16)
    rng = np.random.default_rng(3)
    toks = list(rng.integers(0, cfg.vocab, 20))
    srv.open_document("d", toks)
    with pytest.raises(ValueError):  # host buffer and device state must
        srv.submit_replace("d", 0, cfg.vocab)  # never see out-of-vocab tokens
    for tok in (5, 6, 7):  # three writes, same position
        srv.submit_replace("d", 10, tok)
    srv.submit_replace("d", 11, 8)
    assert srv.step() == 2  # (10,5) and the commuting (11,8) share a bucket
    assert srv.step() == 1  # (10,6) — same-position conflicts go one per round
    assert srv.step() == 1  # (10,7)
    assert srv.tokens("d")[10] == 7  # last writer won
    assert srv.tokens("d")[11] == 8
    assert srv.stats.batch_steps == 3


def test_capacity_overflow_doubles_to_pow2_and_converges(setup):
    """R=1 + wide edits: doubling must converge (R caps at n_cap, where
    overflow is impossible) and stay a power of two throughout."""
    cfg, params = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=1,
                      min_doc_capacity=16)
    rng = np.random.default_rng(4)
    toks = list(rng.integers(0, cfg.vocab, 16))
    srv.open_document("d", toks)
    for i in range(8):
        srv.submit_replace("d", i, int(rng.integers(cfg.vocab)))
        toks[i] = srv.docs["d"].pending[-1][2]  # (op, pos, tok)
    srv.flush()
    doc = srv.docs["d"]
    assert list(srv.tokens("d")) == toks
    assert _is_pow2(doc.row_capacity)
    assert doc.row_capacity <= doc.n_cap


def test_bucket_grouping_by_shape(setup):
    """Docs of different length buckets never share a dispatch; docs of the
    same bucket do (observable through mean batch size)."""
    cfg, params = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=8, min_doc_capacity=16)
    rng = np.random.default_rng(5)
    for i, n in enumerate((10, 12, 14, 60)):  # three n_cap=16, one n_cap=64
        srv.open_document(f"d{i}", rng.integers(0, cfg.vocab, n))
    for i in range(4):
        srv.submit_replace(f"d{i}", 1, 3)
    srv.step()
    # one dispatch for the 16-bucket trio + one for the 64-bucket doc
    assert srv.stats.batch_steps == 2
    assert srv.stats.batched_docs == 4


def test_failed_dispatch_restores_queue(setup, monkeypatch):
    """A dispatch that raises (device OOM, interrupt) must put every taken
    edit back at the front of its queue, in submission order."""
    cfg, params = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      min_doc_capacity=16)
    srv.open_document("d", list(range(1, 17)))
    srv.submit_replace("d", 2, 9)
    srv.submit_replace("d", 5, 4)
    eng = srv.engine(srv.C, srv.docs["d"].row_capacity)

    def boom(*args, **kwargs):
        raise RuntimeError("simulated device failure")

    monkeypatch.setattr(eng, "batch_apply_replaces", boom)
    with pytest.raises(RuntimeError, match="simulated device failure"):
        srv.step()
    assert list(srv.docs["d"].pending) == [("replace", 2, 9), ("replace", 5, 4)]
    assert srv.stats.edits_applied == 0 and srv.stats.batch_steps == 0
    monkeypatch.undo()
    srv.flush()
    toks = srv.tokens("d")
    assert toks[2] == 9 and toks[5] == 4


def _gap_profile(alloc):
    return [alloc.gap_at(i) for i in range(len(alloc) + 1)]


def test_failed_dispatch_rollback_no_allocator_leak(setup, monkeypatch):
    """A rolled-back failed dispatch must restore the affected documents'
    ``PositionAllocator`` gap state exactly — even when the take itself ran
    a defrag (id re-spread + re-ingest) first — and must not leak any gap
    state into documents placed on other shard rows of the same dispatch or
    not dispatched at all (ISSUE 4 satellite). Runs over a 2-shard mesh when
    the environment has the devices (the CI test-multidevice job), else
    single-device — the rollback path is identical."""
    import jax

    from repro.launch.mesh import make_serving_mesh

    cfg, params = setup
    mesh = make_serving_mesh(min(2, jax.device_count()))
    # pool of 16 over 8 tokens: the gap at one insertion point survives
    # exactly one insert, so the second take at the same point must defrag
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=4, min_doc_capacity=16, pos_pool=16,
                      mesh=mesh)
    ref = {d: list(range(1, 9)) for d in ("a", "b", "c")}
    for d, toks in ref.items():
        srv.open_document(d, toks)
    srv.submit_insert("a", 3, 5)
    ref["a"].insert(3, 5)
    srv.flush()  # consumes doc a's gap at sequence index 3

    pre = {d: srv.docs[d].allocator.snapshot().copy() for d in ref}
    pre_gaps = {d: _gap_profile(srv.docs[d].allocator) for d in ref}
    srv.submit_insert("a", 3, 6)  # gap exhausted: the take defrags first
    srv.submit_insert("b", 0, 7)  # same dispatch group, different shard row
    ref["a"].insert(3, 6)
    ref["b"].insert(0, 7)
    eng = srv.engine(srv.C, srv.docs["a"].row_capacity)
    monkeypatch.setattr(
        eng, "batch_apply_inserts",
        lambda *a, **k: (_ for _ in ()).throw(
            RuntimeError("simulated device failure")))
    applied_before = srv.stats.edits_applied
    with pytest.raises(RuntimeError, match="simulated device failure"):
        srv.step()
    assert srv.stats.defrags >= 1  # the take really exercised the slow path

    # every allocator is back to its pre-take gap state: the defragged doc
    # rolled back to pre-defrag ids, its dispatch-mates and idle docs are
    # untouched
    for d in ref:
        np.testing.assert_array_equal(srv.docs[d].allocator.snapshot(),
                                      pre[d])
        assert _gap_profile(srv.docs[d].allocator) == pre_gaps[d]
    assert list(srv.docs["a"].pending) == [("insert", 3, 6)]
    assert list(srv.docs["b"].pending) == [("insert", 0, 7)]
    assert srv.stats.edits_applied == applied_before

    monkeypatch.undo()
    srv.flush()  # the retry re-defrags and applies everything exactly once
    for d, toks in ref.items():
        assert list(srv.tokens(d)) == toks, d


# ------------------------------------------------------------ property-based


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_docs=st.integers(1, 4),
       n_ops=st.integers(1, 40))
def test_interleaving_invariants_property(setup, seed, n_docs, n_ops):
    cfg, params = setup
    _run_interleaving(cfg, params, seed=seed, n_docs=n_docs, n_ops=n_ops)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), row_capacity=st.sampled_from([1, 2, 4]))
def test_tight_capacity_property(setup, seed, row_capacity):
    """Under overflow-heavy schedules the invariants must still hold."""
    cfg, params = setup
    _run_interleaving(cfg, params, seed=seed, n_docs=2, n_ops=16,
                      row_capacity=row_capacity, max_batch=2)
