"""Batched jit serving == per-document serving == the NumPy engine.

Parity ladder (ISSUE 1 tentpole): every slice of a batched result must match
the single-document jit engine, which in turn matches the host NumPy
``IncrementalEngine`` (identical codes, float-tolerance activations) — and
the overflow → full-forward fallback must restore exactness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.core.incremental import IncrementalEngine
from repro.models import transformer as T
from repro.serving.batch_engine import (
    BatchedJitEngine, stack_states, unstack_state,
)
from repro.serving.batch_server import BatchServer, next_pow2
from repro.serving.jit_engine import JitIncrementalEngine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    beng = BatchedJitEngine(params, cfg, edit_capacity=4, row_capacity=32)
    neng = IncrementalEngine(jax.device_get(params), cfg)
    return cfg, params, beng, neng


def _batch_docs(cfg, b=3, n=40, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, (b, n))
    positions = np.tile(np.arange(n) * 5, (b, 1))
    return tokens, positions


def _assert_doc_matches_numpy(js, ns, neng, atol=3e-4):
    for li in range(len(neng.layers)):
        np.testing.assert_array_equal(np.asarray(js.codes[li]),
                                      ns.layers[li].codes)
    np.testing.assert_allclose(np.asarray(js.x[-1]), ns.xs[-1], atol=atol)


def test_batch_full_forward_matches_numpy_per_doc(setup):
    cfg, params, beng, neng = setup
    tokens, positions = _batch_docs(cfg)
    bstate = beng.batch_full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    for b in range(tokens.shape[0]):
        ns = neng.full_forward(tokens[b], positions[b])
        _assert_doc_matches_numpy(unstack_state(bstate, b), ns, neng)


def test_batch_apply_replaces_matches_numpy_per_doc(setup):
    cfg, params, beng, neng = setup
    tokens, positions = _batch_docs(cfg, seed=1)
    bstate = beng.batch_full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    nstates = [neng.full_forward(tokens[b], positions[b]) for b in range(3)]
    rng = np.random.default_rng(2)
    for trial in range(2):
        # disjoint per-doc edit buckets, including one all-empty bucket
        edit_pos = np.full((3, 4), -1, np.int32)
        edit_tok = np.zeros((3, 4), np.int32)
        per_doc = []
        for b in range(2):  # doc 2 gets no edits this round
            pos = sorted(rng.choice(tokens.shape[1], 2, replace=False))
            tok = rng.integers(0, cfg.vocab, 2)
            edit_pos[b, :2] = pos
            edit_tok[b, :2] = tok
            per_doc.append((list(map(int, pos)), list(map(int, tok))))
        bstate, overflow = beng.batch_apply_replaces(
            bstate, jnp.asarray(edit_pos), jnp.asarray(edit_tok))
        assert not np.asarray(overflow).any()
        for b, (pos, tok) in enumerate(per_doc):
            nstates[b] = neng.apply_replaces(nstates[b], pos, tok)
        for b in range(3):
            _assert_doc_matches_numpy(unstack_state(bstate, b), nstates[b], neng)


def test_batch_matches_single_doc_engine_exactly(setup):
    # float atol is 3e-4, not 1e-5: the vmapped and single-doc programs
    # batch their reductions differently, and the drift depends on the CPU
    # client's partitioning (the forced-host-device CI leg reaches ~2.4e-4).
    # Codes — the quantity serving correctness rests on — must match exactly.
    cfg, params, beng, neng = setup
    seng = JitIncrementalEngine({}, cfg, edit_capacity=4, row_capacity=32,
                                _weights=beng.weights)
    tokens, positions = _batch_docs(cfg, seed=3)
    bstate = beng.batch_full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    singles = [seng.full_forward(jnp.asarray(tokens[b]), jnp.asarray(positions[b]))
               for b in range(3)]
    restacked = stack_states(singles)
    for a, c in zip(bstate, restacked):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=3e-4)
    np.testing.assert_array_equal(np.asarray(bstate.codes),
                                  np.asarray(restacked.codes))
    ep = jnp.asarray([[1, 20, -1, -1]] * 3, jnp.int32)
    et = jnp.asarray([[7, 9, 0, 0]] * 3, jnp.int32)
    b2, ovf = beng.batch_apply_replaces(bstate, ep, et)
    s2, o2 = seng.apply_replaces(singles[0], ep[0], et[0])
    assert bool(ovf[0]) == bool(o2)
    np.testing.assert_allclose(np.asarray(unstack_state(b2, 0).x),
                               np.asarray(s2.x), atol=3e-4)
    np.testing.assert_array_equal(np.asarray(unstack_state(b2, 0).codes),
                                  np.asarray(s2.codes))


def test_batch_per_doc_overflow_flags(setup):
    """Overflow is per-document: a wide edit trips only its own flag."""
    cfg, params, beng, neng = setup
    tight = BatchedJitEngine({}, cfg, edit_capacity=4, row_capacity=2,
                             _weights=beng.weights)
    tokens, positions = _batch_docs(cfg, seed=4)
    bstate = tight.batch_full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    edit_pos = np.full((3, 4), -1, np.int32)
    edit_tok = np.zeros((3, 4), np.int32)
    edit_pos[1] = [1, 2, 3, 4]  # 4 edits alone exceed R=2 for doc 1 only
    edit_tok[1] = [9, 9, 9, 9]
    _, overflow = tight.batch_apply_replaces(
        bstate, jnp.asarray(edit_pos), jnp.asarray(edit_tok))
    overflow = np.asarray(overflow)
    assert bool(overflow[1])
    assert not bool(overflow[0]) and not bool(overflow[2])


def test_batched_patch_kernel_route_matches_einsum(setup):
    """use_patch_kernel=True routes the column patch through the Pallas
    kernel (batch grid dimension under vmap) — results must be identical."""
    cfg, params, beng, neng = setup
    keng = BatchedJitEngine({}, cfg, edit_capacity=4, row_capacity=32,
                            use_patch_kernel=True, _weights=beng.weights)
    tokens, positions = _batch_docs(cfg, b=2, n=40, seed=5)
    bstate = beng.batch_full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    ep = jnp.asarray([[2, 11, -1, -1], [5, -1, -1, -1]], jnp.int32)
    et = jnp.asarray([[3, 4, 0, 0], [8, 0, 0, 0]], jnp.int32)
    s_e, o_e = beng.batch_apply_replaces(bstate, ep, et)
    s_k, o_k = keng.batch_apply_replaces(bstate, ep, et)
    np.testing.assert_array_equal(np.asarray(o_e), np.asarray(o_k))
    np.testing.assert_array_equal(np.asarray(s_e.codes), np.asarray(s_k.codes))
    np.testing.assert_allclose(np.asarray(s_e.x), np.asarray(s_k.x), atol=2e-5)


# --------------------------------------------------------------- BatchServer


def test_server_parity_with_numpy_engine(setup):
    """End-to-end: padded, bucketed, batch-dispatched documents match the
    NumPy engine run on the same padded inputs."""
    cfg, params, beng, neng = setup
    srv = BatchServer(jax.device_get(params), cfg, edit_capacity=4,
                      row_capacity=16, max_batch=4, min_doc_capacity=16)
    rng = np.random.default_rng(6)
    ref = {}
    for i in range(4):
        n = int(rng.integers(18, 40))
        toks = rng.integers(0, cfg.vocab, n)
        ref[f"d{i}"] = list(toks)
        srv.open_document(f"d{i}", toks)
    for _ in range(25):
        did = f"d{int(rng.integers(4))}"
        pos = int(rng.integers(len(ref[did])))
        tok = int(rng.integers(cfg.vocab))
        srv.submit_replace(did, pos, tok)
        ref[did][pos] = tok
    srv.flush()
    assert srv.pending_count() == 0
    assert srv.stats.edits_applied == srv.stats.edits_submitted == 25
    for did, toks in ref.items():
        assert list(srv.tokens(did)) == toks
        doc = srv.docs[did]
        ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
        js = doc.state
        sl = np.asarray(doc.slots)
        for li in range(len(neng.layers)):
            np.testing.assert_array_equal(np.asarray(js.codes[li])[sl],
                                          ns.layers[li].codes)
        np.testing.assert_allclose(np.asarray(js.x[-1])[sl],
                                   ns.xs[-1], atol=3e-4)


def test_server_overflow_fallback_restores_exactness(setup):
    """R=1 guarantees overflow on nearly every edit; the full-forward
    fallback + capacity doubling must keep the state exact anyway."""
    cfg, params, beng, neng = setup
    srv = BatchServer(jax.device_get(params), cfg, edit_capacity=4,
                      row_capacity=1, max_batch=4, min_doc_capacity=16)
    rng = np.random.default_rng(7)
    toks = list(rng.integers(0, cfg.vocab, 30))
    srv.open_document("d", toks)
    for pos in (3, 9, 15):
        tok = int(rng.integers(cfg.vocab))
        srv.submit_replace("d", pos, tok)
        toks[pos] = tok
    srv.flush()
    assert srv.stats.overflows >= 1
    assert srv.stats.full_forwards >= 2  # ingest + at least one fallback
    doc = srv.docs["d"]
    assert list(srv.tokens("d")) == toks
    ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
    sl = np.asarray(doc.slots)
    np.testing.assert_allclose(np.asarray(doc.state.x[-1])[sl],
                               ns.xs[-1], atol=3e-4)
    # capacity doubling: the doc's row bucket grew, still a power of two
    assert doc.row_capacity > 1
    assert doc.row_capacity & (doc.row_capacity - 1) == 0


def test_server_logits_match_numpy(setup):
    cfg, params, beng, neng = setup
    srv = BatchServer(jax.device_get(params), cfg, edit_capacity=4,
                      row_capacity=16, min_doc_capacity=16)
    rng = np.random.default_rng(8)
    toks = rng.integers(0, cfg.vocab, 20)
    srv.open_document("d", toks)
    srv.submit_replace("d", 4, 7)
    # unflushed edits: every read accessor must refuse stale state
    for accessor in (srv.logits, srv.state, srv.tokens):
        with pytest.raises(RuntimeError):
            accessor("d")
    srv.flush()
    doc = srv.docs["d"]
    got = srv.logits("d")
    assert got.shape == (cfg.vocab,)
    # recompute from the real-length, sequence-ordered document directly
    ns_real = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
    np.testing.assert_allclose(got, neng.logits_at(ns_real), atol=3e-4)


def test_grow_reingest_does_not_race_host_mirrors(setup):
    """Regression: jax reads numpy inputs ASYNCHRONOUSLY (and may zero-copy
    them), so a grow-triggered re-ingest that handed the live host mirrors
    to ``full_forward`` could "see" the inserts the very same take peeled
    AFTER it — the dispatch then applied them a second time (double-counted
    ``n_real``, garbage T columns, VQ code flips). ``_device_copy`` now
    snapshots mirrors eagerly. This drives the exact traffic shape that
    exposed the race — full-capacity documents whose insert takes grow +
    re-ingest while other documents keep the device queue busy — and
    asserts codes/counters stay exact against the NumPy engine."""
    cfg, params, beng, neng = setup
    srv = BatchServer(jax.device_get(params), cfg, edit_capacity=4,
                      row_capacity=64, max_batch=8, min_doc_capacity=64)
    rng = np.random.default_rng(0)
    ref = {f"d{i}": list(rng.integers(0, cfg.vocab, 64)) for i in range(8)}
    srv.open_documents({d: list(t) for d, t in ref.items()})
    for _ in range(24):  # mixed stream; docs are FULL, so inserts grow
        did = f"d{int(rng.integers(8))}"
        r = ref[did]
        kind = rng.choice(["replace", "insert", "delete"], p=[0.5, 0.3, 0.2])
        if kind == "insert":
            p, t = int(rng.integers(len(r) + 1)), int(rng.integers(cfg.vocab))
            srv.submit_insert(did, p, t)
            r.insert(p, t)
        elif kind == "delete" and len(r) > 1:
            p = int(rng.integers(len(r)))
            srv.submit_delete(did, p)
            del r[p]
        else:
            p, t = int(rng.integers(len(r))), int(rng.integers(cfg.vocab))
            srv.submit_replace(did, p, t)
            r[p] = t
    srv.flush()
    assert srv.stats.grows >= 1  # the race's trigger really fired
    for did, r in ref.items():
        assert list(srv.tokens(did)) == r, did
        doc = srv.docs[did]
        assert int(doc.state.n_real) == int(doc.valid.sum()) == len(r)
        ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
        sl = np.asarray(doc.slots)
        for li in range(len(neng.layers)):
            np.testing.assert_array_equal(np.asarray(doc.state.codes[li])[sl],
                                          ns.layers[li].codes)
        np.testing.assert_allclose(srv.logits(did), neng.logits_at(ns),
                                   atol=3e-4)


def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 17, 64)] == [1, 2, 4, 32, 64]
    assert next_pow2(3, minimum=16) == 16
