"""Checkpoint save/restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_pytree, save_pytree
from repro.configs.vq_opt_125m import smoke_config
from repro.training import train_state_init


def test_roundtrip_train_state(tmp_path):
    cfg = smoke_config(vqt=True)
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    p = str(tmp_path / "ckpt.npz")
    save_pytree(p, state, metadata={"step": 0})
    restored = restore_pytree(p, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "x.npz")
    save_pytree(p, {"w": jnp.zeros((2, 3))})
    with pytest.raises(ValueError):
        restore_pytree(p, {"w": jnp.zeros((3, 2))})


def test_restore_missing_key_raises(tmp_path):
    p = str(tmp_path / "y.npz")
    save_pytree(p, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_pytree(p, {"w": jnp.zeros((2,)), "b": jnp.zeros((1,))})
