"""Checkpoint save/restore: pytree/train-state paths AND the serving
document-state path (the state store's cold tier, ISSUE 5) — a full
``JitState`` with its position-id mirrors, valid mask, allocator snapshot
and suggestion watermarks must round-trip bit-exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    restore_document_state, restore_pytree, save_document_state, save_pytree,
)
from repro.configs.vq_opt_125m import smoke_config
from repro.training import train_state_init


def test_roundtrip_train_state(tmp_path):
    cfg = smoke_config(vqt=True)
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    p = str(tmp_path / "ckpt.npz")
    save_pytree(p, state, metadata={"step": 0})
    restored = restore_pytree(p, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "x.npz")
    save_pytree(p, {"w": jnp.zeros((2, 3))})
    with pytest.raises(ValueError):
        restore_pytree(p, {"w": jnp.zeros((3, 2))})


def test_restore_missing_key_raises(tmp_path):
    p = str(tmp_path / "y.npz")
    save_pytree(p, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_pytree(p, {"w": jnp.zeros((2,)), "b": jnp.zeros((1,))})


# -------------------------------------------------- serving document state


def _slot_buffer_state():
    """A realistic slot-buffer JitState: gapped position ids, a free
    (invalid) slot in the middle, post-edit content — the exact thing the
    state store's cold tier must preserve."""
    from repro.core.positional import PositionAllocator
    from repro.models import transformer as T
    from repro.serving.jit_engine import JitIncrementalEngine

    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    eng = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=16)
    n, n_cap = 6, 8
    alloc = PositionAllocator(n, cfg.pos_pool or cfg.max_seq)
    rng = np.random.default_rng(4)
    tokens = np.zeros(n_cap, np.int32)
    tokens[:n] = rng.integers(0, cfg.vocab, n)
    valid = np.zeros(n_cap, bool)
    valid[:n] = True
    valid[3] = False  # a freed slot mid-buffer: garbage activations ride along
    positions = np.full(n_cap, (cfg.pos_pool or cfg.max_seq) - 1, np.int32)
    positions[:n] = alloc.snapshot()
    state = eng.full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                             jnp.asarray(valid))
    return state, alloc, eng


def test_roundtrip_document_state(tmp_path):
    state, alloc, eng = _slot_buffer_state()
    p = str(tmp_path / "doc.npz")
    save_document_state(p, state, allocator_ids=alloc.snapshot(),
                        invalid_from=17, touched_from=None,
                        extra={"doc_id": "d0"})
    restored, ids, meta = restore_document_state(p)
    # every field bit-exact — including the position-id mirror, the valid
    # mask (with its mid-buffer hole) and n_real
    for name, a, b in zip(type(state)._fields, state, restored):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
        assert np.asarray(a).dtype == np.asarray(b).dtype, name
    np.testing.assert_array_equal(ids, alloc.snapshot())
    assert meta["invalid_from"] == 17
    assert meta["touched_from"] is None
    assert meta["doc_id"] == "d0"
    # the restored state serves logits identical to the original
    np.testing.assert_array_equal(
        np.asarray(eng.logits_at(state, jnp.int32(5))),
        np.asarray(eng.logits_at(
            jax.tree.map(jnp.asarray, restored), jnp.int32(5))))


def test_document_state_rejects_non_state(tmp_path):
    with pytest.raises(TypeError):
        save_document_state(str(tmp_path / "x.npz"), {"not": "a state"},
                            allocator_ids=np.arange(3))


def test_document_state_missing_fields_raises(tmp_path):
    p = str(tmp_path / "y.npz")
    np.savez(p, **{"state/tokens": np.zeros(4, np.int32)})
    with pytest.raises(KeyError):
        restore_document_state(p)
