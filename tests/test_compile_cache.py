"""Persistent-compilation-cache plumbing (ISSUE 6 satellite).

The heavy claim — a second process reloads compiled executables from disk —
is exercised by CI's bench-gate job (actions cache keyed on the jax
version); these tests cover the opt-in plumbing: off by default, env-var
and explicit-dir activation, idempotence, and the ``BatchServer`` flag.
"""
import os

import jax
import pytest

from repro.common import compile_cache
from repro.common.compile_cache import (
    ENV_VAR, enable_persistent_compilation_cache,
)


@pytest.fixture(autouse=True)
def _isolate(monkeypatch):
    """Each test sees a clean module state and no ambient env var; the
    jax config value is restored afterwards so other suites are unaffected."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    monkeypatch.setattr(compile_cache, "_enabled_dir", None)
    before = jax.config.jax_compilation_cache_dir
    yield
    jax.config.update("jax_compilation_cache_dir", before)


def test_off_without_dir_or_env():
    assert enable_persistent_compilation_cache() is None


def test_env_var_activates(tmp_path, monkeypatch):
    target = tmp_path / "jcc-env"
    monkeypatch.setenv(ENV_VAR, str(target))
    got = enable_persistent_compilation_cache()
    assert got == str(target)
    assert os.path.isdir(got)
    assert jax.config.jax_compilation_cache_dir == got


def test_explicit_dir_wins_and_is_idempotent(tmp_path, monkeypatch):
    monkeypatch.setenv(ENV_VAR, str(tmp_path / "ignored"))
    target = tmp_path / "jcc-explicit"
    got = enable_persistent_compilation_cache(str(target))
    assert got == str(target)
    assert enable_persistent_compilation_cache(str(target)) == got
    assert not (tmp_path / "ignored").exists()
    # cache-everything thresholds: the serving bucket steps are small
    assert jax.config.jax_persistent_cache_min_compile_time_secs == 0
    assert jax.config.jax_persistent_cache_min_entry_size_bytes == -1


def test_batch_server_flag(tmp_path):
    """The BatchServer kwarg threads through without requiring the env."""
    from repro.configs.vq_opt_125m import smoke_config
    from repro.models import transformer as T
    from repro.serving.batch_server import BatchServer

    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    target = tmp_path / "jcc-srv"
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=2, min_doc_capacity=16,
                      compilation_cache_dir=str(target))
    assert srv.compilation_cache_dir == str(target)
    assert os.path.isdir(target)
    # default stays off
    srv2 = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                       max_batch=2, min_doc_capacity=16)
    assert srv2.compilation_cache_dir is None
