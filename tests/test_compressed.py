"""Property tests for the compressed activation format (paper §3.1-3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import compressed as C


def _random_compressed(rng, b, n, q, d):
    rows = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, q, (b, n)), jnp.int32)
    return C.from_dense_rows(rows, idx)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 5), n=st.integers(1, 16), q=st.integers(1, 8),
    d=st.integers(1, 9), seed=st.integers(0, 2**31 - 1),
)
def test_per_location_equals_dense(b, n, q, d, seed):
    """(P, F(C)) == F(dense) for per-location ops (paper eq. 2)."""
    rng = np.random.default_rng(seed)
    c = _random_compressed(rng, b, n, q, d)
    f = lambda x: jnp.tanh(x) * 2.0 + 1.0
    out = C.per_location(f, c)
    np.testing.assert_allclose(out.to_dense(), f(c.to_dense()), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4), n=st.integers(1, 12), qa=st.integers(1, 6),
    qb=st.integers(1, 6), d=st.integers(1, 8), seed=st.integers(0, 2**31 - 1),
)
def test_binary_equals_dense(b, n, qa, qb, d, seed):
    """Binary element-wise ops on unique index pairs (App. A.3)."""
    rng = np.random.default_rng(seed)
    a = _random_compressed(rng, b, n, qa, d)
    c = _random_compressed(rng, b, n, qb, d)
    out = C.add(a, c)
    np.testing.assert_allclose(out.to_dense(), a.to_dense() + c.to_dense(), rtol=1e-6)
    # codebook growth is bounded by unique pairs
    assert int(out.n_codes) <= qa * qb
    assert int(out.n_codes) <= b * n


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4), n=st.integers(1, 12), q=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_recompress_drops_unused(b, n, q, seed):
    rng = np.random.default_rng(seed)
    c = _random_compressed(rng, b, n, q, 4)
    r = C.recompress(c)
    np.testing.assert_allclose(r.to_dense(), c.to_dense(), rtol=1e-6)
    assert int(r.n_codes) == len(np.unique(np.asarray(c.idx)))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 16), b=st.integers(1, 6), n_edit=st.integers(0, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_base_and_deltas_storage_bound(n, b, n_edit, seed):
    """The sparse batch representation is O(n + b) when rows are near-equal
    (paper §3.1 fig. 2)."""
    rng = np.random.default_rng(seed)
    base_idx = rng.integers(0, n + 1, n)
    idx = np.tile(base_idx, (b, 1))
    for _ in range(n_edit):  # a few per-row deviations
        idx[rng.integers(b), rng.integers(n)] = rng.integers(0, n + 1)
    rows = jnp.asarray(rng.standard_normal((n + 1, 4)), jnp.float32)
    c = C.from_dense_rows(rows, jnp.asarray(idx, jnp.int32))
    base, delta = C.base_and_deltas(c)
    # reconstruct
    rec = np.where(np.asarray(delta), np.asarray(c.idx), np.asarray(base)[None, :])
    np.testing.assert_array_equal(rec, idx)
    assert int(np.asarray(delta).sum()) <= n_edit * 2 + b  # near-sparse


def test_from_tokens_is_compressed():
    emb = jnp.asarray(np.random.default_rng(0).standard_normal((10, 4)), jnp.float32)
    toks = jnp.asarray([[1, 2, 3], [1, 2, 9]], jnp.int32)
    c = C.from_tokens(emb, toks)
    np.testing.assert_allclose(c.to_dense(), emb[toks], rtol=1e-7)


def test_compress_dedups_rows():
    rows = np.random.default_rng(0).standard_normal((4, 3)).astype(np.float32)
    x = jnp.asarray(rows[[0, 1, 0, 2, 2, 1]]).reshape(2, 3, 3)
    c = C.compress(x)
    assert int(c.n_codes) == 3
    np.testing.assert_allclose(c.to_dense(), x, rtol=1e-7)
