"""Chunked prefill + greedy_decode (ISSUE 3 satellites): one batched
prefill step must equal the training forward AND the per-token decode loop,
and decode caches built from the jit engine's KV export must continue a
document exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.models import transformer as T
from repro.serving.decode import greedy_decode, make_serve_step
from repro.serving.jit_engine import JitIncrementalEngine


@pytest.fixture(scope="module", params=[True, False],
                ids=["vqt-sigma", "opt-softmax"])
def setup(request):
    cfg = smoke_config(vqt=request.param)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _doc(cfg, b=2, n=24, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, n)), jnp.int32)
    positions = jnp.asarray(
        np.sort(rng.choice(cfg.max_seq, (b, n), replace=False), axis=1)
        if cfg.pos == "learned" else
        np.sort(np.stack([rng.choice(cfg.pos_pool - 64, n, replace=False)
                          for _ in range(b)]), axis=1),
        jnp.int32)
    return tokens, positions


def test_prefill_step_matches_forward(setup):
    """ONE chunked prefill step == the training/prefill forward, exactly
    (same attention core, cache writes are pure bookkeeping)."""
    cfg, params = setup
    tokens, positions = _doc(cfg)
    caches = T.init_caches(cfg, 2, 32, dtype=jnp.float32)
    logits_pf, _ = T.prefill_step(params, cfg, tokens, caches, positions)
    logits_fwd, _ = T.forward(params, cfg, tokens, positions)
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(logits_fwd),
                               atol=1e-5)


def test_prefill_step_matches_token_by_token_decode(setup):
    """The chunked prefill's caches + last logits == feeding every token
    through decode_step — so greedy_decode's batched prefill is a pure
    speedup, not a semantic change."""
    cfg, params = setup
    tokens, positions = _doc(cfg, seed=1)
    b, n = tokens.shape
    caches_c = T.init_caches(cfg, b, n + 4, dtype=jnp.float32)
    logits_c, caches_c = T.prefill_step(params, cfg, tokens, caches_c,
                                        positions)
    caches_s = T.init_caches(cfg, b, n + 4, dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg))
    for i in range(n):
        logits_s, caches_s = step(params, caches_s, tokens[:, i:i + 1],
                                  positions[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits_c[:, -1:]),
                               np.asarray(logits_s), atol=1e-5)
    flat_c = jax.tree.leaves(caches_c)
    flat_s = jax.tree.leaves(caches_s)
    for a, b_ in zip(flat_c, flat_s):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_greedy_decode_matches_stepwise_reference(setup):
    """greedy_decode (batched prefill path) == a hand-rolled per-token
    prefill + greedy loop at the same cache shape."""
    cfg, params = setup
    tokens, positions = _doc(cfg, b=1, n=12, seed=2)
    n_new = 5
    out, _ = greedy_decode(params, cfg, tokens, n_new, positions=positions)
    # reference: per-token prefill, then greedy steps
    b, n = tokens.shape
    caches = T.init_caches(cfg, b, n + n_new, dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg))
    for i in range(n):
        logits, caches = step(params, caches, tokens[:, i:i + 1],
                              positions[:, i:i + 1])
    ref = []
    cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    ref.append(cur)
    gen_pos = positions[:, -1:] + 1 + jnp.arange(n_new, dtype=jnp.int32)
    for i in range(1, n_new):
        logits, caches = step(params, caches, cur, gen_pos[:, i - 1:i])
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        ref.append(cur)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.concatenate(ref, axis=1)))


def test_prefill_rejects_unchunkable_configs():
    cfg = smoke_config(vqt=False)
    layer = cfg.layer_list()[0]
    windowed = dataclasses.replace(
        cfg, stages=(((dataclasses.replace(layer, window=16),), cfg.n_layers),))
    assert not T.chunkable(windowed)
    params = T.init_params(jax.random.PRNGKey(0), windowed)
    caches = T.init_caches(windowed, 1, 16, dtype=jnp.float32)
    with pytest.raises(ValueError, match="chunked prefill"):
        T.prefill_step(params, windowed, jnp.zeros((1, 4), jnp.int32), caches,
                       jnp.zeros((1, 4), jnp.int32))


def test_batch_export_kv_matches_per_doc():
    """Slice b of the vmapped export == the single-document export."""
    from repro.serving.batch_engine import BatchedJitEngine

    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(0), cfg))
    beng = BatchedJitEngine(params, cfg, edit_capacity=4, row_capacity=16)
    rng = np.random.default_rng(6)
    B, n_cap = 2, 16
    tokens = np.zeros((B, n_cap), np.int32)
    positions = np.full((B, n_cap), cfg.pos_pool - 1, np.int32)
    valid = np.zeros((B, n_cap), bool)
    for b in range(B):
        n = 9 + 3 * b
        tokens[b, :n] = rng.integers(0, cfg.vocab, n)
        positions[b, :n] = np.sort(rng.choice(1024, n, replace=False))
        valid[b, :n] = True
    bstate = beng.batch_full_forward(jnp.asarray(tokens),
                                     jnp.asarray(positions),
                                     jnp.asarray(valid))
    bexp = beng.batch_export_kv(bstate)
    for b in range(B):
        single = beng.export_kv(jax.tree.map(lambda x: x[b], bstate))
        for leaf_b, leaf_s in zip(bexp, single):
            np.testing.assert_array_equal(np.asarray(leaf_b[b]),
                                          np.asarray(leaf_s))


def test_caches_from_kv_continues_engine_state():
    """export_kv -> caches_from_kv -> decode_step == appending the token to
    the document and re-running the full forward (float tolerance; VQ codes
    drive both paths through the same quantized lookups)."""
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(0), cfg))
    eng = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=16)
    rng = np.random.default_rng(4)
    n, n_cap = 11, 16
    tokens = np.zeros(n_cap, np.int32)
    tokens[:n] = rng.integers(0, cfg.vocab, n)
    positions = np.full(n_cap, cfg.pos_pool - 1, np.int32)
    positions[:n] = (np.arange(1, n + 1) * 512) // (n + 1)
    valid = np.zeros(n_cap, bool)
    valid[:n] = True
    state = eng.full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                             jnp.asarray(valid))
    exp = eng.export_kv(state)
    assert int(exp.n_real) == n
    np.testing.assert_array_equal(np.asarray(exp.tokens)[:n], tokens[:n])
    # exported rows are the slot rows, reordered
    order = np.asarray(exp.order)
    np.testing.assert_array_equal(np.asarray(exp.k),
                                  np.asarray(state.k)[:, order])
    caches = T.caches_from_kv(cfg, exp.k[:, None], exp.v[:, None],
                              jnp.asarray([n], jnp.int32), seq_len=n_cap + 4)
    nxt_tok = int(rng.integers(cfg.vocab))
    nxt_pos = int(positions[n - 1]) + 3
    logits_d, _ = T.decode_step(params, cfg,
                                jnp.asarray([[nxt_tok]], jnp.int32), caches,
                                jnp.asarray([[nxt_pos]], jnp.int32))
    logits_f, _ = T.forward(
        params, cfg,
        jnp.asarray(np.concatenate([tokens[:n], [nxt_tok]]))[None],
        jnp.asarray(np.concatenate([positions[:n], [nxt_pos]]))[None])
    np.testing.assert_allclose(np.asarray(logits_d[0, -1]),
                               np.asarray(logits_f[0, -1]), atol=3e-4)
