"""Sigma-delta thresholded propagation (ISSUE 9, DESIGN.md §10).

The exactness contract, tested from the bottom of the stack to the top:

* **kernel** — ``delta_gate`` (Pallas) vs ``delta_gate_ref`` (jnp oracle)
  on odd/non-pow2 shapes, including the ``delta == threshold`` edge (the
  compare is STRICT ``>``: an exactly-at-threshold row is suppressed);
* **engine, threshold 0** — ``delta_threshold=0.0`` is BITWISE-identical
  to the default engine on both the fused and inline paths: the Python-
  level guard means the traced jaxpr is literally the same program;
* **engine, threshold > 0** — suppression actually happens (an infinite
  threshold freezes every ``x[1:]`` leaf while layer-0 token/embedding/
  quantizer state still advances), overflow stays a PRE-gate property
  (thresholding never hides an overflow), and fused vs inline agree on
  which rows propagate (codes exact, activations float-close);
* **server, threshold 0** — a ``BatchServer(delta_threshold=0.0)`` serves
  a mixed grow/defrag-forcing edit stream bitwise-identically to the
  default server, and token-exactly vs a plain-Python list oracle;
* **server, threshold > 0** — suggestions remain oracle-TOKEN-exact at a
  lossy threshold: suppressed rows always sit at/after the suggestion
  watermark, and the refresh re-prefills those rows through exact
  transformer math, so only ``logits()`` ever carries drift.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.kernels.fused_step import delta_gate, delta_gate_ref
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer
from repro.serving.jit_engine import JitIncrementalEngine
from repro.serving.suggest import SuggestionEngine, oracle_suggestion

# ------------------------------------------------------------------ kernel


@pytest.mark.parametrize(
    "r,d,block_r",
    [
        (64, 32, 32),    # pow2 everything
        (13, 7, 8),      # odd rows and feature dim, padded final block
        (1, 256, 128),   # single row, block_r > r
        (100, 33, 16),   # non-pow2 both axes
    ],
)
def test_delta_gate_kernel_matches_ref(r, d, block_r):
    rng = np.random.default_rng(r + d)
    x_new = jnp.asarray(rng.normal(size=(r, d)).astype(np.float32))
    x_old = jnp.asarray(rng.normal(size=(r, d)).astype(np.float32))
    for thr in (0.25, 1.0, 3.0):
        got = delta_gate(x_new, x_old, thr, block_r=block_r)
        want = delta_gate_ref(x_new, x_old, thr)
        assert got.shape == (r,) and got.dtype == bool
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_delta_gate_strict_at_threshold():
    """A row whose L-inf change is EXACTLY the threshold is suppressed
    (strict >), one epsilon above propagates — in kernel and ref alike."""
    thr = 0.5
    x_old = jnp.zeros((3, 4), jnp.float32)
    above = np.nextafter(np.float32(thr), np.float32(1.0))  # f32 next-up
    x_new = jnp.asarray([[thr, 0, 0, 0],      # == thr: drop (strict >)
                         [above, 0, 0, 0],    # one ulp above: keep
                         [0.0, 0, 0, 0]], jnp.float32)  # no change: drop
    want = np.array([False, True, False])
    np.testing.assert_array_equal(
        np.asarray(delta_gate(x_new, x_old, thr, block_r=2)), want)
    np.testing.assert_array_equal(
        np.asarray(delta_gate_ref(x_new, x_old, thr)), want)


# ------------------------------------------------------------------ engine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
    base = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=16)
    return cfg, params, base


def _engine(setup, **kw):
    cfg, _, base = setup
    kw.setdefault("edit_capacity", 4)
    kw.setdefault("row_capacity", 16)
    return JitIncrementalEngine({}, cfg, _weights=base.weights, **kw)


def _ragged_start(cfg, engine, rng, n=20, n_cap=24):
    tokens = np.zeros(n_cap, np.int32)
    tokens[:n] = rng.integers(0, cfg.vocab, n)
    valid = np.zeros(n_cap, bool)
    valid[:n] = True
    valid[5] = False  # interior hole
    positions = np.full(n_cap, cfg.pos_pool - 1, np.int32)
    positions[:n] = np.arange(n) * 7
    return engine.full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                               jnp.asarray(valid))


def _mixed_bucket(positions_of):
    from repro.serving.jit_engine import OP_DELETE, OP_INSERT, OP_REPLACE

    slot = jnp.asarray([3, 8, 21, -1], jnp.int32)
    tok = jnp.asarray([7, 0, 11, 0], jnp.int32)
    pos = jnp.asarray([positions_of(3), 0, 40, 0], jnp.int32)
    op = jnp.asarray([OP_REPLACE, OP_DELETE, OP_INSERT, 0], jnp.int32)
    return slot, tok, pos, op


@pytest.mark.parametrize("fused", [False, True])
def test_threshold_zero_engine_bitwise(setup, fused):
    """delta_threshold=0.0 is bitwise-identical to the default engine —
    every state leaf, every overflow flag, on a mixed typed bucket —
    because the Python-level guard leaves the traced program untouched."""
    cfg, params, base = setup
    rng = np.random.default_rng(0)
    ref = _engine(setup, use_fused_kernel=fused)
    zed = _engine(setup, use_fused_kernel=fused, delta_threshold=0.0)
    sr = _ragged_start(cfg, ref, rng)
    sz = _ragged_start(cfg, zed, rng2 := np.random.default_rng(0))
    del rng2
    slot, tok, pos, op = _mixed_bucket(lambda i: int(sr.positions[i]))
    nr, ovr = ref.apply_edits(sr, slot, tok, pos, op)
    nz, ovz = zed.apply_edits(sz, slot, tok, pos, op)
    assert bool(ovr) == bool(ovz)
    for name, a, b in zip(nr._fields, nr, nz):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_threshold_rejects_negative(setup):
    with pytest.raises(ValueError, match="delta_threshold"):
        _engine(setup, delta_threshold=-0.1)


def test_infinite_threshold_freezes_downstream_only(setup):
    """At an unreachable threshold an edit still lands at layer 0 (token,
    embedding row, quantizer state) but NOTHING propagates: x[1:] is
    bitwise-frozen. This is the pure sigma-delta limit — and the sharpest
    proof the gate withholds transmission without stalling the quantizer."""
    cfg, params, base = setup
    rng = np.random.default_rng(1)
    eng = _engine(setup, delta_threshold=1e9)
    s = _ragged_start(cfg, eng, rng)
    pad = jnp.asarray([-1, -1, -1], jnp.int32)
    ns, ovf = eng.apply_replaces(
        s, jnp.concatenate([jnp.asarray([2], jnp.int32), pad]),
        jnp.asarray([5, 0, 0, 0], jnp.int32))
    assert not bool(ovf)
    assert int(ns.tokens[2]) == 5
    assert np.any(np.asarray(ns.x[0][2]) != np.asarray(s.x[0][2]))
    np.testing.assert_array_equal(np.asarray(ns.x[1:]), np.asarray(s.x[1:]))
    # the edited row's layer-0 quantizer state advanced regardless
    moved = (np.any(np.asarray(ns.codes[0][:, 2]) != np.asarray(s.codes[0][:, 2]))
             or np.any(np.asarray(ns.T[0][2]) != np.asarray(s.T[0][2])))
    assert moved


def test_overflow_is_pre_gate(setup):
    """Overflow is detected on the PRE-gate changed set: a bucket that
    overflows the row capacity under the exact engine must still flag
    overflow under ANY threshold — thresholding never masks the bit the
    server's full-forward fallback depends on."""
    cfg, params, base = setup
    rng = np.random.default_rng(2)
    ref = _engine(setup, row_capacity=2)
    thr = _engine(setup, row_capacity=2, delta_threshold=1e9)
    sr = _ragged_start(cfg, ref, rng)
    st = _ragged_start(cfg, thr, np.random.default_rng(2))
    bucket = (jnp.asarray([1, 3, 9, 12], jnp.int32),
              jnp.asarray([5, 6, 7, 8], jnp.int32))
    _, ovr = ref.apply_replaces(sr, *bucket)
    _, ovt = thr.apply_replaces(st, *bucket)
    assert bool(ovr), "fixture should overflow R=2 under the exact engine"
    assert bool(ovt) == bool(ovr)


def test_thresholded_fused_matches_inline(setup):
    """At a lossy threshold the fused and inline paths agree on WHICH rows
    propagate (L-inf/abs/> are order-insensitive, so the keep booleans are
    bitwise-equal) — codes exact, activations float-close."""
    cfg, params, base = setup
    rng = np.random.default_rng(3)
    inline = _engine(setup, delta_threshold=2.0)
    fused = _engine(setup, use_fused_kernel=True, delta_threshold=2.0)
    si = _ragged_start(cfg, inline, rng)
    sf = _ragged_start(cfg, fused, np.random.default_rng(3))
    slot, tok, pos, op = _mixed_bucket(lambda i: int(si.positions[i]))
    for _ in range(3):
        si, ovi = inline.apply_edits(si, slot, tok, pos, op)
        sf, ovf = fused.apply_edits(sf, slot, tok, pos, op)
        assert bool(ovi) == bool(ovf)
    np.testing.assert_array_equal(np.asarray(si.tokens), np.asarray(sf.tokens))
    np.testing.assert_array_equal(np.asarray(si.valid), np.asarray(sf.valid))
    np.testing.assert_array_equal(np.asarray(si.codes), np.asarray(sf.codes))
    np.testing.assert_allclose(np.asarray(si.x), np.asarray(sf.x), atol=3e-4)
    np.testing.assert_allclose(np.asarray(si.T), np.asarray(sf.T), atol=3e-4)


# ------------------------------------------------------------------ server


def _mk_server(cfg, params, **kw):
    base = dict(edit_capacity=4, row_capacity=16, max_batch=2,
                min_doc_capacity=8, pos_pool=256)
    base.update(kw)
    return BatchServer(params, cfg, **base)


def _drive_pair(cfg, servers, n_edits, seed):
    """Drive identical mixed streams into every server, mirroring each
    edit into plain-Python reference lists (the NumPy-free oracle).
    Front-biased inserts + tiny pos_pool force grow AND defrag."""
    rng = np.random.default_rng(seed)
    refs = {did: [int(t) for t in servers[0].tokens(did)]
            for did in sorted(servers[0].docs)}
    for _ in range(n_edits):
        did = sorted(refs)[int(rng.integers(len(refs)))]
        r = refs[did]
        u = rng.random()
        if u < 0.55 or len(r) < 3:
            pos = int(rng.integers(min(len(r) + 1, 2)))  # front-biased
            tokv = int(rng.integers(1, cfg.vocab))
            r.insert(pos, tokv)
            for srv in servers:
                srv.submit_insert(did, pos, tokv)
        elif u < 0.8:
            pos = int(rng.integers(len(r)))
            tokv = int(rng.integers(1, cfg.vocab))
            r[pos] = tokv
            for srv in servers:
                srv.submit_replace(did, pos, tokv)
        else:
            pos = int(rng.integers(len(r)))
            del r[pos]
            for srv in servers:
                srv.submit_delete(did, pos)
        for srv in servers:
            srv.flush()
    return refs


@pytest.fixture(scope="module")
def server_setup():
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
    return cfg, params


def test_threshold_zero_server_bitwise(server_setup):
    """End-to-end differential: a delta_threshold=0.0 server serves a
    grow/defrag-forcing mixed stream bitwise-identically to the default
    server (tokens AND logits), and token-exactly vs the list oracle."""
    cfg, params = server_setup
    docs = {"a": [5, 9, 2, 7, 1, 3], "b": [4, 4, 8, 1, 2, 6]}
    ref = _mk_server(cfg, params)
    zed = _mk_server(cfg, params, delta_threshold=0.0)
    for srv in (ref, zed):
        srv.open_documents({k: list(v) for k, v in docs.items()})
    refs = _drive_pair(cfg, (ref, zed), n_edits=24, seed=7)
    assert ref.stats.device_grows >= 1 or ref.stats.device_defrags >= 1
    for did in docs:
        assert list(zed.tokens(did)) == refs[did]
        np.testing.assert_array_equal(ref.tokens(did), zed.tokens(did))
        np.testing.assert_array_equal(np.asarray(ref.logits(did)),
                                      np.asarray(zed.logits(did)))


def test_lossy_server_tokens_exact_logits_drift_bounded(server_setup):
    """At a lossy threshold the DOCUMENT is still served token-exactly
    (edits land in the host mirrors and layer-0 state unconditionally);
    only the logits drift, and boundedly so."""
    cfg, params = server_setup
    docs = {"a": [5, 9, 2, 7, 1, 3, 8, 2]}
    ref = _mk_server(cfg, params)
    lossy = _mk_server(cfg, params, delta_threshold=2.0)
    for srv in (ref, lossy):
        srv.open_documents({k: list(v) for k, v in docs.items()})
    refs = _drive_pair(cfg, (ref, lossy), n_edits=16, seed=11)
    assert list(lossy.tokens("a")) == refs["a"]
    drift = float(np.max(np.abs(np.asarray(lossy.logits("a"))
                                - np.asarray(ref.logits("a")))))
    assert np.isfinite(drift)


def test_lossy_server_suggestions_match_oracle(server_setup):
    """Suggestions are oracle-TOKEN-exact at a lossy threshold: suppressed
    rows never sit before the suggestion watermark (causal mask ⇒ every
    changed-or-suppressed row has pos >= the earliest edited pid), and the
    refresh re-prefills all rows at/after the boundary through exact
    transformer math — the engine's drift never reaches the decode."""
    cfg, params = server_setup
    n_new = 4
    srv = _mk_server(cfg, params, delta_threshold=2.0, min_doc_capacity=16)
    srv.open_document("d", [3, 1, 4, 1, 5, 9, 2, 6])
    oracle_eng = JitIncrementalEngine(params, cfg, edit_capacity=4,
                                      row_capacity=16)
    oracle_sugg = SuggestionEngine(params, cfg)
    rng = np.random.default_rng(13)
    for i in range(6):
        n = srv.docs["d"].n_virtual
        if i % 2 == 0:
            srv.submit_replace("d", int(rng.integers(n)),
                               int(rng.integers(1, cfg.vocab)))
        else:
            srv.submit_insert("d", int(rng.integers(n + 1)),
                              int(rng.integers(1, cfg.vocab)))
        srv.flush()
        got = srv.suggest("d", n_new=n_new)
        doc = srv.docs["d"]
        want = oracle_suggestion(params, cfg, oracle_eng, doc.tokens,
                                 doc.positions, doc.valid, n_new,
                                 suggester=oracle_sugg)
        np.testing.assert_array_equal(got, want, err_msg=f"edit {i}")
