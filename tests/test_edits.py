"""Edit-script properties (paper §3.3 / §4 alignment)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.edits import apply_edits, edit_script, random_revision


@settings(max_examples=50, deadline=None)
@given(
    old=st.lists(st.integers(0, 9), min_size=1, max_size=40),
    new=st.lists(st.integers(0, 9), min_size=1, max_size=40),
)
def test_edit_script_roundtrip(old, new):
    """apply_edits(old, edit_script(old, new)) == new, for arbitrary pairs."""
    script = edit_script(old, new)
    assert apply_edits(old, script) == list(new)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), frac=st.sampled_from([0.01, 0.05, 0.2]))
def test_random_revision_edit_fraction(seed, frac):
    rng = np.random.default_rng(seed)
    old = list(rng.integers(0, 100, 200))
    new = random_revision(rng, old, 100, frac)
    script = edit_script(old, new)
    # the revision generator applies ~frac*n atomic edits; alignment can only
    # find fewer-or-equal
    assert 0 < len(script) <= max(3, int(3 * frac * len(old)) + 8)
