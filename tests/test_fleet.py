"""Fleet serving (ISSUE 10): router, replica workers, shared cold tier.

The exactness ladder for ``serving.fleet``:

1. cross-replica **migration** is invisible — a migrated document's logits
   are bitwise-equal and its suggestions token-exact vs a never-migrated
   in-process oracle, through forced slot-buffer grows and defrags both
   before and after the move;
2. **failover** — documents of a hard-killed replica resume token-exact on
   the survivors: acked edits are already in the recovery target, and the
   client replays exactly the tickets that failed (a per-document suffix),
   never double-applying;
3. the router's **aggregated stats reconcile** with the sum of replica
   stats and with the client-side acked-work count;
4. ``close_fleet`` is **leak-free** — no surviving subprocess, no cold
   files, no leases (looped, with a residual checkpoint snapshot to clean);
5. fast unit layers: lease mutual exclusion, RPC framing, and the
   crash-safe cold-tier write (an interrupted spill never leaves a
   truncated archive visible — satellite of ISSUE 10).

Process tests are ``slow`` (each fleet pays subprocess jax boots); CI's
bench-gate covers the same contract via ``benchmarks.fleet_load``.
"""
import io
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.store import atomic_savez
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer
from repro.serving.fleet import FleetRouter, RemoteOpError, ReplicaDiedError
from repro.serving.fleet import cold_tier
from repro.serving.fleet.protocol import ProtocolError, recv_msg, send_msg

WAIT = 600.0
N_NEW = 4
# tiny capacity + position pool: insert streams force grows AND defrags
SERVER_KW = {"edit_capacity": 4, "row_capacity": 16, "max_batch": 4,
             "min_doc_capacity": 8, "pos_pool": 64}


# ------------------------------------------------------------- fast: leases


def test_lease_protocol(tmp_path):
    cold = str(tmp_path)
    cold_tier.acquire_lease(cold, "doc", "r0")
    assert cold_tier.lease_owner(cold, "doc") == "r0"
    cold_tier.acquire_lease(cold, "doc", "r0")  # idempotent re-acquire
    with pytest.raises(cold_tier.LeaseHeldError):
        cold_tier.acquire_lease(cold, "doc", "r1")
    with pytest.raises(cold_tier.LeaseHeldError):
        cold_tier.release_lease(cold, "doc", "r1")
    cold_tier.release_lease(cold, "doc", "r0")
    assert cold_tier.lease_owner(cold, "doc") is None
    cold_tier.release_lease(cold, "doc", "r0")  # missing lease: no-op
    cold_tier.acquire_lease(cold, "doc", "r1")
    cold_tier.break_lease(cold, "doc")  # router's failover prerogative
    assert cold_tier.lease_owner(cold, "doc") is None


def test_cold_path_names(tmp_path):
    a = cold_tier.cold_path_for(str(tmp_path), "weird/../doc id!")
    b = cold_tier.cold_path_for(str(tmp_path), "weird/../doc id?")
    assert a != b  # sanitized names stay distinct via the digest suffix
    assert os.path.dirname(a) == str(tmp_path)
    assert "/.." not in os.path.basename(a) and " " not in os.path.basename(a)
    assert a == cold_tier.cold_path_for(str(tmp_path), "weird/../doc id!")


# -------------------------------------------------------- fast: RPC framing


def test_protocol_framing_roundtrip():
    buf = io.BytesIO()
    msgs = [{"id": 1, "ops": [{"op": "ping"}]},
            {"arr": np.arange(5), "s": "x"}]
    for m in msgs:
        send_msg(buf, m)
    buf.seek(0)
    got = [recv_msg(buf), recv_msg(buf)]
    assert got[0] == msgs[0]
    np.testing.assert_array_equal(got[1]["arr"], msgs[1]["arr"])
    with pytest.raises(EOFError):
        recv_msg(buf)  # clean EOF at a frame boundary
    half = io.BytesIO(b"\x00\x00")
    with pytest.raises(EOFError):
        recv_msg(half)  # pipe died mid-header
    bogus = io.BytesIO(b"\xff\xff\xff\xff")
    with pytest.raises(ProtocolError):
        recv_msg(bogus)  # absurd length = corrupted framing


# ---------------------------------------- fast: crash-safe cold-tier writes


def test_interrupted_spill_never_visible(tmp_path, monkeypatch):
    """A spill that dies mid-write (the satellite regression): the
    destination keeps the previous complete archive and no temp garbage
    survives — a reader can never observe a truncated npz."""
    path = str(tmp_path / "doc.state.npz")
    atomic_savez(path, {"a": np.arange(4)})
    np.testing.assert_array_equal(np.load(path)["a"], np.arange(4))

    real_savez = np.savez

    def dying_savez(fp, **arrays):
        fp.write(b"PK\x03\x04 truncated")  # partial zip magic, then crash
        raise RuntimeError("simulated crash mid-spill")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(RuntimeError, match="simulated crash"):
        atomic_savez(path, {"a": np.arange(9)})
    monkeypatch.setattr(np, "savez", real_savez)

    # old snapshot intact, no *.tmp* orphans left behind
    np.testing.assert_array_equal(np.load(path)["a"], np.arange(4))
    assert [f for f in os.listdir(tmp_path) if ".tmp" in f] == []


# -------------------------------------------------------- process fixtures


@pytest.fixture(scope="module")
def oracle():
    cfg = get_config("vq-opt-125m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)  # == worker seed 0
    return cfg, BatchServer(params, cfg, **SERVER_KW)


@pytest.fixture(scope="module")
def fleet2(tmp_path_factory):
    cold = str(tmp_path_factory.mktemp("fleet-cold"))
    fleet = FleetRouter(2, cold_dir=cold, server_kwargs=SERVER_KW,
                        max_batch_delay_ms=3.0, seed=0)
    yield fleet
    fleet.close_fleet()


# ------------------------------------------------------------ slow: migration


@pytest.mark.slow
def test_migration_bitwise_exact(fleet2, oracle):
    """Forced grow/defrag, migrate, forced grow/defrag again: logits stay
    bitwise-equal and suggestions token-exact vs the never-migrated oracle
    (the DESIGN.md §11 acceptance criterion, in-process edition)."""
    cfg, srv = oracle
    rng = np.random.default_rng(3)
    ref = [int(t) for t in rng.integers(0, cfg.vocab, 7)]
    fleet2.open_document("mig", ref).result(WAIT)
    srv.open_document("mig", ref)
    src = fleet2.owner_of("mig")

    def insert_burst(n):
        for _ in range(n):
            tok = int(rng.integers(cfg.vocab))
            fleet2.submit_insert("mig", 3, tok).result(WAIT)
            srv.submit_insert("mig", 3, tok)
            ref.insert(3, tok)
        srv.flush()  # the oracle's logits/tokens refuse unflushed queues

    insert_burst(10)  # blows past min capacity 8 and chews the 64-id pool
    np.testing.assert_array_equal(fleet2.suggest("mig", N_NEW).result(WAIT),
                                  srv.suggest("mig", N_NEW))

    fleet2.migrate("mig", (src + 1) % 2)
    assert fleet2.owner_of("mig") == (src + 1) % 2
    np.testing.assert_array_equal(fleet2.logits("mig").result(WAIT),
                                  np.asarray(srv.logits("mig")))

    insert_burst(8)  # re-ingest paths again, now on the adopting replica
    np.testing.assert_array_equal(fleet2.logits("mig").result(WAIT),
                                  np.asarray(srv.logits("mig")))
    np.testing.assert_array_equal(fleet2.suggest("mig", N_NEW).result(WAIT),
                                  srv.suggest("mig", N_NEW))
    assert list(fleet2.tokens("mig").result(WAIT)) == ref

    agg = fleet2.stats(WAIT)
    assert agg["exports"] >= 1 and agg["imports"] >= 1
    assert agg["router"]["migrations"] >= 1
    per = agg["per_replica"]
    assert sum(s["batch"]["grows"] for s in per) >= 1
    assert sum(s["batch"]["defrags"] for s in per) >= 1


@pytest.mark.slow
def test_stats_reconcile(fleet2, oracle):
    """Fleet aggregation == sum of replica stats == client-side acked work."""
    cfg, _ = oracle
    before = fleet2.stats(WAIT)
    rng = np.random.default_rng(5)
    docs = ["s0", "s1"]
    for d in docs:
        fleet2.open_document(
            d, [int(t) for t in rng.integers(0, cfg.vocab, 10)]).result(WAIT)
    assert fleet2.owner_of("s0") != fleet2.owner_of("s1")  # load spreads
    n_edits = 6
    tickets = [fleet2.submit_replace(d, i % 10, int(rng.integers(cfg.vocab)))
               for i in range(n_edits // 2) for d in docs]
    acked = sum(1 for t in tickets if t.result(WAIT) is not None or True)
    agg = fleet2.stats(WAIT)
    per = agg["per_replica"]
    for field in ("edits_applied", "hot_hits", "state_touches", "exports",
                  "imports"):
        assert agg[field] == sum(s["batch"][field] for s in per)
    assert agg["rounds"] == sum(s["async"]["rounds"] for s in per)
    assert agg["edits_applied"] - before["edits_applied"] == acked == n_edits
    assert agg["docs_open"] == len(fleet2._route)
    assert (agg["router"]["docs_opened"] - agg["router"]["docs_closed"]
            == agg["docs_open"])
    assert 0.0 <= agg["hot_hit_rate"] <= 1.0
    merged = agg["edit_latency"]
    assert merged["count"] == sum(
        s["batch"]["edit_latency"]["count"] for s in per)
    for d in docs:
        fleet2.close_document(d).result(WAIT)


# ------------------------------------------------------------ slow: failover


@pytest.mark.slow
def test_failover_resume_token_exact(tmp_path):
    """Kill a replica with acked, checkpointed AND in-flight edits: its
    documents fail over to the survivor, the client replays exactly the
    failed tickets, and every document's tokens stay exact."""
    cfg = get_config("vq-opt-125m", smoke=True)
    rng = np.random.default_rng(7)
    fleet = FleetRouter(2, cold_dir=str(tmp_path / "cold"),
                        server_kwargs=SERVER_KW, max_batch_delay_ms=3.0)
    try:
        refs = {d: [int(t) for t in rng.integers(0, cfg.vocab, 10)]
                for d in ("f0", "f1")}
        for d, ref in refs.items():
            fleet.open_document(d, ref).result(WAIT)
        victim = fleet.owner_of("f0")
        survivor = 1 - victim
        assert fleet.owner_of("f1") == survivor

        for i in range(3):  # acked work, then a fleet-wide snapshot
            for d in refs:
                tok = int(rng.integers(cfg.vocab))
                fleet.submit_replace(d, i, tok).result(WAIT)
                refs[d][i] = tok
        fleet.checkpoint(WAIT)

        # in-flight edits racing the kill: each either acks (already in the
        # recovery target) or fails (client replays it) — never both
        inflight = []
        for i in range(3):
            tok = int(rng.integers(cfg.vocab))
            inflight.append(((i, tok), fleet.submit_replace("f0", i, tok)))
        fleet.kill_replica(victim)
        assert fleet.stats_fleet.failovers == 1
        assert fleet.owner_of("f0") == survivor
        for (pos, tok), t in inflight:
            try:
                t.result(WAIT)
            except (ReplicaDiedError, RemoteOpError):
                fleet.submit_replace("f0", pos, tok).result(WAIT)
            refs["f0"][pos] = tok

        for d in refs:  # both documents keep serving on the survivor
            tok = int(rng.integers(cfg.vocab))
            fleet.submit_insert(d, 2, tok).result(WAIT)
            refs[d].insert(2, tok)
            assert list(fleet.tokens(d).result(WAIT)) == refs[d]
        assert len(fleet.suggest("f0", N_NEW).result(WAIT)) == N_NEW
        # the dead replica's lease was broken, the survivor's acquired
        assert cold_tier.lease_owner(fleet.cold_dir, "f0") == f"r{survivor}"
    finally:
        fleet.close_fleet()
    assert all(r.proc.poll() is not None for r in fleet.replicas)


# ----------------------------------------------------------- slow: leak loop


@pytest.mark.slow
def test_close_fleet_leak_loop(tmp_path):
    """Repeated fleet lifecycles leave nothing behind: no subprocess, no
    cold-tier document files, no leases — even when a checkpoint parked a
    residual snapshot in the shared directory before the close."""
    cfg = get_config("vq-opt-125m", smoke=True)
    cold = str(tmp_path / "cold")
    for it in range(2):
        fleet = FleetRouter(1, cold_dir=cold, server_kwargs=SERVER_KW,
                            max_batch_delay_ms=3.0)
        try:
            fleet.open_document("d", list(range(8))).result(WAIT)
            fleet.submit_insert("d", 0, 5).result(WAIT)
            assert len(fleet.suggest("d", N_NEW).result(WAIT)) == N_NEW
            if it == 1:
                fleet.checkpoint(WAIT)  # close must clean this snapshot up
                assert os.listdir(cold)
        finally:
            fleet.close_fleet()
        assert all(r.proc.poll() is not None for r in fleet.replicas)
        assert os.listdir(cold) == [], f"cold leftovers on iteration {it}"
    assert cfg.vocab > 0
