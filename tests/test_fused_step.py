"""The fused ragged hot path (ISSUE 7): one-launch edit step + device-side
state surgery.

Three rungs of the differential ladder:

* **kernel** — ``fused_patch_assign`` vs the unfused reference chain
  (``incr_patch_ref`` + inline requantize) on odd/non-pow2 shapes,
  all-masked rows and documents, batched grids, and under the engine's
  jit(vmap(...)) route;
* **engine** — ``use_fused_kernel=True`` vs the inline-einsum engine:
  identical codes, float-close activations, identical overflow flags, on
  mixed typed buckets including merged-bucket ragged documents (same
  ``n_cap``, very different ``n_real``);
* **server** — device-side grow (``pad_state``) and defrag
  (``gather_slots`` + re-spread + the SAME ``full_forward``) vs the host
  re-ingest slow paths: defrag is BITWISE-equal by construction, grow is
  history-preserving (token-exact streams, close logits), and the
  failed-dispatch rollback ladder still holds with the device paths on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.kernels.fused_step import (
    fused_patch_assign, fused_patch_assign_batched, fused_patch_assign_ref,
)
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer, _device_copy
from repro.serving.jit_engine import JitIncrementalEngine


def _inputs(n, H, dh, C, Q, hq, seed=0, mask_p=0.6, batch=None):
    shape = (lambda *s: ((batch,) + s) if batch else s)
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    q = jax.random.normal(ks[0], shape(n, H, dh))
    k_new = jax.random.normal(ks[1], shape(H, C, dh))
    k_old = jax.random.normal(ks[2], shape(H, C, dh))
    vc_new = jax.random.normal(ks[3], shape(H, C, Q))
    vc_old = jax.random.normal(ks[4], shape(H, C, Q))
    mask = jax.random.bernoulli(ks[5], mask_p, shape(n, C)).astype(jnp.float32)
    T_base = jax.random.normal(ks[6], shape(n, H, Q))
    counts = jnp.maximum(
        jax.random.randint(ks[7], shape(n), 1, n + 1), 1).astype(jnp.float32)
    vq_bias = jax.random.normal(ks[0], (hq, Q))  # shared across the batch
    return q, k_new, k_old, vc_new, vc_old, mask, T_base, counts, vq_bias


# ------------------------------------------------------------------ kernel


@pytest.mark.parametrize(
    "n,H,dh,C,Q,hq,block_r",
    [
        (64, 4, 64, 8, 64, 2, 32),     # pow2 everything
        (13, 4, 8, 5, 16, 2, 8),       # odd rows/columns, tiny dims
        (100, 6, 16, 7, 48, 3, 128),   # non-pow2, block_r > n (one block)
        (7, 2, 4, 3, 8, 1, 4),         # hq=1 (every head in one vq group)
    ],
)
def test_fused_kernel_matches_ref(n, H, dh, C, Q, hq, block_r):
    args = _inputs(n, H, dh, C, Q, hq, seed=n + C)
    T_all, codes = fused_patch_assign(*args, heads_per_vq=H // hq,
                                      block_r=block_r)
    T_ref, codes_ref = fused_patch_assign_ref(*args)
    assert T_all.shape == (n, H, Q) and codes.shape == (n, hq)
    np.testing.assert_allclose(np.asarray(T_all), np.asarray(T_ref),
                               atol=2e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_ref))


def test_fused_kernel_all_masked_rows_keep_T_base():
    """A fully-masked row receives an exactly-zero patch: its T output is
    bitwise T_base and its code is the plain requantize of T_base — the
    contract that lets the engine exclude dirty rows (and free slots)
    through the mask alone."""
    n, H, dh, C, Q, hq = 11, 4, 8, 4, 16, 2
    args = list(_inputs(n, H, dh, C, Q, hq, seed=3))
    mask = np.array(args[5], copy=True)
    mask[2] = 0.0
    mask[7] = 0.0
    args[5] = jnp.asarray(mask)
    T_all, codes = fused_patch_assign(*args, heads_per_vq=H // hq, block_r=8)
    T_ref, codes_ref = fused_patch_assign_ref(*args)
    for r in (2, 7):
        np.testing.assert_array_equal(np.asarray(T_all[r]),
                                      np.asarray(args[6][r], np.float32))
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_ref))


@pytest.mark.parametrize("B,n,H,dh,C,Q,hq", [(2, 64, 4, 16, 8, 32, 2),
                                             (3, 9, 2, 8, 3, 16, 1)])
def test_fused_kernel_batched_matches_per_doc(B, n, H, dh, C, Q, hq):
    args = _inputs(n, H, dh, C, Q, hq, seed=B * n, batch=B)
    T_all, codes = fused_patch_assign_batched(*args, heads_per_vq=H // hq,
                                              block_r=8)
    assert T_all.shape == (B, n, H, Q) and codes.shape == (B, n, hq)
    for b in range(B):
        per = [a[b] for a in args[:-1]] + [args[-1]]  # vq_bias is shared
        T_b, codes_b = fused_patch_assign(*per, heads_per_vq=H // hq,
                                          block_r=8)
        np.testing.assert_allclose(np.asarray(T_all[b]), np.asarray(T_b),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(codes[b]),
                                      np.asarray(codes_b))


def test_fused_kernel_all_masked_document_in_batch():
    """A document whose whole mask is zero (a filler row in a padded
    dispatch) must keep T_base everywhere — no cross-document leakage
    through the batched grid."""
    B, n, H, dh, C, Q, hq = 3, 8, 2, 8, 4, 16, 2
    args = list(_inputs(n, H, dh, C, Q, hq, seed=9, batch=B))
    mask = np.array(args[5], copy=True)
    mask[1] = 0.0
    args[5] = jnp.asarray(mask)
    T_all, _ = fused_patch_assign_batched(*args, heads_per_vq=H // hq,
                                          block_r=8)
    np.testing.assert_array_equal(np.asarray(T_all[1]),
                                  np.asarray(args[6][1], np.float32))


def test_fused_kernel_vmap_matches_batched():
    """jit(vmap(unbatched)) — the engine's route into the batched grid via
    the pallas batching rule — equals the hand-written batched entry."""
    B, n, H, dh, C, Q, hq = 2, 16, 4, 8, 4, 16, 2
    args = _inputs(n, H, dh, C, Q, hq, seed=4, batch=B)

    def one(q, kn, ko, vn, vo, m, tb, c):
        return fused_patch_assign(q, kn, ko, vn, vo, m, tb, c, args[-1],
                                  heads_per_vq=H // hq, block_r=8)

    T_v, codes_v = jax.jit(jax.vmap(one))(*args[:-1])
    T_b, codes_b = fused_patch_assign_batched(*args, heads_per_vq=H // hq,
                                              block_r=8)
    np.testing.assert_allclose(np.asarray(T_v), np.asarray(T_b),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(codes_v), np.asarray(codes_b))


# ------------------------------------------------------------------ engine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    fused = JitIncrementalEngine(params, cfg, edit_capacity=4,
                                 row_capacity=16, use_fused_kernel=True)
    inline = JitIncrementalEngine({}, cfg, edit_capacity=4, row_capacity=16,
                                  use_fused_kernel=False,
                                  _weights=fused.weights)
    return cfg, params, fused, inline


def _assert_states_close(a, b, atol=3e-4):
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    np.testing.assert_array_equal(np.asarray(a.valid), np.asarray(b.valid))
    np.testing.assert_array_equal(np.asarray(a.codes), np.asarray(b.codes))
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x), atol=atol)
    np.testing.assert_allclose(np.asarray(a.T), np.asarray(b.T), atol=atol)


def test_engine_fused_matches_inline_mixed_bucket(setup):
    """One typed bucket of each kind on a ragged document (invalid tail +
    interior hole): fused and inline engines agree — codes exactly,
    activations float-close, overflow bit-for-bit."""
    cfg, params, fused, inline = setup
    rng = np.random.default_rng(0)
    n, n_cap = 20, 24
    tokens = np.zeros(n_cap, np.int32)
    tokens[:n] = rng.integers(0, cfg.vocab, n)
    valid = np.zeros(n_cap, bool)
    valid[:n] = True
    valid[5] = False  # interior hole (deleted slot)
    positions = np.full(n_cap, cfg.pos_pool - 1, np.int32)
    positions[:n] = np.arange(n) * 7
    sf = fused.full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                            jnp.asarray(valid))
    si = inline.full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                             jnp.asarray(valid))
    _assert_states_close(sf, si)
    from repro.serving.jit_engine import OP_DELETE, OP_INSERT, OP_REPLACE

    slot = jnp.asarray([3, 8, 21, -1], jnp.int32)   # 21 = free-slot insert
    tok = jnp.asarray([7, 0, 11, 0], jnp.int32)
    pos = jnp.asarray([positions[3], 0, 40, 0], jnp.int32)
    op = jnp.asarray([OP_REPLACE, OP_DELETE, OP_INSERT, 0], jnp.int32)
    nf, of = fused.apply_edits(sf, slot, tok, pos, op)
    ni, oi = inline.apply_edits(si, slot, tok, pos, op)
    assert bool(of) == bool(oi)
    _assert_states_close(nf, ni)


def test_engine_fused_matches_inline_merged_bucket_ragged(setup):
    """Two documents sharing one capacity class with very different real
    lengths (the ragged merged-bucket case): the batched fused step matches
    the batched inline step slice-for-slice."""
    cfg, params, fused, inline = setup
    from repro.serving.batch_engine import BatchedJitEngine, unstack_state

    bf = BatchedJitEngine({}, cfg, edit_capacity=4, row_capacity=16,
                          use_fused_kernel=True, _weights=fused.weights)
    bi = BatchedJitEngine({}, cfg, edit_capacity=4, row_capacity=16,
                          use_fused_kernel=False, _weights=fused.weights)
    rng = np.random.default_rng(1)
    n_cap, n_reals = 32, (29, 4)  # same class, very different occupancy
    tokens = np.zeros((2, n_cap), np.int32)
    valid = np.zeros((2, n_cap), bool)
    positions = np.full((2, n_cap), cfg.pos_pool - 1, np.int32)
    for b, nr in enumerate(n_reals):
        tokens[b, :nr] = rng.integers(0, cfg.vocab, nr)
        valid[b, :nr] = True
        positions[b, :nr] = np.arange(nr) * 5
    sf = bf.batch_full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                               jnp.asarray(valid))
    si = bi.batch_full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                               jnp.asarray(valid))
    slot = jnp.asarray([[2, 28, -1, -1], [1, -1, -1, -1]], jnp.int32)
    tok = jnp.asarray([[9, 4, 0, 0], [3, 0, 0, 0]], jnp.int32)
    nf, of = bf.batch_apply_replaces(sf, slot, tok)
    ni, oi = bi.batch_apply_replaces(si, slot, tok)
    np.testing.assert_array_equal(np.asarray(of), np.asarray(oi))
    for b in range(2):
        _assert_states_close(unstack_state(nf, b), unstack_state(ni, b))


# ------------------------------------------------------------------ server


def _mk_server(cfg, params, **kw):
    base = dict(edit_capacity=4, row_capacity=16, max_batch=2,
                min_doc_capacity=8, pos_pool=256)
    base.update(kw)
    return BatchServer(params, cfg, **base)


def _drive(srv, n_edits, seed=3, insert_p=0.7):
    """Insert-heavy stream; inserts cluster at the front so the SAME
    position-id gap keeps splitting — deterministic defrag pressure."""
    rng = np.random.default_rng(seed)
    for _ in range(n_edits):
        did = sorted(srv.docs)[int(rng.integers(len(srv.docs)))]
        n = srv.docs[did].n_virtual
        if rng.random() < insert_p:
            srv.submit_insert(did, int(rng.integers(min(n + 1, 2))),
                              int(rng.integers(1, srv.cfg.vocab)))
        elif n > 2:
            srv.submit_delete(did, int(rng.integers(n)))
        srv.flush()


@pytest.fixture(scope="module")
def server_setup():
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
    return cfg, params


def test_device_defrag_bitwise_vs_reingest_oracle(server_setup):
    """Device defrag (gather_slots + host re-spread + full_forward) must be
    BITWISE-equal to re-ingesting from identically-compacted host mirrors:
    both feed the same compiled function the same values, so this holds by
    construction — and this test keeps it held."""
    cfg, params = server_setup
    srv = _mk_server(cfg, params)
    rng = np.random.default_rng(2)
    srv.open_documents({"a": list(rng.integers(1, cfg.vocab, 6))})
    _drive(srv, 20, seed=5)
    doc = srv.docs["a"]
    srv._defrag(doc)  # force one more device defrag right now
    assert srv.stats.device_defrags >= 1
    dev = srv.store.ensure_hot(doc)
    eng = srv.engine(srv.C, srv.R)
    oracle = eng.full_forward(_device_copy(doc.tokens),
                              _device_copy(doc.positions),
                              _device_copy(doc.valid))
    for a, b in zip(jax.tree.leaves(dev), jax.tree.leaves(oracle)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the compacted mirrors are self-consistent: slots are the identity
    assert doc.slots == list(range(doc.n))
    assert doc.touched_from is None


def test_device_paths_match_host_reingest_stream(server_setup):
    """End-to-end: an insert-heavy stream that grows AND defrags, served by
    the device paths vs the legacy host re-ingest paths — token-exact
    documents and close logits, with the device counters proving the fast
    paths actually ran."""
    cfg, params = server_setup
    docs = {"a": [5, 9, 2, 7, 1, 3], "b": [4, 4, 8, 1, 2, 6]}
    dev = _mk_server(cfg, params)
    host = _mk_server(cfg, params, use_fused_kernel=False,
                      capacity_class_step=2, device_grow=False,
                      device_defrag=False)
    for srv in (dev, host):
        srv.open_documents({k: list(v) for k, v in docs.items()})
        _drive(srv, 28, seed=7)
    assert dev.stats.device_grows >= 1
    assert dev.stats.device_defrags >= 1
    assert host.stats.device_grows == host.stats.device_defrags == 0
    for did in docs:
        np.testing.assert_array_equal(dev.tokens(did), host.tokens(did))
        np.testing.assert_allclose(np.asarray(dev.logits(did)),
                                   np.asarray(host.logits(did)), atol=3e-4)


def test_device_grow_is_pure_padding(server_setup):
    """Device grow appends invalid zero slots and NOTHING else: original
    rows are bitwise-untouched (incremental attention history survives, so
    ``touched_from`` must survive too)."""
    cfg, params = server_setup
    srv = _mk_server(cfg, params)
    rng = np.random.default_rng(4)
    srv.open_documents({"a": list(rng.integers(1, cfg.vocab, 8))})
    doc = srv.docs["a"]
    before = jax.tree.map(np.asarray, srv.store.ensure_hot(doc))
    old_cap = doc.n_cap
    doc.free.clear()  # force the next insert to grow
    srv.submit_insert("a", 0, 3)
    srv.flush()
    assert doc.n_cap == srv.padded_cap(old_cap + 1) > old_cap
    assert srv.stats.device_grows == 1 and srv.stats.full_forwards == 1
    # the state now reflects the insert; undo nothing — instead check the
    # pad itself via the engine primitive on the pre-grow snapshot
    eng = srv.engine(srv.C, srv.R)
    from repro.serving.jit_engine import JitState

    padded = eng.pad_state(JitState(*(jnp.asarray(l) for l in before)),
                           doc.n_cap, pos_fill=srv._pos_sentinel)
    for name, leaf in zip(JitState._fields, padded):
        arr = np.asarray(leaf)
        ref = getattr(before, name)
        if arr.ndim == 0:
            assert arr == ref
            continue
        slot_axis = 0 if arr.ndim == 1 else 1
        np.testing.assert_array_equal(
            np.take(arr, np.arange(old_cap), axis=slot_axis), ref, err_msg=name)
        tail = np.take(arr, np.arange(old_cap, doc.n_cap), axis=slot_axis)
        if name == "positions":
            assert (tail == srv._pos_sentinel).all()
        else:
            assert not tail.any(), name


def test_failed_dispatch_after_device_grow_rolls_back(server_setup):
    """The rollback ladder with the device paths ON: a take whose grow ran
    the device pad, followed by an injected dispatch failure, restores the
    pre-take mirrors and re-adopts the pre-take device state (epoch case 2)
    — then the retry converges to the never-failed server's exact tokens
    and logits."""
    cfg, params = server_setup
    toks = [3, 1, 4, 1, 5, 9, 2, 6]  # fills min capacity: insert => grow

    oracle = _mk_server(cfg, params)
    oracle.open_document("d", list(toks))
    oracle.submit_insert("d", 0, 7)
    oracle.flush()

    srv = _mk_server(cfg, params)
    srv.open_document("d", list(toks))
    pre_cap = srv.docs["d"].n_cap
    srv.submit_insert("d", 0, 7)
    eng = srv.engine(srv.C, srv.docs["d"].row_capacity)
    orig = eng.batch_apply_inserts
    eng.batch_apply_inserts = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("injected dispatch failure"))
    try:
        with pytest.raises(RuntimeError, match="injected"):
            srv.step()
    finally:
        eng.batch_apply_inserts = orig
    doc = srv.docs["d"]
    assert doc.n_cap == pre_cap  # the grow rolled back with the mirrors
    assert list(doc.pending) == [("insert", 0, 7)]
    np.testing.assert_array_equal(doc.seq_tokens(), toks)
    srv.flush()  # retry: grows again (device pad) and applies the edit
    assert srv.stats.device_grows >= 1
    np.testing.assert_array_equal(srv.tokens("d"), oracle.tokens("d"))
    np.testing.assert_allclose(np.asarray(srv.logits("d")),
                               np.asarray(oracle.logits("d")), atol=3e-4)
