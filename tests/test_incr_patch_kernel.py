"""Sweeps for the incremental column-patch Pallas kernel + equivalence with
the NumPy engine's patch math and the compressed-MoE dedup."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.incr_patch import incr_patch, incr_patch_batched, incr_patch_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "R,H,dh,C,Q", [(64, 4, 64, 8, 64), (100, 12, 64, 16, 128), (7, 2, 32, 8, 64)]
)
def test_incr_patch_sweep(R, H, dh, C, Q, dtype):
    ks = jax.random.split(jax.random.PRNGKey(R + C), 6)
    q = jax.random.normal(ks[0], (R, H, dh), dtype)
    k_new = jax.random.normal(ks[1], (H, C, dh), dtype)
    k_old = jax.random.normal(ks[2], (H, C, dh), dtype)
    vc_new = jax.random.normal(ks[3], (H, C, Q), dtype)
    vc_old = jax.random.normal(ks[4], (H, C, Q), dtype)
    mask = jax.random.bernoulli(ks[5], 0.7, (R, C))
    out = incr_patch(q, k_new, k_old, vc_new, vc_old, mask, block_r=32)
    ref = incr_patch_ref(q, k_new, k_old, vc_new, vc_old,
                         mask.astype(jnp.float32))
    atol = 0.35 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=atol,
                               rtol=0.02)


@pytest.mark.parametrize("B,R,H,dh,C,Q", [(2, 64, 4, 64, 8, 64), (3, 7, 2, 32, 8, 64)])
def test_incr_patch_batched_matches_per_doc(B, R, H, dh, C, Q):
    """The batch-grid kernel slice b == the single-doc kernel on doc b."""
    ks = jax.random.split(jax.random.PRNGKey(B * R + C), 6)
    q = jax.random.normal(ks[0], (B, R, H, dh))
    k_new = jax.random.normal(ks[1], (B, H, C, dh))
    k_old = jax.random.normal(ks[2], (B, H, C, dh))
    vc_new = jax.random.normal(ks[3], (B, H, C, Q))
    vc_old = jax.random.normal(ks[4], (B, H, C, Q))
    mask = jax.random.bernoulli(ks[5], 0.7, (B, R, C))
    out = incr_patch_batched(q, k_new, k_old, vc_new, vc_old, mask, block_r=32)
    assert out.shape == (B, R, H, Q)
    for b in range(B):
        ref = incr_patch(q[b], k_new[b], k_old[b], vc_new[b], vc_old[b],
                         mask[b], block_r=32)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize(
    "B,R,H,dh,C,Q",
    [(2, 13, 3, 24, 5, 48), (3, 9, 2, 16, 3, 40), (1, 130, 5, 48, 7, 96)],
)
def test_incr_patch_batched_matches_ref_odd_shapes(B, R, H, dh, C, Q):
    """The batch-grid kernel vs the pure-jnp oracle on non-power-of-two /
    odd row, column, head and codebook extents (the row axis is the only
    padded one — every other extent must be handled at its exact size)."""
    ks = jax.random.split(jax.random.PRNGKey(B + R + C), 6)
    q = jax.random.normal(ks[0], (B, R, H, dh))
    k_new = jax.random.normal(ks[1], (B, H, C, dh))
    k_old = jax.random.normal(ks[2], (B, H, C, dh))
    vc_new = jax.random.normal(ks[3], (B, H, C, Q))
    vc_old = jax.random.normal(ks[4], (B, H, C, Q))
    mask = jax.random.bernoulli(ks[5], 0.6, (B, R, C))
    out = incr_patch_batched(q, k_new, k_old, vc_new, vc_old, mask, block_r=8)
    assert out.shape == (B, R, H, Q)
    for b in range(B):
        ref = incr_patch_ref(q[b], k_new[b], k_old[b], vc_new[b], vc_old[b],
                             mask[b].astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_incr_patch_batched_all_masked_rows_are_zero():
    """Rows whose mask (or slot-buffer ``row_valid``) is entirely zero must
    receive an exactly-zero patch — the guarantee the slot-buffer engine
    relies on so free/deleted slots never accumulate ΔT."""
    B, R, H, dh, C, Q = 2, 11, 2, 16, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    q = jax.random.normal(ks[0], (B, R, H, dh))
    k_new = jax.random.normal(ks[1], (B, H, C, dh))
    k_old = jax.random.normal(ks[2], (B, H, C, dh))
    vc_new = jax.random.normal(ks[3], (B, H, C, Q))
    vc_old = jax.random.normal(ks[4], (B, H, C, Q))
    mask = np.array(jax.random.bernoulli(ks[5], 0.6, (B, R, C)))
    mask[0, 3] = False  # one fully-masked row
    mask[1] = False  # one fully-masked document
    out = incr_patch_batched(q, k_new, k_old, vc_new, vc_old,
                             jnp.asarray(mask), block_r=8)
    np.testing.assert_array_equal(np.asarray(out[0, 3]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    # row_valid folds into the mask identically: invalidate rows of doc 0
    row_valid = np.ones((B, R), np.float32)
    row_valid[0, ::2] = 0.0
    out_rv = incr_patch_batched(q, k_new, k_old, vc_new, vc_old,
                                jnp.asarray(mask), row_valid=jnp.asarray(row_valid),
                                block_r=8)
    np.testing.assert_array_equal(np.asarray(out_rv[0, ::2]), 0.0)
    np.testing.assert_allclose(np.asarray(out_rv[0, 1::2]),
                               np.asarray(out[0, 1::2]), atol=0, rtol=0)


def test_incr_patch_matches_engine_math():
    """The kernel computes exactly the engine's apply_replaces step-2a ΔT."""
    from repro.configs.vq_opt_125m import smoke_config
    from repro.core.incremental import IncrementalEngine, gelu
    from repro.models import transformer as T

    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = IncrementalEngine(params, cfg)
    rng = np.random.default_rng(0)
    n = 40
    tokens = rng.integers(0, cfg.vocab, n)
    positions = np.arange(n) * 3
    base = eng.full_forward(tokens, positions)

    # replace two tokens; capture the engine's ΔT for the stable rows
    D = np.array([5, 12])
    new_toks = rng.integers(0, cfg.vocab, 2)
    st0 = base.layers[0]
    later = np.setdiff1d(np.arange(5, n), D)
    old_k, old_vc = st0.k[D].copy(), st0.vc[D].copy()
    T_before = st0.T[later].copy()
    inc = eng.apply_replaces(base, list(D), list(new_toks))
    dT_engine = inc.layers[0].T[later] - T_before

    # same ΔT through the kernel (dirty-slot buffers of capacity 2)
    q_rows = jnp.asarray(base.layers[0].q[later])
    k_new = jnp.asarray(np.moveaxis(inc.layers[0].k[D], 1, 0))  # [H, C, dh]
    k_old = jnp.asarray(np.moveaxis(old_k, 1, 0))
    vc_new = jnp.asarray(np.moveaxis(inc.layers[0].vc[D], 1, 0))  # [H, C, Q]
    vc_old = jnp.asarray(np.moveaxis(old_vc, 1, 0))
    mask = jnp.asarray(D[None, :] <= later[:, None])
    dT_kernel = incr_patch(q_rows, k_new, k_old, vc_new, vc_old, mask)
    np.testing.assert_allclose(np.asarray(dT_kernel), dT_engine, atol=2e-4)


def test_moe_per_code_equals_dense():
    """Compressed-format MoE: per-unique-code compute == dense (the routing
    dedup the VQT technique enables for MoE architectures)."""
    from repro.configs import get_config
    from repro.core import compressed as CM
    from repro.models.moe import moe_apply_dense, moe_init, moe_per_code

    cfg = get_config("deepseek-v2-236b", smoke=True)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    rows = jax.random.normal(jax.random.PRNGKey(1), (6, cfg.d_model))
    idx = jax.random.randint(jax.random.PRNGKey(2), (3, 10), 0, 6)
    c = CM.from_dense_rows(rows, idx)
    y_c, aux_c = moe_per_code(params, cfg, c)
    y_dense, aux_d = moe_apply_dense(params, cfg, c.to_dense())
    np.testing.assert_allclose(
        np.asarray(y_c.to_dense()), np.asarray(y_dense), atol=2e-5, rtol=2e-5
    )
    # cost scales with unique codes (6), not batch*seq (30)
    assert y_c.codebook.shape[0] == 6
