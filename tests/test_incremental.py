"""THE paper's correctness property: incremental inference over a VQT is
*exact* — identical VQ codes and (float-tolerance) identical hidden states to
recomputing the edited document from scratch — while costing a fraction of
the arithmetic operations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.vq_opt_125m import smoke_config
from repro.core.edits import Edit, apply_edit
from repro.core.incremental import IncrementalEngine
from repro.core.opcount import OpCounter
from repro.core.positional import PositionAllocator
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    return cfg, params, IncrementalEngine(params, cfg)


def _doc(cfg, n=48, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, n)
    positions = np.arange(n) * 7  # gapped ids
    return tokens, positions


def _assert_state_equal(a, b, atol=5e-5):
    for la, lb in zip(a.layers, b.layers):
        np.testing.assert_array_equal(la.codes, lb.codes)
    for xa, xb in zip(a.xs, b.xs):
        np.testing.assert_allclose(xa, xb, atol=atol)


def test_engine_matches_jax_forward(setup):
    cfg, params, eng = setup
    tokens, positions = _doc(cfg)
    st_ = eng.full_forward(tokens, positions)
    logits_jax, _ = T.forward(
        params, cfg, jnp.asarray(tokens)[None], jnp.asarray(positions)[None]
    )
    np.testing.assert_allclose(
        eng.logits_at(st_), np.asarray(logits_jax[0, -1]), atol=2e-4
    )


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_edits=st.integers(1, 4))
def test_replace_exactness(setup, seed, n_edits):
    cfg, params, eng = setup
    tokens, positions = _doc(cfg, seed=seed % 7)
    base = eng.full_forward(tokens, positions)
    rng = np.random.default_rng(seed)
    pos_list = list(rng.choice(len(tokens), n_edits, replace=False))
    new_toks = list(rng.integers(0, cfg.vocab, n_edits))
    inc = eng.apply_replaces(base, pos_list, new_toks)
    t2 = tokens.copy()
    t2[pos_list] = new_toks
    full = eng.full_forward(t2, positions)
    _assert_state_equal(inc, full)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_insert_exactness(setup, seed):
    cfg, params, eng = setup
    tokens, positions = _doc(cfg, seed=seed % 5)
    base = eng.full_forward(tokens, positions)
    rng = np.random.default_rng(seed)
    p = int(rng.integers(0, len(tokens) + 1))
    lo = positions[p - 1] if p > 0 else -1
    hi = positions[p] if p < len(tokens) else positions[-1] + 8
    pid = int((lo + hi) // 2)
    tok = int(rng.integers(0, cfg.vocab))
    inc = eng.apply_insert(base, p, tok, pid)
    full = eng.full_forward(np.insert(tokens, p, tok), np.insert(positions, p, pid))
    _assert_state_equal(inc, full)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_delete_exactness(setup, seed):
    cfg, params, eng = setup
    tokens, positions = _doc(cfg, seed=seed % 5)
    base = eng.full_forward(tokens, positions)
    rng = np.random.default_rng(seed)
    p = int(rng.integers(0, len(tokens)))
    inc = eng.apply_delete(base, p)
    full = eng.full_forward(np.delete(tokens, p), np.delete(positions, p))
    _assert_state_equal(inc, full)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_edit_stream_exactness(setup, seed):
    """Mixed replace/insert/delete stream with a real allocator — the full
    online serving scenario stays exact edit after edit."""
    cfg, params, eng = setup
    rng = np.random.default_rng(seed)
    n = 32
    tokens = list(rng.integers(0, cfg.vocab, n))
    alloc = PositionAllocator(n, pool_size=cfg.pos_pool)
    state = eng.full_forward(tokens, alloc.positions)
    for _ in range(5):
        op = ["replace", "insert", "delete"][rng.integers(3)]
        if op == "replace":
            e = Edit("replace", int(rng.integers(len(tokens))), int(rng.integers(cfg.vocab)))
        elif op == "insert":
            e = Edit("insert", int(rng.integers(len(tokens) + 1)), int(rng.integers(cfg.vocab)))
        else:
            e = Edit("delete", int(rng.integers(len(tokens))))
        state = eng.apply_edit(state, e, alloc)
        tokens = apply_edit(tokens, e)
    full = eng.full_forward(tokens, alloc.positions)
    _assert_state_equal(state, full)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), frac=st.sampled_from([0.02, 0.1, 0.3]))
def test_apply_revision_exactness(setup, seed, frac):
    """Batched offline revision (one column-patch sweep per layer) is exact."""
    from repro.core.edits import random_revision

    cfg, params, eng = setup
    rng = np.random.default_rng(seed)
    n = 48
    tokens = rng.integers(0, cfg.vocab, n)
    alloc = PositionAllocator(n, cfg.pos_pool)
    base = eng.full_forward(tokens, alloc.positions)
    new = np.asarray(random_revision(rng, tokens, cfg.vocab, frac))
    inc = eng.apply_revision(base, new, alloc)
    full = eng.full_forward(new, np.asarray(alloc.positions))
    _assert_state_equal(inc, full)


def test_incremental_is_cheaper(setup):
    cfg, params, _ = setup
    c_full, c_inc = OpCounter(), OpCounter()
    e_full = IncrementalEngine(params, cfg, c_full)
    e_inc = IncrementalEngine(params, cfg, c_inc)
    tokens, positions = _doc(cfg, n=96)
    base = e_inc.full_forward(tokens, positions)
    c_inc.counts.clear()
    t2 = tokens.copy()
    t2[40] = (t2[40] + 1) % cfg.vocab
    e_full.full_forward(t2, positions)
    e_inc.apply_replaces(base, [40], [t2[40]])
    assert c_inc.total < c_full.total / 2, (c_inc.total, c_full.total)
