"""The jit-able static-bucket engine == the NumPy engine == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.core.incremental import IncrementalEngine
from repro.models import transformer as T
from repro.serving.jit_engine import JitIncrementalEngine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    jeng = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=32)
    neng = IncrementalEngine(params, cfg)
    return cfg, jeng, neng


def _doc(cfg, n=40, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, n), np.arange(n) * 5


def test_jit_full_forward_matches_numpy(setup):
    cfg, jeng, neng = setup
    tokens, positions = _doc(cfg)
    js = jeng.full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    ns = neng.full_forward(tokens, positions)
    for li in range(len(neng.layers)):
        np.testing.assert_array_equal(np.asarray(js.codes[li]), ns.layers[li].codes)
    np.testing.assert_allclose(np.asarray(js.x[-1]), ns.xs[-1], atol=3e-4)


def test_jit_replace_matches_numpy(setup):
    cfg, jeng, neng = setup
    tokens, positions = _doc(cfg, seed=1)
    js = jeng.full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    ns = neng.full_forward(tokens, positions)
    rng = np.random.default_rng(2)
    for trial in range(3):
        pos = sorted(rng.choice(len(tokens), 2, replace=False))
        tok = rng.integers(0, cfg.vocab, 2)
        edit_pos = jnp.asarray(list(pos) + [-1, -1], jnp.int32)  # C=4 bucket
        edit_tok = jnp.asarray(list(tok) + [0, 0], jnp.int32)
        js2, overflow = jeng.apply_replaces(js, edit_pos, edit_tok)
        assert not bool(overflow)
        ns2 = neng.apply_replaces(ns, list(pos), list(tok))
        for li in range(len(neng.layers)):
            np.testing.assert_array_equal(
                np.asarray(js2.codes[li]), ns2.layers[li].codes)
        np.testing.assert_allclose(np.asarray(js2.x[-1]), ns2.xs[-1], atol=3e-4)
        np.testing.assert_allclose(
            np.asarray(jeng.logits_last(js2)), neng.logits_at(ns2), atol=3e-4)
        js, ns = js2, ns2
        tokens = np.asarray(js.tokens)


def test_jit_overflow_flag(setup):
    """A tiny row capacity must trip the overflow flag on a wide edit."""
    cfg, jeng, neng = setup
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    tight = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=2)
    tokens, positions = _doc(cfg, seed=3)
    js = tight.full_forward(jnp.asarray(tokens), jnp.asarray(positions))
    edit_pos = jnp.asarray([1, 2, 3, 4], jnp.int32)
    edit_tok = jnp.asarray([9, 9, 9, 9], jnp.int32)
    _, overflow = tight.apply_replaces(js, edit_pos, edit_tok)
    assert bool(overflow)  # 4 edits alone exceed R=2
