"""The Pallas kernels are wired into the model path: flipping the dispatch
flags routes σ-attention and hard VQ through the kernels (interpret mode on
CPU) and yields the same model outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.core import vq as vq_mod
from repro.models import attention as attn_mod
from repro.models import transformer as T


@pytest.fixture
def restore_flags():
    yield
    attn_mod.USE_PALLAS_SIGMA = False
    vq_mod.USE_PALLAS = False


def test_model_forward_via_pallas_kernels(restore_flags):
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab)
    positions = jnp.arange(48)[None].repeat(2, 0) * 3

    logits_jnp, _ = T.forward(params, cfg, tokens, positions)
    attn_mod.USE_PALLAS_SIGMA = True
    vq_mod.USE_PALLAS = True
    logits_k, _ = T.forward(params, cfg, tokens, positions)
    np.testing.assert_allclose(
        np.asarray(logits_k, np.float32), np.asarray(logits_jnp, np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_vq_quantize_pallas_identical_codes(restore_flags):
    cfg = vq_mod.VQConfig(n_heads=2, codebook_size=64)
    params = vq_mod.init(jax.random.PRNGKey(0), 128, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (37, 128))
    xq0, idx0 = vq_mod.quantize(params, x)
    vq_mod.USE_PALLAS = True
    xq1, idx1 = vq_mod.quantize(params, x)
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_allclose(np.asarray(xq0), np.asarray(xq1), atol=1e-6)
