"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gated_attention import gated_attention, gated_attention_ref
from repro.kernels.vq_assign import vq_assign, vq_assign_batched, vq_assign_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "N,hq,Q,dv", [(64, 2, 64, 384), (257, 4, 64, 64), (8, 1, 128, 256), (1024, 2, 32, 128)]
)
def test_vq_assign_sweep(N, hq, Q, dv, dtype):
    key = jax.random.PRNGKey(N + hq)
    x = jax.random.normal(key, (N, hq * dv), dtype)
    cb = (jax.random.normal(jax.random.PRNGKey(1), (hq, Q, dv)) * 0.5).astype(dtype)
    idx, xq = vq_assign(x, cb)
    idx_r, xq_r = vq_assign_ref(x.reshape(N, hq, dv), cb)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_r))
    np.testing.assert_allclose(
        np.asarray(xq, np.float32).reshape(N, hq, dv),
        np.asarray(xq_r, np.float32),
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


@pytest.mark.parametrize("B,N,hq,Q,dv", [(2, 64, 2, 64, 128), (3, 30, 2, 32, 64)])
def test_vq_assign_batched_matches_per_doc(B, N, hq, Q, dv):
    """The batch-grid kernel slice b == the single-doc kernel on doc b."""
    x = jax.random.normal(jax.random.PRNGKey(B + N), (B, N, hq * dv))
    cb = jax.random.normal(jax.random.PRNGKey(1), (hq, Q, dv)) * 0.5
    idx_b, xq_b = vq_assign_batched(x, cb, block_n=32)
    assert idx_b.shape == (B, N, hq) and xq_b.shape == (B, N, hq * dv)
    for b in range(B):
        idx_s, xq_s = vq_assign(x[b], cb, block_n=32)
        np.testing.assert_array_equal(np.asarray(idx_b[b]), np.asarray(idx_s))
        np.testing.assert_allclose(np.asarray(xq_b[b]), np.asarray(xq_s),
                                   atol=1e-6)


@pytest.mark.parametrize(
    "B,N,hq,Q,dv",
    [(2, 13, 3, 48, 24), (3, 7, 1, 40, 96), (1, 257, 2, 96, 40)],
)
def test_vq_assign_batched_matches_ref_odd_shapes(B, N, hq, Q, dv):
    """The batch-grid kernel vs the pure-jnp oracle on non-power-of-two /
    odd token, head, codebook and chunk extents (token rows are the only
    padded axis; Q/dv must be exact), including N smaller than one block."""
    x = jax.random.normal(jax.random.PRNGKey(B * N + Q), (B, N, hq * dv))
    cb = jax.random.normal(jax.random.PRNGKey(2), (hq, Q, dv)) * 0.5
    idx, xq = vq_assign_batched(x, cb, block_n=8)
    assert idx.shape == (B, N, hq) and xq.shape == (B, N, hq * dv)
    for b in range(B):
        idx_r, xq_r = vq_assign_ref(x[b].reshape(N, hq, dv), cb)
        np.testing.assert_array_equal(np.asarray(idx[b]), np.asarray(idx_r))
        np.testing.assert_allclose(np.asarray(xq[b]).reshape(N, hq, dv),
                                   np.asarray(xq_r), atol=1e-6)


def test_vq_assign_matches_model_vq():
    """Kernel == repro.core.vq assignment (same inner-product trick)."""
    from repro.core import vq as V

    cfg = V.VQConfig(n_heads=2, codebook_size=64)
    params = V.init(jax.random.PRNGKey(0), 128, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (50, 128))
    idx_kernel, xq_kernel = vq_assign(x, params.codebook)
    idx_model = V.assign(params, x)
    np.testing.assert_array_equal(np.asarray(idx_kernel), np.asarray(idx_model))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,n,H,Hkv,dh,bq,bk",
    [
        (2, 128, 4, 4, 64, 64, 64),
        (1, 200, 8, 2, 32, 64, 128),  # ragged n, GQA
        (2, 64, 2, 1, 128, 32, 32),  # MQA
        (1, 33, 1, 1, 64, 256, 256),  # blocks larger than n
    ],
)
def test_gated_attention_sweep(b, n, H, Hkv, dh, bq, bk, dtype):
    key = jax.random.PRNGKey(n)
    q = jax.random.normal(key, (b, n, H, dh), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, n, Hkv, dh), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, n, Hkv, dh), dtype)
    out = gated_attention(q, k, v, block_q=bq, block_k=bk)
    rep = H // Hkv
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    fold = lambda a: jnp.moveaxis(a, 2, 1).reshape(b * H, n, dh)
    ref = gated_attention_ref(fold(q), fold(kr), fold(vr))
    ref = jnp.moveaxis(ref.reshape(b, H, n, dh), 1, 2).reshape(b, n, H * dh)
    atol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol, rtol=atol
    )


def test_gated_attention_matches_model_sigma_path():
    from repro.models.attention import attention_core, make_mask

    b, n, H, dh = 2, 96, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, n, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, n, H, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, n, H, dh))
    kernel = gated_attention(q, k, v, block_q=32, block_k=32)
    model = attention_core(q, k, v, make_mask(n, n, causal=True, window=None),
                           softmax=False)
    np.testing.assert_allclose(
        np.asarray(kernel, np.float32), np.asarray(model, np.float32),
        atol=1e-5, rtol=1e-5,
    )
