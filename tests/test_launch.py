"""Launch-layer units: HLO stats parser, input specs, full-size configs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.launch.hlo_stats import collective_stats, top_ops_by_bytes, _shape_bytes
from repro.launch.specs import SHAPES, decode_token_specs, input_specs, shape_supported

ASSIGNED = {
    # arch: (layers, d_model, heads, kv, vocab)
    "deepseek-v2-236b": (60, 5120, 128, 128, 102400),
    "gemma3-12b": (48, 3840, 16, 8, 262144),
    "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
    "internvl2-1b": (24, 896, 14, 2, 151655),
    "musicgen-large": (48, 2048, 32, 32, 2048),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 32000),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 200064),
    "stablelm-1.6b": (24, 2048, 32, 32, 100352),
    "hymba-1.5b": (32, 1600, 25, 5, 32001),
    "rwkv6-7b": (32, 4096, 64, 64, 65536),
}


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_full_configs_match_assignment(arch):
    cfg = get_config(arch)
    L, d, H, kv, V = ASSIGNED[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == kv and cfg.vocab == V
    assert len(cfg.layer_list()) == L
    assert cfg.source, "every config must cite its source"
    if arch.startswith("deepseek"):
        assert cfg.moe is not None and cfg.mla is not None
        assert cfg.mla.kv_lora == 512
    if arch == "deepseek-v2-236b":
        assert (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.n_shared) == (160, 6, 2)
        assert cfg.moe.d_ff_expert == 1536
    if arch == "deepseek-v3-671b":
        assert (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.n_shared) == (256, 8, 1)
        assert cfg.moe.d_ff_expert == 2048 and cfg.mtp
    if arch == "gemma3-12b":
        windows = [l.window for l in cfg.layer_list()]
        assert windows.count(None) == 8 and len(windows) == 48  # 5:1 local:global
    if arch == "hymba-1.5b":
        assert cfg.ssm is not None and cfg.ssm.d_state == 16
        assert sum(1 for l in cfg.layer_list() if l.window is None) == 3
    if arch == "rwkv6-7b":
        assert all(l.mixer == "rwkv6" for l in cfg.layer_list())
        assert cfg.d_ff == 14336


def test_long_context_gating():
    allowed = {a for a in ASSIGNED if shape_supported(get_config(a), SHAPES["long_500k"])[0]}
    assert allowed == {"gemma3-12b", "h2o-danube-1.8b", "hymba-1.5b", "rwkv6-7b"}


@pytest.mark.parametrize("arch", list(ASSIGNED))
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    sc = SHAPES[shape]
    if sc.kind == "decode":
        specs = decode_token_specs(cfg, sc)
        assert specs["tokens"].shape[0] == sc.global_batch
        assert specs["tokens"].shape[1] == 1
    else:
        specs = input_specs(cfg, sc)
        total = specs["tokens"].shape[1]
        if cfg.input_mode == "vlm":
            total += specs["patch_embeds"].shape[1]
            assert specs["patch_embeds"].shape[-1] == cfg.d_model
        assert total == sc.seq_len
        assert specs["tokens"].shape[0] == sc.global_batch


def test_hlo_shape_bytes():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[4,4]{1,0}") == 32
    assert _shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert _shape_bytes("pred[]") == 1


def test_collective_stats_parses_synthetic_hlo():
    hlo = """
HloModule m
  %p0 = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%p0), replica_groups={}
  %ag.1 = f32[16,16]{1,0} all-gather(%ar), dimensions={0}
  %x = f32[8,16]{1,0} add(%p0, %ar)
"""
    st = collective_stats(hlo)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.bytes_by_kind["all-reduce"] == 8 * 16 * 4
    assert st.count_by_kind["all-gather"] == 1
    assert st.bytes_by_kind["all-gather"] == 8 * 16 * 4  # operand bytes
    ops = top_ops_by_bytes(hlo, 5)
    assert any(op == "all-gather" for op, _, _ in ops)


def test_flash_q_offset_matches_suffix():
    """Streaming attention with q_offset == computing the suffix rows of the
    full attention (the decode-prefill split invariant)."""
    from repro.models.flash import streaming_attention

    key = jax.random.PRNGKey(0)
    b, n, H, dh = 1, 64, 2, 16
    q = jax.random.normal(key, (b, n, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, n, H, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, n, H, dh))
    full = streaming_attention(q, k, v, causal=True, softmax=True, kv_block=16)
    tail = streaming_attention(q[:, 48:], k, v, causal=True, softmax=True,
                               kv_block=16, q_offset=48)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 48:]),
                               atol=1e-5)


def test_make_serving_mesh():
    """1-D serving mesh (DESIGN.md §6): device-count-agnostic default, a
    prefix of the device list on request, loud failure past the hardware."""
    from repro.launch.mesh import make_serving_mesh

    mesh = make_serving_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == jax.device_count()
    one = make_serving_mesh(1)
    assert one.shape["data"] == 1
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(jax.device_count() + 1)


def test_serving_batch_sharding_prefix():
    """The serving batch sharding (the spec every sharded dispatch uses,
    via BatchedJitEngine._sharded) puts dim 0 on the mesh axis and
    replicates the rest; unknown axes are rejected."""
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.sharding import serving_batch_sharding

    mesh = make_serving_mesh(1)
    s = serving_batch_sharding(mesh)
    assert tuple(s.spec) == ("data",)
    with pytest.raises(ValueError, match="no axis"):
        serving_batch_sharding(mesh, axis="nope")
