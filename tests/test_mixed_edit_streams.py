"""Full edit algebra in the jit path == the NumPy engine (ISSUE 2 tentpole).

Parity ladder over randomized insert/delete/replace streams:

1. engine level — the slot-buffer ``JitIncrementalEngine`` stepped edit by
   edit (host-managed slot map) matches ``IncrementalEngine`` in sequence
   order: codes exact, activations to float tolerance;
2. server level — ``BatchServer`` serves a randomized mixed stream (>=30%
   structural edits) end to end with fixed-shape dispatches only (the
   traced-shape count is bounded by the capacity grid, not the edit
   count), and the final states match a NumPy full forward on the same
   sequence-ordered tokens/positions;
3. forced gap exhaustion — a tiny position pool drives the allocator into
   defragmentation (full-forward re-ingest), after which parity holds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.core.incremental import IncrementalEngine
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer
from repro.serving.jit_engine import JitIncrementalEngine


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
    jeng = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=16)
    neng = IncrementalEngine(params, cfg)
    return cfg, params, jeng, neng


def _assert_seq_parity(js, slots, ns, neng, atol=3e-4):
    sl = np.asarray(slots)
    np.testing.assert_array_equal(np.asarray(js.tokens)[sl], ns.tokens)
    np.testing.assert_array_equal(np.asarray(js.positions)[sl], ns.positions)
    assert int(js.n_real) == ns.n
    for li in range(len(neng.layers)):
        np.testing.assert_array_equal(np.asarray(js.codes[li])[sl],
                                      ns.layers[li].codes)
    np.testing.assert_allclose(np.asarray(js.x[-1])[sl], ns.xs[-1], atol=atol)


# ------------------------------------------------------------- engine level


def test_engine_mixed_stream_matches_numpy(setup):
    """Randomized insert/delete/replace stream, one jit step per edit, with
    slot reuse (deleted slots are reclaimed by later inserts)."""
    cfg, params, jeng, neng = setup
    rng = np.random.default_rng(0)
    n, n_cap, pool = 12, 16, 2048
    tokens = np.zeros(n_cap, np.int32)
    tokens[:n] = rng.integers(0, cfg.vocab, n)
    positions = np.full(n_cap, pool - 1, np.int32)
    positions[:n] = (np.arange(1, n + 1) * pool) // (n + 1)
    valid = np.zeros(n_cap, bool)
    valid[:n] = True
    slots = list(range(n))
    free = list(range(n_cap - 1, n - 1, -1))
    pad = jnp.asarray([-1, -1, -1], jnp.int32)

    js = jeng.full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                           jnp.asarray(valid))
    ns = neng.full_forward(tokens[:n], positions[:n])
    _assert_seq_parity(js, slots, ns, neng)

    structural = 0
    for step in range(24):
        kind = rng.choice(["replace", "insert", "delete"])
        nn = len(slots)
        if kind == "insert" and free:
            p = int(rng.integers(nn + 1))
            t = int(rng.integers(cfg.vocab))
            lo = ns.positions[p - 1] if p > 0 else -1
            hi = ns.positions[p] if p < nn else pool
            if hi - lo <= 1:
                continue
            pid = int((lo + hi) // 2)
            s = free.pop()
            slots.insert(p, s)
            js, ovf = jeng.apply_inserts(
                js, jnp.concatenate([jnp.asarray([s], jnp.int32), pad]),
                jnp.asarray([t, 0, 0, 0], jnp.int32),
                jnp.asarray([pid, 0, 0, 0], jnp.int32))
            ns = neng.apply_insert(ns, p, t, pid)
            structural += 1
        elif kind == "delete" and nn > 2:
            p = int(rng.integers(nn))
            s = slots.pop(p)
            free.append(s)
            js, ovf = jeng.apply_deletes(
                js, jnp.concatenate([jnp.asarray([s], jnp.int32), pad]))
            ns = neng.apply_delete(ns, p)
            structural += 1
        else:
            p = int(rng.integers(nn))
            t = int(rng.integers(cfg.vocab))
            js, ovf = jeng.apply_replaces(
                js, jnp.concatenate([jnp.asarray([slots[p]], jnp.int32), pad]),
                jnp.asarray([t, 0, 0, 0], jnp.int32))
            ns = neng.apply_replaces(ns, [p], [t])
        assert not bool(ovf), (step, kind)
        _assert_seq_parity(js, slots, ns, neng)
    assert structural >= 5  # the stream genuinely exercised inserts/deletes


def test_engine_mixed_bucket_single_step(setup):
    """One generic apply_edits step carrying a replace AND an insert."""
    cfg, params, jeng, neng = setup
    rng = np.random.default_rng(3)
    n, n_cap, pool = 10, 16, 2048
    tokens = np.zeros(n_cap, np.int32)
    tokens[:n] = rng.integers(0, cfg.vocab, n)
    positions = np.full(n_cap, pool - 1, np.int32)
    positions[:n] = (np.arange(1, n + 1) * pool) // (n + 1)
    valid = np.zeros(n_cap, bool)
    valid[:n] = True
    js = jeng.full_forward(jnp.asarray(tokens), jnp.asarray(positions),
                           jnp.asarray(valid))
    ns = neng.full_forward(tokens[:n], positions[:n])
    pid = int((positions[4] + positions[5]) // 2)
    slots = list(range(n))
    slots.insert(5, 10)  # fresh slot for the insert
    js, ovf = jeng.apply_edits(
        js,
        jnp.asarray([2, 10, -1, -1], jnp.int32),  # slot
        jnp.asarray([7, 9, 0, 0], jnp.int32),  # tok
        jnp.asarray([0, pid, 0, 0], jnp.int32),  # pos_id
        jnp.asarray([0, 1, 0, 0], jnp.int32),  # op: replace, insert
    )
    assert not bool(ovf)
    ns = neng.apply_replaces(ns, [2], [7])
    ns = neng.apply_insert(ns, 5, 9, pid)
    _assert_seq_parity(js, slots, ns, neng)


# ------------------------------------------------------------- server level


def test_server_mixed_stream_parity_and_fixed_shapes(setup):
    """BatchServer serves a >=30%-structural randomized stream end to end;
    every dispatch is fixed-shape (traced-shape count independent of the
    edit count) and final states match the NumPy engine."""
    cfg, params, jeng, neng = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=4, min_doc_capacity=16, pos_pool=2048)
    rng = np.random.default_rng(6)
    ref = {}
    for i in range(3):
        n = int(rng.integers(10, 15))
        toks = rng.integers(0, cfg.vocab, n)
        ref[f"d{i}"] = list(toks)
        srv.open_document(f"d{i}", toks)
    n_ops, structural = 48, 0
    for _ in range(n_ops):
        did = f"d{int(rng.integers(3))}"
        r = ref[did]
        kind = rng.choice(["replace", "insert", "delete"], p=[0.5, 0.3, 0.2])
        if kind == "insert":
            p = int(rng.integers(len(r) + 1))
            t = int(rng.integers(cfg.vocab))
            srv.submit_insert(did, p, t)
            r.insert(p, t)
            structural += 1
        elif kind == "delete" and len(r) > 1:
            p = int(rng.integers(len(r)))
            srv.submit_delete(did, p)
            del r[p]
            structural += 1
        else:
            p = int(rng.integers(len(r)))
            t = int(rng.integers(cfg.vocab))
            srv.submit_replace(did, p, t)
            r[p] = t
        if rng.random() < 0.3:
            srv.step()  # partial flush mid-stream
    srv.flush()
    assert structural / n_ops >= 0.3
    assert srv.pending_count() == 0
    assert srv.stats.edits_applied == srv.stats.edits_submitted
    # fixed-shape serving: shapes come from the capacity grid (n_cap
    # buckets x batch pads x full/edit), never from individual edits —
    # far fewer traced shapes than edits applied
    assert srv.stats.rejits <= 8
    for did, r in ref.items():
        assert list(srv.tokens(did)) == r, did
        doc = srv.docs[did]
        ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
        _assert_seq_parity(doc.state, doc.slots, ns, neng)
        np.testing.assert_allclose(srv.logits(did), neng.logits_at(ns),
                                   atol=3e-4)


def test_server_gap_exhaustion_defrags_and_recovers(setup):
    """A tiny position pool forces gap exhaustion: the scheduler must
    defragment (re-spread ids + full-forward re-ingest) and stay exact."""
    cfg, params, jeng, neng = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=2, min_doc_capacity=16, pos_pool=64)
    rng = np.random.default_rng(7)
    r = list(rng.integers(0, cfg.vocab, 8))
    srv.open_document("d", r)
    # hammer one insertion point: each insert halves the local gap, so a
    # pool of 64 exhausts within a handful of inserts
    for _ in range(8):
        t = int(rng.integers(cfg.vocab))
        srv.submit_insert("d", 3, t)
        r.insert(3, t)
        srv.flush()
    assert srv.stats.defrags >= 1
    assert srv.docs["d"].allocator.defrag_count >= 1
    assert list(srv.tokens("d")) == r
    doc = srv.docs["d"]
    ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
    _assert_seq_parity(doc.state, doc.slots, ns, neng)


def test_server_capacity_grow_on_full_buffer(setup):
    """Inserting past n_cap steps the slot buffer up to the next capacity
    class (on-device pad, no re-ingest) without losing exactness."""
    cfg, params, jeng, neng = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=2, min_doc_capacity=8, pos_pool=2048)
    rng = np.random.default_rng(8)
    r = list(rng.integers(0, cfg.vocab, 7))
    srv.open_document("d", r)
    assert srv.docs["d"].n_cap == 8
    for i in range(6):
        t = int(rng.integers(cfg.vocab))
        p = int(rng.integers(len(r) + 1))
        srv.submit_insert("d", p, t)
        r.insert(p, t)
    srv.flush()
    doc = srv.docs["d"]
    assert srv.stats.grows >= 1
    assert srv.stats.device_grows >= 1
    assert doc.n_cap == srv.padded_cap(9) and doc.n == 13
    assert list(srv.tokens("d")) == r
    ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
    _assert_seq_parity(doc.state, doc.slots, ns, neng)


def test_server_edit_script_round_trip(setup):
    """submit_edit consumes core.edits scripts: replaying a random revision
    through the server reproduces the revision exactly."""
    from repro.core.edits import apply_edits, edit_script, random_revision

    cfg, params, jeng, neng = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      min_doc_capacity=16, pos_pool=2048)
    rng = np.random.default_rng(9)
    base = list(rng.integers(0, cfg.vocab, 12))
    srv.open_document("d", base)
    new = random_revision(rng, base, cfg.vocab, edit_fraction=0.3)
    script = edit_script(base, new)
    for e in script:
        srv.submit_edit("d", e)
    srv.flush()
    assert list(srv.tokens("d")) == apply_edits(base, script) == list(new)


def test_server_long_mixed_stream_compiled_shape_budget(setup):
    """ISSUE 7 satellite: a LONG mixed stream (structural-heavy, crossing a
    capacity-class boundary) must stay within a fixed compiled-shape
    budget, and the per-edit launch rate must stay O(1) — the ragged
    capacity classes + device-side grow keep the shape lattice bounded by
    the class grid, never by traffic volume."""
    cfg, params, jeng, neng = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=2, min_doc_capacity=8, pos_pool=2048)
    rng = np.random.default_rng(11)
    ref = {f"d{i}": list(rng.integers(0, cfg.vocab, 6)) for i in range(2)}
    srv.open_documents({k: list(v) for k, v in ref.items()})
    n_ops = 96
    for _ in range(n_ops):
        did = f"d{int(rng.integers(2))}"
        r = ref[did]
        kind = rng.choice(["replace", "insert", "delete"], p=[0.4, 0.4, 0.2])
        if kind == "insert":
            p = int(rng.integers(len(r) + 1))
            t = int(rng.integers(cfg.vocab))
            srv.submit_insert(did, p, t)
            r.insert(p, t)
        elif kind == "delete" and len(r) > 1:
            p = int(rng.integers(len(r)))
            srv.submit_delete(did, p)
            del r[p]
        else:
            p = int(rng.integers(len(r)))
            t = int(rng.integers(cfg.vocab))
            srv.submit_replace(did, p, t)
            r[p] = t
        if rng.random() < 0.5:
            srv.step()
    srv.flush()
    assert srv.stats.grows >= 1  # the stream DID cross a class boundary
    # the budget: ingest shapes + one edit shape per visited (class, B pad)
    # + one pad shape per class transition + overflow/defrag full shapes.
    # 2 classes x {full, edit, pad} at <= 2 batch pads is well under 12 —
    # and crucially INDEPENDENT of n_ops (96 edits here, was 8 shapes at
    # 24 edits in dev runs)
    assert srv.stats.traced_shapes <= 12
    assert srv.stats.traced_shapes == srv.stats.rejits  # alias stays true
    assert srv.stats.kernel_launches_per_edit <= 3.0
    for did, r in ref.items():
        assert list(srv.tokens(did)) == r, did
        doc = srv.docs[did]
        ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
        _assert_seq_parity(doc.state, doc.slots, ns, neng)
