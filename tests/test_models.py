"""Per-architecture smoke tests (reduced configs, CPU) + model-level
equivalence properties (streaming attention, linear scan, MoE paths)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.models import transformer as T

ARCHS = all_arch_names()


def _batch(cfg, key, b=2, n=32):
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(key, (b, n, cfg.n_codebooks), 0, cfg.vocab)
    else:
        tokens = jax.random.randint(key, (b, n), 0, cfg.vocab)
    kw = {}
    if cfg.input_mode == "vlm":
        kw["patch_embeds"] = jax.random.normal(key, (b, 8, cfg.d_model))
    if cfg.pos in ("learned", "sampled"):
        kw["positions"] = jnp.arange(n)[None].repeat(b, 0) * 3
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.d_model <= 512 and cfg.n_layers <= 2
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens, kw = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = T.forward(params, cfg, tokens, **kw)
    n_out = tokens.shape[1] + (8 if cfg.input_mode == "vlm" else 0)
    want = (2, n_out, cfg.n_codebooks, cfg.vocab) if cfg.n_codebooks > 1 else (
        2, n_out, cfg.vocab)
    assert logits.shape == want
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.training import make_schedule, make_train_step, train_state_init

    cfg = get_config(arch, smoke=True)
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, make_schedule(peak_lr=1e-3, warmup_steps=0,
                                                      total_steps=10)))
    tokens, kw = _batch(cfg, jax.random.PRNGKey(1), b=2, n=16)
    batch = {"tokens": tokens, **kw}
    state2, m = step(state, batch)
    assert np.isfinite(float(m["lm_loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Running the document token-by-token through decode_step must produce
    the same final logits as the full forward — validates every cache type
    (KV, ring-buffer SWA, MLA latent, SSM state, RWKV state, conv state)."""
    cfg = get_config(arch, smoke=True)
    if cfg.input_mode == "vlm":
        pytest.skip("decode consistency covered by text-only archs")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, n = 2, 24
    tokens, kw = _batch(cfg, jax.random.PRNGKey(1), b=b, n=n)
    positions = kw.get("positions")
    logits_full, _ = T.forward(params, cfg, tokens, positions)
    caches = T.init_caches(cfg, b, n, dtype=jnp.float32)
    for i in range(n):
        tok_i = tokens[:, i : i + 1]
        pos_i = (positions[:, i : i + 1] if positions is not None
                 else jnp.full((b, 1), i, jnp.int32))
        logits_step, caches = T.decode_step(params, cfg, tok_i, caches, pos_i)
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_windowed_decode_ring_buffer_matches_forward():
    """Sequence longer than the sliding window: ring-buffer decode must equal
    the windowed forward mask."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)  # window=64 after reduce
    assert any(l.window for l in cfg.layer_list())
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, n = 1, 80  # > window 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, n), 0, cfg.vocab)
    logits_full, _ = T.forward(params, cfg, tokens)
    caches = T.init_caches(cfg, b, n, dtype=jnp.float32)
    for i in range(n):
        logits_step, caches = T.decode_step(
            params, cfg, tokens[:, i : i + 1], caches, jnp.full((b, 1), i, jnp.int32)
        )
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("softmax", [True, False])
@pytest.mark.parametrize("window", [None, 16])
def test_streaming_attention_equals_dense(softmax, window):
    from repro.models.attention import attention_core, make_mask
    from repro.models.flash import streaming_attention

    key = jax.random.PRNGKey(0)
    b, n, H, Hkv, dh = 2, 100, 4, 2, 16
    q = jax.random.normal(key, (b, n, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, n, Hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, n, Hkv, dh))
    dense = attention_core(
        q, k, v, make_mask(n, n, causal=True, window=window), softmax=softmax
    )
    stream = streaming_attention(
        q, k, v, causal=True, window=window, softmax=softmax, kv_block=32
    )
    np.testing.assert_allclose(
        np.asarray(stream, np.float32), np.asarray(dense, np.float32),
        atol=2e-5, rtol=2e-5,
    )


@pytest.mark.parametrize("mamba_style", [True, False])
def test_linear_scan_chunked_equals_sequential(mamba_style):
    from repro.models.linear_scan import lin_attn_chunked, lin_attn_sequential

    key = jax.random.PRNGKey(0)
    b, h, n, dk, dv = 2, 3, 64, 8, 12
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, n, dk))
    k = jax.random.normal(ks[1], (b, h, n, dk))
    v = jax.random.normal(ks[2], (b, h, n, dv))
    logw = -jnp.abs(jax.random.normal(ks[3], (b, h, n, dk))) * 0.3
    u = jax.random.normal(ks[4], (h, dk)) * 0.5
    y1, s1 = lin_attn_sequential(q, k, v, logw, u=u, mamba_style=mamba_style)
    y2, s2 = lin_attn_chunked(q, k, v, logw, u=u, mamba_style=mamba_style)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


def test_linear_scan_decode_steps_match_full():
    from repro.models.linear_scan import lin_attn_decode_step, lin_attn_sequential

    key = jax.random.PRNGKey(0)
    b, h, n, dk, dv = 1, 2, 10, 4, 6
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, n, dk))
    k = jax.random.normal(ks[1], (b, h, n, dk))
    v = jax.random.normal(ks[2], (b, h, n, dv))
    logw = -jnp.abs(jax.random.normal(ks[3], (b, h, n, dk))) * 0.3
    y_full, s_full = lin_attn_sequential(q, k, v, logw)
    S = jnp.zeros((b, h, dk, dv))
    ys = []
    for t in range(n):
        y, S = lin_attn_decode_step(q[:, :, t], k[:, :, t], v[:, :, t], logw[:, :, t], S)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ys, 2)), np.asarray(y_full), atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(S), np.asarray(s_full), atol=1e-5)


def test_moe_ep_equals_dense():
    """shard_map expert-parallel path == dense reference (1-device mesh,
    capacity raised so no tokens drop)."""
    from repro.distributed.context import use_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.models.moe import moe_apply_dense, moe_apply_ep, moe_init

    cfg = get_config("deepseek-v2-236b", smoke=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_dense, aux_d = moe_apply_dense(params, cfg, x)
    with use_mesh(make_host_mesh()):
        y_ep, aux_e = jax.jit(lambda p, x: moe_apply_ep(p, cfg, x))(params, x)
    np.testing.assert_allclose(
        np.asarray(y_dense), np.asarray(y_ep), atol=2e-5, rtol=2e-5
    )
    np.testing.assert_allclose(float(aux_d), float(aux_e), rtol=1e-5)


def test_vqt_variant_available_for_every_arch():
    """The paper's technique is a first-class feature: every arch config can
    be instantiated with vqt=True (rwkv6 documents inapplicability and stays
    vanilla)."""
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True, vqt=True)
        if arch == "rwkv6-7b":
            assert cfg.vqt is None  # documented inapplicability
        else:
            assert cfg.vqt is not None and not cfg.attn_softmax
