"""Sampled positional embeddings and the gap allocator (paper §3.3, App. B)."""
import jax
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.positional import PositionAllocator, sample_positions, spread_positions


def test_sample_positions_sorted_unique():
    pos = np.asarray(sample_positions(jax.random.PRNGKey(0), 50, 1000))
    assert (np.diff(pos) > 0).all()
    assert pos.min() >= 0 and pos.max() < 1000


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), seed=st.integers(0, 100))
def test_allocator_insert_keeps_order(n, seed):
    rng = np.random.default_rng(seed)
    alloc = PositionAllocator(n, pool_size=n * 64)
    for _ in range(32):
        i = int(rng.integers(0, len(alloc) + 1))
        pid = alloc.insert_at(i)
        if pid is None:
            alloc.defragment()
        pos = alloc.positions
        assert all(pos[j] < pos[j + 1] for j in range(len(pos) - 1))


def test_allocator_exhaustion_triggers_none():
    alloc = PositionAllocator(4, pool_size=8)
    hits = 0
    for _ in range(16):
        if alloc.insert_at(1) is None:
            hits += 1
            alloc.defragment()
    assert hits >= 1  # tiny pool must exhaust and defragment


def test_spread_positions_has_gaps():
    pos = spread_positions(10, 1000)
    gaps = np.diff(pos)
    assert gaps.min() >= 99  # ~pool/n spacing for insertions
