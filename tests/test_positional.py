"""Sampled positional embeddings and the gap allocator (paper §3.3, App. B)."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.positional import PositionAllocator, sample_positions, spread_positions


def test_sample_positions_sorted_unique():
    pos = np.asarray(sample_positions(jax.random.PRNGKey(0), 50, 1000))
    assert (np.diff(pos) > 0).all()
    assert pos.min() >= 0 and pos.max() < 1000


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), seed=st.integers(0, 100))
def test_allocator_insert_keeps_order(n, seed):
    rng = np.random.default_rng(seed)
    alloc = PositionAllocator(n, pool_size=n * 64)
    for _ in range(32):
        i = int(rng.integers(0, len(alloc) + 1))
        pid = alloc.insert_at(i)
        if pid is None:
            alloc.defragment()
        pos = alloc.positions
        assert all(pos[j] < pos[j + 1] for j in range(len(pos) - 1))


def test_allocator_exhaustion_triggers_none():
    alloc = PositionAllocator(4, pool_size=8)
    hits = 0
    for _ in range(16):
        if alloc.insert_at(1) is None:
            hits += 1
            alloc.defragment()
    assert hits >= 1  # tiny pool must exhaust and defragment


def test_spread_positions_has_gaps():
    pos = spread_positions(10, 1000)
    gaps = np.diff(pos)
    assert gaps.min() >= 99  # ~pool/n spacing for insertions


def test_allocator_boundary_gaps():
    """The allocator's layouts leave room BEFORE the first and AFTER the
    last token (front-anchored spreads made insert-at-0 unsatisfiable even
    right after a defrag)."""
    alloc = PositionAllocator(8, pool_size=64)
    assert alloc.can_insert_at(0) and alloc.can_insert_at(8)
    alloc.defragment()
    assert alloc.can_insert_at(0) and alloc.can_insert_at(len(alloc))


def test_allocator_snapshot_restore_and_gap_queries():
    """The device-friendly API the batch server's rollback path uses."""
    alloc = PositionAllocator(6, pool_size=64)
    snap = alloc.snapshot()
    assert snap.dtype == np.int32 and list(snap) == alloc.positions
    assert alloc.min_gap() == min(alloc.gap_at(i) for i in range(7))
    pid = alloc.insert_at(3)
    assert pid is not None and alloc.positions[3] == pid
    alloc.delete_at(0)
    alloc.restore(snap)  # rollback: exactly the snapshotted ids again
    assert alloc.positions == list(snap)
    with pytest.raises(ValueError):
        alloc.restore(snap[::-1])  # not increasing
    with pytest.raises(ValueError):
        alloc.restore(np.asarray([0, 99], np.int32))  # outside the pool
    # exhaustion reporting: a saturated region reports gap 0 / min_gap 0
    tight = PositionAllocator(4, pool_size=5)
    while tight.insert_at(1) is not None:
        pass
    assert tight.gap_at(1) == 0
    assert tight.min_gap() == 0
    assert not tight.can_insert_at(1)
