"""Incremental serving engine (online + offline paths)."""
import jax
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.core.edits import Edit
from repro.core.incremental import IncrementalEngine
from repro.models import transformer as T
from repro.serving.engine import IncrementalServer


@pytest.fixture(scope="module")
def server():
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return IncrementalServer(jax.device_get(params), cfg), cfg, params


def test_online_edits_stay_consistent(server):
    srv, cfg, params = server
    rng = np.random.default_rng(0)
    doc = list(rng.integers(0, cfg.vocab, 40))
    srv.open_document("a", doc)
    edits = [Edit("replace", 5, 7), Edit("insert", 11, 9), Edit("delete", 0),
             Edit("insert", 39, 3), Edit("replace", 20, 1)]
    expect = list(doc)
    from repro.core.edits import apply_edit

    for e in edits:
        srv.apply_edit("a", e)
        expect = apply_edit(expect, e)
    assert list(srv.tokens("a")) == expect
    # state equals recomputing from scratch with the server's positions
    eng = IncrementalEngine(jax.device_get(params), cfg)
    fresh = eng.full_forward(expect, srv.docs["a"].allocator.positions)
    np.testing.assert_allclose(
        srv.docs["a"].state.xs[-1], fresh.xs[-1], atol=5e-5
    )


def test_offline_revision_and_speedup(server):
    srv, cfg, params = server
    rng = np.random.default_rng(1)
    doc = list(rng.integers(0, cfg.vocab, 64))
    srv.open_document("b", doc)
    new = list(doc)
    new[10] = 3
    new[30] = 4
    del new[50]
    ops = srv.submit_revision("b", new)
    assert list(srv.tokens("b")) == new
    assert ops < srv._dense_ops(len(new)), "incremental must beat from-scratch"


def test_defrag_counted(server):
    srv, cfg, params = server
    # tiny positional pool forces defragmentation under repeated inserts
    small = IncrementalServer(
        jax.device_get(params), cfg, pos_pool=80
    )
    rng = np.random.default_rng(2)
    doc = list(rng.integers(0, cfg.vocab, 40))
    small.open_document("c", doc)
    for i in range(30):
        small.apply_edit("c", Edit("insert", 20, int(rng.integers(cfg.vocab))))
    assert small.stats.defrags >= 1
    assert len(small.tokens("c")) == 70
