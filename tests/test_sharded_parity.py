"""Sharded serving differential suite (ISSUE 4 tentpole).

The batch (document) axis of every ``BatchedJitEngine`` dispatch shards
over a 1-D serving mesh (``launch.mesh.make_serving_mesh``; DESIGN.md §6).
Contract, per the acceptance criteria:

1. **mesh size 1 is bit-exact vs the pre-mesh path** — a size-1 mesh routes
   through the identical single-device jit functions, so every state leaf,
   token buffer and suggestion matches bitwise;
2. **engine parity across mesh sizes** — every batched entry point
   (full forward / apply_edits / export_kv / logits_at) produces the same
   per-document results under mesh sizes 1, 2 and 4 (codes exact, floats
   to tolerance — per-shard vmaps may batch reductions differently);
3. **server end-to-end differential** — ``BatchServer`` over a mesh serves
   mixed edit streams + suggestions (incl. forced defrag and grow) with
   final tokens/logits identical to the NumPy oracle and suggestions equal
   to the from-scratch decode oracle;
4. **scheduler shard-awareness** — dispatch batches pad to a multiple of
   the mesh's batch axis and members place balanced across per-shard row
   blocks (greedy LPT).

Mesh sizes above the visible device count skip in-process; a subprocess
leg forces 4 host devices (``XLA_FLAGS=--xla_force_host_platform_device_count``)
so the mesh>1 code path runs even in a single-device tier-1 environment.
The CI ``test-multidevice`` job runs this whole suite under 4 forced
devices on every PR.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.core.incremental import IncrementalEngine
from repro.launch.mesh import make_serving_mesh
from repro.models import transformer as T
from repro.serving.batch_engine import BatchedJitEngine
from repro.serving.batch_server import BatchServer
from repro.serving.jit_engine import JitIncrementalEngine
from repro.serving.suggest import SuggestionEngine, oracle_suggestion

MESH_SIZES = (1, 2, 4)


def _need(k: int):
    if jax.device_count() < k:
        pytest.skip(f"needs {k} devices, have {jax.device_count()} "
                    "(run under XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
    neng = IncrementalEngine(params, cfg)
    base = BatchedJitEngine(params, cfg, edit_capacity=4, row_capacity=32)
    return cfg, params, neng, base


# ------------------------------------------------------- scheduler host logic


def _host_server(n_shards: int, max_batch: int = 8) -> BatchServer:
    """A BatchServer shell for host-side scheduling unit tests: no params,
    no devices — only the fields _padded_batch/_place_rows read."""
    srv = BatchServer.__new__(BatchServer)
    srv.n_shards = n_shards
    srv.max_batch = max_batch
    return srv


def test_padded_batch_is_multiple_of_mesh_axis():
    srv = _host_server(n_shards=4, max_batch=8)
    for chunk_len in range(1, 9):
        b = srv._padded_batch(chunk_len)
        assert b % 4 == 0 and b >= chunk_len
    assert srv._padded_batch(1) == 4  # at least one row per device
    assert srv._padded_batch(5) == 8
    # a non-pow2 max_batch still rounds up to the mesh multiple
    srv = _host_server(n_shards=4, max_batch=6)
    assert srv._padded_batch(6) % 4 == 0


def test_place_rows_balances_and_covers():
    srv = _host_server(n_shards=4)
    weights = [4, 3, 3, 2, 2, 1, 1]
    rows, loads = srv._place_rows(weights, 8)
    placed = [i for i in rows if i is not None]
    assert sorted(placed) == list(range(len(weights)))  # exactly once each
    assert len(rows) == 8
    # per-shard blocks are contiguous halves of the padded batch
    per = 8 // 4
    block_loads = [sum(weights[i] for i in rows[s * per:(s + 1) * per]
                       if i is not None) for s in range(4)]
    assert block_loads == loads
    # greedy LPT: no shard exceeds the lightest by more than one bucket
    assert max(loads) - min(loads) <= max(weights)
    assert sum(loads) == sum(weights)


def test_place_rows_identity_for_single_shard():
    srv = _host_server(n_shards=1)
    rows, loads = srv._place_rows([2, 1, 3], 4)
    assert rows == [0, 1, 2, None]  # the pre-mesh dispatch layout
    assert loads == [6]


# ------------------------------------------------------------- engine parity


@pytest.mark.parametrize("k", MESH_SIZES)
def test_engine_parity_across_mesh_sizes(setup, k):
    """Every batched entry point under a k-way mesh matches the unsharded
    engine per document: codes/tokens exact, activations to tolerance."""
    _need(k)
    cfg, params, neng, base = setup
    eng = BatchedJitEngine({}, cfg, edit_capacity=4, row_capacity=32,
                           mesh=make_serving_mesh(k), _weights=base.weights)
    assert eng.n_shards == k
    rng = np.random.default_rng(0)
    B, n = 4, 16
    toks = rng.integers(0, cfg.vocab, (B, n)).astype(np.int32)
    poss = np.tile(np.arange(n, dtype=np.int32) * 5, (B, 1))
    st = eng.batch_full_forward(jnp.asarray(toks), jnp.asarray(poss))
    st0 = base.batch_full_forward(jnp.asarray(toks), jnp.asarray(poss))
    np.testing.assert_array_equal(np.asarray(st.codes), np.asarray(st0.codes))
    np.testing.assert_allclose(np.asarray(st.x), np.asarray(st0.x), atol=1e-5)

    slot = jnp.asarray([[1, 5, -1, -1]] * B, jnp.int32)
    tok = jnp.asarray([[7, 9, 0, 0]] * B, jnp.int32)
    s1, o1 = eng.batch_apply_replaces(st, slot, tok)
    s0, o0 = base.batch_apply_replaces(st0, slot, tok)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o0))
    np.testing.assert_array_equal(np.asarray(s1.codes), np.asarray(s0.codes))
    np.testing.assert_allclose(np.asarray(s1.x), np.asarray(s0.x), atol=1e-5)

    e1, e0 = eng.batch_export_kv(s1), base.batch_export_kv(s0)
    np.testing.assert_array_equal(np.asarray(e1.order), np.asarray(e0.order))
    np.testing.assert_array_equal(np.asarray(e1.tokens), np.asarray(e0.tokens))
    np.testing.assert_allclose(np.asarray(e1.k), np.asarray(e0.k), atol=1e-5)

    idx = jnp.asarray([n - 1] * B, jnp.int32)
    np.testing.assert_allclose(np.asarray(eng.batch_logits_at(s1, idx)),
                               np.asarray(base.batch_logits_at(s0, idx)),
                               atol=1e-4)


def test_engine_rejects_indivisible_batch(setup):
    _need(2)
    cfg, params, neng, base = setup
    eng = BatchedJitEngine({}, cfg, edit_capacity=4, row_capacity=32,
                           mesh=make_serving_mesh(2), _weights=base.weights)
    toks = jnp.zeros((3, 8), jnp.int32)
    poss = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (3, 1))
    with pytest.raises(ValueError, match="does not divide"):
        eng.batch_full_forward(toks, poss)


def test_engine_rejects_missing_batch_axis(setup):
    cfg, params, neng, base = setup
    with pytest.raises(ValueError, match="no axis"):
        BatchedJitEngine({}, cfg, mesh=make_serving_mesh(1, axis="batchy"),
                         _weights=base.weights)


def test_server_rejects_indivisible_max_batch(setup):
    """max_batch must be a multiple of the mesh batch axis, else a full
    chunk's padded dispatch would exceed the documented cap."""
    _need(2)
    cfg, params, neng, base = setup
    with pytest.raises(ValueError, match="not a multiple"):
        BatchServer(params, cfg, max_batch=3, mesh=make_serving_mesh(2))


# ---------------------------------------------------------- server end-to-end


def _mixed_stream(srv: BatchServer, cfg, seed: int, n_docs: int, n_ops: int,
                  suggest_doc=None, n_new: int = 4):
    rng = np.random.default_rng(seed)
    ref = {}
    for i in range(n_docs):
        n = int(rng.integers(10, 15))
        toks = rng.integers(0, cfg.vocab, n)
        ref[f"d{i}"] = list(toks)
        srv.open_document(f"d{i}", toks)
    if suggest_doc is not None:
        srv.submit_suggest(suggest_doc, n_new)
    for _ in range(n_ops):
        did = f"d{int(rng.integers(n_docs))}"
        r = ref[did]
        kind = rng.choice(["replace", "insert", "delete"], p=[0.5, 0.3, 0.2])
        if kind == "insert":
            p, t = int(rng.integers(len(r) + 1)), int(rng.integers(cfg.vocab))
            srv.submit_insert(did, p, t)
            r.insert(p, t)
        elif kind == "delete" and len(r) > 1:
            p = int(rng.integers(len(r)))
            srv.submit_delete(did, p)
            del r[p]
        else:
            p, t = int(rng.integers(len(r))), int(rng.integers(cfg.vocab))
            srv.submit_replace(did, p, t)
            r[p] = t
        if rng.random() < 0.3:
            srv.step()
    srv.flush()
    return ref


def _assert_server_matches_numpy(srv, ref, neng, atol=3e-4):
    for did, r in ref.items():
        assert list(srv.tokens(did)) == r, did
        doc = srv.docs[did]
        ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
        sl = np.asarray(doc.slots)
        for li in range(len(neng.layers)):
            np.testing.assert_array_equal(
                np.asarray(doc.state.codes[li])[sl], ns.layers[li].codes)
        np.testing.assert_allclose(srv.logits(did), neng.logits_at(ns),
                                   atol=atol)


@pytest.mark.parametrize("k", MESH_SIZES)
def test_server_differential_vs_numpy(setup, k):
    """End-to-end: mixed edit streams + a suggestion subscription over a
    k-way mesh; final tokens/codes/logits match the NumPy oracle and the
    suggestion equals the from-scratch decode oracle."""
    _need(k)
    cfg, params, neng, base = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=4, min_doc_capacity=16, pos_pool=2048,
                      mesh=make_serving_mesh(k))
    ref = _mixed_stream(srv, cfg, seed=6, n_docs=4, n_ops=40,
                        suggest_doc="d0")
    assert srv.pending_count() == 0
    assert srv.stats.edits_applied == srv.stats.edits_submitted
    if k > 1:
        assert srv.stats.sharded_dispatches > 0
    _assert_server_matches_numpy(srv, ref, neng)
    sugg = srv.suggest("d0", 4)
    doc = srv.docs["d0"]
    oracle_eng = JitIncrementalEngine({}, cfg, edit_capacity=4,
                                      row_capacity=16, _weights=base.weights)
    ora = oracle_suggestion(params, cfg, oracle_eng, doc.tokens,
                            doc.positions, doc.valid, 4)
    np.testing.assert_array_equal(sugg, ora)


@pytest.mark.parametrize("k", MESH_SIZES)
def test_server_defrag_and_grow_under_mesh(setup, k):
    """Forced slow paths stay exact over a mesh: a tiny position pool drives
    defrag, a tiny slot buffer drives grow; both re-ingest per document."""
    _need(k)
    cfg, params, neng, base = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=k, min_doc_capacity=8, pos_pool=64,
                      mesh=make_serving_mesh(k))
    rng = np.random.default_rng(7)
    ref = {}
    for i in range(k):  # one doc per shard: slow paths fire on every device
        toks = rng.integers(0, cfg.vocab, 7)
        ref[f"d{i}"] = list(toks)
        srv.open_document(f"d{i}", toks)
    for _ in range(8):  # hammer one insertion point -> defrag; fill -> grow
        for i in range(k):
            t = int(rng.integers(cfg.vocab))
            srv.submit_insert(f"d{i}", 3, t)
            ref[f"d{i}"].insert(3, t)
        srv.flush()
    assert srv.stats.defrags >= 1
    assert srv.stats.grows >= 1
    _assert_server_matches_numpy(srv, ref, neng)


def test_mesh1_bit_exact_vs_premesh(setup):
    """A size-1 mesh must reproduce the mesh=None scheduler bit-for-bit:
    same dispatch layout, same compiled steps, bitwise-identical states."""
    cfg, params, neng, base = setup
    srv_a = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                        max_batch=4, min_doc_capacity=16, pos_pool=2048)
    srv_b = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                        max_batch=4, min_doc_capacity=16, pos_pool=2048,
                        mesh=make_serving_mesh(1))
    ref_a = _mixed_stream(srv_a, cfg, seed=11, n_docs=3, n_ops=24,
                          suggest_doc="d1")
    ref_b = _mixed_stream(srv_b, cfg, seed=11, n_docs=3, n_ops=24,
                          suggest_doc="d1")
    assert ref_a == ref_b
    assert srv_b.stats.sharded_dispatches == 0
    for did in ref_a:
        doc_a, doc_b = srv_a.docs[did], srv_b.docs[did]
        np.testing.assert_array_equal(doc_a.tokens, doc_b.tokens)
        np.testing.assert_array_equal(doc_a.positions, doc_b.positions)
        for leaf_a, leaf_b in zip(doc_a.state, doc_b.state):
            np.testing.assert_array_equal(np.asarray(leaf_a),
                                          np.asarray(leaf_b))
    np.testing.assert_array_equal(srv_a.suggestion("d1"),
                                  srv_b.suggestion("d1"))


def test_forced_multidevice_subprocess():
    """mesh>1 coverage even in a single-device environment: force 4 host
    devices in a subprocess (the flag must precede jax init) and run a
    compact server-vs-NumPy differential there."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax, numpy as np
        from repro.configs.vq_opt_125m import smoke_config
        from repro.core.incremental import IncrementalEngine
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as T
        from repro.serving.batch_server import BatchServer

        assert jax.device_count() == 4
        cfg = smoke_config(vqt=True)
        params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
        neng = IncrementalEngine(params, cfg)
        srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                          max_batch=4, min_doc_capacity=16, pos_pool=2048,
                          mesh=make_serving_mesh())
        rng = np.random.default_rng(0)
        ref = {}
        for i in range(4):
            toks = rng.integers(0, cfg.vocab, int(rng.integers(10, 14)))
            ref[f"d{i}"] = list(toks)
            srv.open_document(f"d{i}", toks)
        for _ in range(12):
            did = f"d{int(rng.integers(4))}"
            r = ref[did]
            kind = rng.choice(["replace", "insert", "delete"], p=[.5, .3, .2])
            if kind == "insert":
                p, t = int(rng.integers(len(r) + 1)), int(rng.integers(cfg.vocab))
                srv.submit_insert(did, p, t); r.insert(p, t)
            elif kind == "delete" and len(r) > 1:
                p = int(rng.integers(len(r)))
                srv.submit_delete(did, p); del r[p]
            else:
                p, t = int(rng.integers(len(r))), int(rng.integers(cfg.vocab))
                srv.submit_replace(did, p, t); r[p] = t
        srv.flush()
        assert srv.stats.sharded_dispatches > 0
        for did, r in ref.items():
            assert list(srv.tokens(did)) == r
            doc = srv.docs[did]
            ns = neng.full_forward(doc.seq_tokens(), doc.seq_positions())
            np.testing.assert_allclose(srv.logits(did), neng.logits_at(ns),
                                       atol=3e-4)
        print("SHARDED-OK")
        """
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr
    assert "SHARDED-OK" in res.stdout
