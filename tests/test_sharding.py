"""Sharding rules + a subprocess mini dry-run (8 fake devices).

The full 512-device matrix runs via ``python -m repro.launch.dryrun --all``;
here we verify the machinery end-to-end at a tractable size. The subprocess
is required because the device-count override must happen before jax init.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import all_arch_names, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import param_shardings


@pytest.mark.parametrize("arch", all_arch_names())
def test_param_shardings_cover_all_leaves(arch):
    from functools import partial

    import jax.numpy as jnp

    from repro.models import transformer as T

    cfg = get_config(arch, smoke=True)
    mesh = make_host_mesh()
    sds = jax.eval_shape(partial(T.init_params, cfg=cfg, dtype=jnp.float32),
                         jax.random.PRNGKey(0))
    sh = param_shardings(sds, mesh)
    leaves_a = jax.tree.leaves(sds)
    leaves_b = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves_a) == len(leaves_b)
    for sd, ns in zip(leaves_a, leaves_b):
        # every sharded dim must divide (host mesh is 1x1 so trivially true;
        # the rule itself is exercised against the production mesh below)
        assert len(ns.spec) <= len(sd.shape)


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile a reduced arch on an 8-device (2,4)+(2,2,2) mesh pair in
    a subprocess with forced host devices — the real dry-run in miniature."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        from functools import partial
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed.context import use_mesh
        from repro.launch.sharding import batch_shardings, param_shardings
        from repro.models import transformer as T
        from repro.training import make_schedule, make_train_step, train_state_init

        out = {}
        for axes, shape in [(("data", "model"), (2, 4)),
                            (("pod", "data", "model"), (2, 2, 2))]:
            mesh = jax.make_mesh(shape, axes)
            cfg = get_config("deepseek-v2-236b", smoke=True)
            with use_mesh(mesh):
                state_sds = jax.eval_shape(
                    partial(train_state_init, cfg=cfg), jax.random.PRNGKey(0))
                batch_sds = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
                step = make_train_step(cfg, make_schedule(
                    peak_lr=1e-3, warmup_steps=1, total_steps=10))
                lowered = jax.jit(step, in_shardings=(
                    param_shardings(state_sds, mesh),
                    batch_shardings(batch_sds, mesh))).lower(state_sds, batch_sds)
                compiled = lowered.compile()
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):  # older jax: one per device
                    ca = ca[0]
                out["x".join(map(str, shape))] = float(ca.get("flops", 0))
        print(json.dumps(out))
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    assert res["2x4"] > 0 and res["2x2x2"] > 0
