"""Tiered document-state store (ISSUE 5): eviction, persistence, rehydration.

The contract under test (DESIGN.md §7): device state is a pure function of
its snapshot, so a document that was evicted to host RAM (warm) or disk
(cold) and touched again is **bit-exact** against one that never left the
device — rehydration is a re-upload, never a recompute. Suggestion decode
caches are soft state: dropping them changes nothing token-level. And
``close_document`` is the true inverse of ``open_document``: open→edit→
suggest→close churn leaks no slots, no allocator state, no caches, no bytes.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer
from repro.serving.jit_engine import (
    state_nbytes, state_nbytes_for, state_from_host, state_to_host,
)
from repro.serving.state_store import DeviceBudgetError

DOC_LEN = 12
N_CAP = 16  # next_pow2(DOC_LEN, min_doc_capacity=16)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _server(cfg, params, **kw):
    kw.setdefault("edit_capacity", 4)
    kw.setdefault("row_capacity", 32)
    kw.setdefault("max_batch", 2)
    kw.setdefault("min_doc_capacity", 16)
    return BatchServer(params, cfg, **kw)


def _open_docs(srv, cfg, n_docs, seed=0, doc_len=DOC_LEN):
    rng = np.random.default_rng(seed)
    docs = {f"d{i}": list(rng.integers(0, cfg.vocab, doc_len))
            for i in range(n_docs)}
    srv.open_documents({d: list(t) for d, t in docs.items()})
    return docs


def _doc_bytes(srv):
    eng = srv.engine(srv.C, srv.R)
    return state_nbytes_for(N_CAP, eng.L, eng.meta)


def _reconcile(srv):
    """Recount every byte/doc stat from the underlying objects and assert
    the store-maintained counters match exactly (the BatchStats memory-
    blindness satellite)."""
    s = srv.stats
    tiers = srv.store.tiers()
    assert set(tiers) == set(srv.docs)
    hot = [d for d, t in tiers.items() if t == "hot"]
    warm = [d for d, t in tiers.items() if t == "warm"]
    cold = [d for d, t in tiers.items() if t == "cold"]
    assert (s.docs_hot, s.docs_warm, s.docs_cold) == \
        (len(hot), len(warm), len(cold))
    assert s.bytes_hot == sum(state_nbytes(srv.docs[d].state) for d in hot)
    for d in hot:
        assert srv.docs[d].state is not None
    for d in warm + cold:
        assert srv.docs[d].state is None
    assert s.bytes_warm == sum(srv.store.nbytes(d) for d in warm)
    assert s.bytes_cold == sum(srv.store.nbytes(d) for d in cold)
    if srv._sugg is not None:
        assert s.bytes_suggest == sum(
            srv._sugg.cache_nbytes(k) for k in srv._sugg.cached_keys())
    else:
        assert s.bytes_suggest == 0
    assert s.state_touches == s.hot_hits + s.rehydrations + s.rollback_rebuilds


# ---------------------------------------------------------------- accounting


def test_state_nbytes_formula_matches(setup):
    cfg, params = setup
    srv = _server(cfg, params)
    _open_docs(srv, cfg, 1)
    doc = srv.docs["d0"]
    eng = srv.engine(srv.C, srv.R)
    assert state_nbytes(doc.state) == state_nbytes_for(
        doc.n_cap, eng.L, eng.meta)
    _reconcile(srv)


def test_stats_reconcile(setup, tmp_path):
    """Byte/doc counters reconcile after every kind of movement: ingest,
    edits, suggestion caches, forced warm and cold evictions, rehydration,
    grow (an n_cap-doubling re-ingest changes the footprint), close."""
    cfg, params = setup
    srv = _server(cfg, params, spill_dir=str(tmp_path))
    _open_docs(srv, cfg, 3)
    _reconcile(srv)
    srv.submit_replace("d0", 2, 5)
    srv.submit_insert("d1", 0, 9)
    srv.flush()
    _reconcile(srv)
    srv.suggest("d0", 4)
    srv.suggest("d1", 4)
    _reconcile(srv)
    assert srv.stats.bytes_suggest > 0
    srv.evict("d0", "warm")
    _reconcile(srv)
    assert srv.stats.evictions == 1
    srv.evict("d1", "cold")
    _reconcile(srv)
    assert srv.stats.spills == 1 and srv.stats.bytes_cold > 0
    srv.submit_replace("d1", 1, 3)  # cold doc: next dispatch rehydrates
    srv.flush()
    _reconcile(srv)
    assert srv.tier("d1") == "hot" and srv.stats.rehydrations >= 1
    # grow d2 past its slot capacity: the doubled footprint is recounted
    before = srv.store.nbytes("d2")
    for i in range(N_CAP):
        srv.submit_insert("d2", 0, 1)
    srv.flush()
    eng = srv.engine(srv.C, srv.R)
    assert srv.stats.grows >= 1
    assert srv.store.nbytes("d2") == state_nbytes_for(
        srv.docs["d2"].n_cap, eng.L, eng.meta) > before
    _reconcile(srv)
    srv.close_document("d0")
    srv.close_document("d1")
    srv.close_document("d2")
    _reconcile(srv)
    assert srv.stats.bytes_hot == srv.stats.bytes_warm == \
        srv.stats.bytes_cold == srv.stats.bytes_suggest == 0


def test_close_document_no_leak(setup):
    """open→edit→suggest→close in a loop at small capacity grows nothing:
    no document objects, no store entries, no suggestion caches, no bytes —
    and a long-lived bystander document's allocator and slot map are
    untouched (extends the PR 4 allocator rollback leak test)."""
    cfg, params = setup
    srv = _server(cfg, params)
    _open_docs(srv, cfg, 1, seed=7)  # the long-lived bystander
    srv.suggest("d0", 4)
    base = srv.docs["d0"]
    base_alloc = base.allocator.snapshot().copy()
    base_free = list(base.free)
    baseline = (srv.stats.bytes_hot, len(srv.docs),
                len(srv.suggester.cached_keys()))
    rng = np.random.default_rng(3)
    for i in range(4):
        did = f"churn{i}"
        srv.open_document(did, list(rng.integers(0, cfg.vocab, DOC_LEN)))
        srv.submit_insert(did, 0, 2)
        srv.submit_replace(did, 3, 4)
        srv.submit_delete(did, 1)
        srv.flush()
        srv.suggest(did, 4)
        srv.close_document(did)
        assert (srv.stats.bytes_hot, len(srv.docs),
                len(srv.suggester.cached_keys())) == baseline
        assert did not in srv.store
        _reconcile(srv)
    assert srv.stats.closes == 4
    np.testing.assert_array_equal(base.allocator.snapshot(), base_alloc)
    assert list(base.free) == base_free
    with pytest.raises(KeyError):
        srv.close_document("churn0")  # double-close / unknown id


# ---------------------------------------------------------------- residency


def test_rehydration_is_bit_exact(setup, tmp_path):
    """Warm and cold round-trips reproduce logits and state leaves bit-for-
    bit — no recompute, no float drift."""
    cfg, params = setup
    srv = _server(cfg, params, spill_dir=str(tmp_path))
    _open_docs(srv, cfg, 2, seed=1)
    srv.submit_insert("d0", 2, 11)
    srv.flush()
    ref_logits = srv.logits("d0")
    ref_state = state_to_host(srv.docs["d0"].state)
    for tier in ("warm", "cold"):
        srv.evict("d0", tier)
        assert srv.tier("d0") == tier and srv.docs["d0"].state is None
        got = srv.logits("d0")  # transparent rehydration on touch
        assert srv.tier("d0") == "hot"
        np.testing.assert_array_equal(got, ref_logits)
        for a, b in zip(state_to_host(srv.docs["d0"].state), ref_state):
            np.testing.assert_array_equal(a, b)
    # spill files are removed on rehydration
    assert os.listdir(str(tmp_path)) == []


def test_state_host_roundtrip_helpers(setup):
    cfg, params = setup
    srv = _server(cfg, params)
    _open_docs(srv, cfg, 1, seed=2)
    state = srv.docs["d0"].state
    host = state_to_host(state)
    back = state_from_host(host)
    for a, b in zip(state, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert state_nbytes(host) == state_nbytes(state)


def test_budget_evicts_lru_and_pins_hold(setup):
    """A device budget of ~2 documents: opening a third evicts the least-
    recently-touched; a pinned document survives; pinning everything makes
    the next admission fail loudly."""
    cfg, params = setup
    srv = _server(cfg, params)
    _open_docs(srv, cfg, 1)
    per = _doc_bytes(srv)

    srv2 = _server(cfg, params, device_budget_bytes=int(2.4 * per),
                   max_batch=1)
    _open_docs(srv2, cfg, 2, seed=4)
    assert srv2.stats.evictions == 0
    srv2.pin("d1")
    srv2.open_document("d2", list(np.arange(DOC_LEN) % cfg.vocab))
    # d0 (LRU, unpinned) was evicted; pinned d1 stayed hot
    assert srv2.tier("d0") == "warm"
    assert srv2.tier("d1") == "hot" and srv2.tier("d2") == "hot"
    _reconcile(srv2)
    srv2.pin("d2")
    with pytest.raises(DeviceBudgetError):
        srv2.open_document("d3", list(np.arange(DOC_LEN) % cfg.vocab))
    assert "d3" not in srv2.docs
    srv2.unpin("d1")
    srv2.open_document("d3", list(np.arange(DOC_LEN) % cfg.vocab))
    assert srv2.tier("d1") == "warm" and srv2.tier("d3") == "hot"
    _reconcile(srv2)
    # edits on the evicted docs rehydrate transparently and stay correct
    srv2.submit_replace("d0", 0, 1)
    srv2.submit_replace("d1", 0, 1)
    srv2.flush()
    assert srv2.stats.rehydrations >= 2
    _reconcile(srv2)


def test_suggest_cache_is_soft_state(setup):
    """Decode caches are dropped before any document state is evicted, and
    a dropped cache changes nothing token-level."""
    cfg, params = setup
    srv = _server(cfg, params)
    _open_docs(srv, cfg, 1, seed=5)
    want = srv.suggest("d0", 4)
    assert srv.suggester.cache_nbytes("d0") > 0
    srv.store._drop_suggest("d0")
    assert srv.suggester.cache_nbytes("d0") == 0
    assert srv.stats.bytes_suggest == 0
    srv.docs["d0"].suggest_fresh = False  # force a refresh without the cache
    got = srv.suggest("d0", 4)
    np.testing.assert_array_equal(got, want)
    _reconcile(srv)


def test_failed_dispatch_on_evicted_doc_rolls_back_to_void(setup):
    """The rollback corner: a doc enters a take evicted, the take's grow
    re-ingest consumes its warm copy, and then the dispatch fails. Rollback
    must not raise (other docs in the round depend on it finishing) and
    must not lose the doc: it lands in the 'void' residency state and the
    next touch rebuilds it from the restored mirrors — final tokens and
    logits bitwise-match a server that never failed."""
    cfg, params = setup
    toks = list(np.arange(N_CAP) % cfg.vocab)  # fills n_cap: insert => grow

    oracle = _server(cfg, params)
    oracle.open_document("d", list(toks))
    oracle.submit_insert("d", 0, 3)
    oracle.flush()

    srv = _server(cfg, params)
    srv.open_document("d", list(toks))
    srv.evict("d", "warm")
    srv.submit_insert("d", 0, 3)
    eng = srv.engine(srv.C, srv.docs["d"].row_capacity)
    orig = eng.batch_apply_inserts
    eng.batch_apply_inserts = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("injected dispatch failure"))
    try:
        with pytest.raises(RuntimeError, match="injected"):
            srv.step()
    finally:
        eng.batch_apply_inserts = orig
    # rolled back: mirrors pre-take, edit still queued, residency = void
    assert srv.tier("d") == "void" and srv.docs["d"].state is None
    assert list(srv.docs["d"].pending) == [("insert", 0, 3)]
    np.testing.assert_array_equal(srv.docs["d"].seq_tokens(), toks)
    # next touch rebuilds from the restored mirrors (full forward)...
    assert srv.store.ensure_hot(srv.docs["d"]) is not None
    assert srv.tier("d") == "hot" and srv.stats.rollback_rebuilds == 1
    srv.flush()  # ...and the still-queued edit applies normally
    np.testing.assert_array_equal(srv.tokens("d"), oracle.tokens("d"))
    np.testing.assert_array_equal(srv.logits("d"), oracle.logits("d"))
    _reconcile(srv)


# ------------------------------------------------------ differential churn


def test_tiered_churn_matches_unbounded_oracle(setup, tmp_path):
    """The acceptance harness: a mixed edit+suggest stream over more
    documents than the device budget admits — with forced warm AND cold
    evictions interleaved between edits — produces logits bit-identical and
    suggestions token-identical to an unbounded-budget oracle server, and
    closing every document leaks nothing."""
    cfg, params = setup
    probe = _server(cfg, params)
    _open_docs(probe, cfg, 1)
    per = _doc_bytes(probe)

    spill = str(tmp_path / "spill")
    srv = _server(cfg, params, device_budget_bytes=int(2.6 * per),
                  host_budget_bytes=int(1.2 * per), spill_dir=spill)
    oracle = _server(cfg, params)  # unbounded: everything stays hot
    n_docs = 4
    _open_docs(srv, cfg, n_docs, seed=6)
    refs = _open_docs(oracle, cfg, n_docs, seed=6)
    refs = {d: list(t) for d, t in refs.items()}
    assert srv.stats.evictions > 0, "budget must force evictions at open"

    rng = np.random.default_rng(9)
    forced = ["warm", "cold"]
    for t in range(10):
        did = f"d{int(rng.integers(n_docs))}"
        n = len(refs[did])
        op = ["replace", "insert", "delete"][int(rng.integers(3))]
        if op == "delete" and n <= 2:
            op = "replace"
        if op == "replace":
            pos, tok = int(rng.integers(n)), int(rng.integers(cfg.vocab))
            srv.submit_replace(did, pos, tok)
            oracle.submit_replace(did, pos, tok)
            refs[did][pos] = tok
        elif op == "insert":
            pos, tok = int(rng.integers(n + 1)), int(rng.integers(cfg.vocab))
            srv.submit_insert(did, pos, tok)
            oracle.submit_insert(did, pos, tok)
            refs[did].insert(pos, tok)
        else:
            pos = int(rng.integers(n))
            srv.submit_delete(did, pos)
            oracle.submit_delete(did, pos)
            del refs[did][pos]
        # force extra churn: demote some OTHER unpinned doc between edits
        victim = f"d{(int(did[1:]) + 1 + t % (n_docs - 1)) % n_docs}"
        if srv.tier(victim) == "hot":
            srv.evict(victim, forced[t % 2])
        srv.flush()
        oracle.flush()
        np.testing.assert_array_equal(srv.tokens(did), refs[did])
        np.testing.assert_array_equal(srv.logits(did), oracle.logits(did))
        if t % 3 == 0:
            s_t = srv.suggest(did, 4)
            s_o = oracle.suggest(did, 4)
            np.testing.assert_array_equal(s_t, s_o)
        _reconcile(srv)

    st = srv.stats
    assert st.evictions > 0 and st.spills > 0 and st.rehydrations > 0
    assert st.hot_hit_rate < 1.0
    assert oracle.stats.evictions == oracle.stats.rehydrations == 0
    # final sweep: every document bit-identical to the oracle
    for did in refs:
        np.testing.assert_array_equal(srv.tokens(did), refs[did])
        np.testing.assert_array_equal(srv.logits(did), oracle.logits(did))
    # teardown leaks nothing: no bytes, no spill files, no caches
    for did in list(srv.docs):
        srv.close_document(did)
    assert len(srv.docs) == 0
    assert st.bytes_hot == st.bytes_warm == st.bytes_cold == 0
    assert st.bytes_suggest == 0
    assert srv._sugg is None or srv._sugg.cached_keys() == []
    assert not os.path.isdir(spill) or os.listdir(spill) == []
