"""Differential harness for suggestion decoding (ISSUE 3): after EVERY edit
of a mixed insert/delete/replace stream, the ``SuggestionEngine``'s greedy
continuation — computed with edited-prefix reuse (KV export + re-prefill
from the earliest invalidated position) — must equal a from-scratch
full-recompute decode oracle, token for token.

Three rungs, mirroring the mixed-edit-stream parity ladder:

1. engine level — raw ``JitIncrementalEngine.apply_*`` steps with a
   host-managed slot map, refresh after each edit;
2. server level — ``BatchServer`` suggestion subscriptions over randomized
   mixed streams, including forced buffer growth;
3. forced defrag — a tiny position pool drives id re-spreads (and the
   suggestion engine's own headroom-defrag path); parity must survive the
   total loss of reuse.

Property-mode (hypothesis, via the ``_hypothesis_compat`` shim) fuzzes the
stream seeds; the deterministic seeded tests below keep real coverage on
bare interpreters where hypothesis degrades to skips.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

_UID = itertools.count()  # unique doc/cache keys across hypothesis examples

from repro.configs.vq_opt_125m import smoke_config
from repro.models import transformer as T
from repro.serving.batch_server import BatchServer
from repro.serving.jit_engine import JitIncrementalEngine
from repro.serving.suggest import SuggestionEngine, oracle_suggestion

N_NEW = 4
POOL = 2048


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(vqt=True)
    params = jax.device_get(T.init_params(jax.random.PRNGKey(1), cfg))
    jeng = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=16)
    sugg = SuggestionEngine(params, cfg)
    oracle = SuggestionEngine(params, cfg)
    return cfg, params, jeng, sugg, oracle


class _SlotDoc:
    """Host-side slot-buffer mirror for engine-level streams."""

    def __init__(self, cfg, rng, n, n_cap, pool=POOL):
        self.pool = pool
        self.tokens = np.zeros(n_cap, np.int32)
        self.tokens[:n] = rng.integers(0, cfg.vocab, n)
        self.positions = np.full(n_cap, pool - 1, np.int32)
        self.positions[:n] = (np.arange(1, n + 1) * pool) // (n + 1)
        self.valid = np.zeros(n_cap, bool)
        self.valid[:n] = True
        self.slots = list(range(n))
        self.free = list(range(n_cap - 1, n - 1, -1))

    def seq_positions(self):
        return self.positions[np.asarray(self.slots, np.int64)]


def _engine_edit(cfg, jeng, js, doc, rng):
    """One random edit through ``apply_edits``; returns (js, edited pid) or
    (js, None) when the drawn edit was impossible (exhausted gap)."""
    pad = jnp.asarray([-1, -1, -1], jnp.int32)
    kind = rng.choice(["replace", "insert", "delete"])
    nn = len(doc.slots)
    seq_pos = doc.seq_positions()
    if kind == "insert" and doc.free:
        p = int(rng.integers(nn + 1))
        t = int(rng.integers(cfg.vocab))
        lo = seq_pos[p - 1] if p > 0 else -1
        hi = seq_pos[p] if p < nn else doc.pool
        if hi - lo <= 1:
            return js, None
        pid = int((lo + hi) // 2)
        s = doc.free.pop()
        doc.slots.insert(p, s)
        doc.tokens[s] = t
        doc.positions[s] = pid
        doc.valid[s] = True
        js, ovf = jeng.apply_inserts(
            js, jnp.concatenate([jnp.asarray([s], jnp.int32), pad]),
            jnp.asarray([t, 0, 0, 0], jnp.int32),
            jnp.asarray([pid, 0, 0, 0], jnp.int32))
    elif kind == "delete" and nn > 2:
        p = int(rng.integers(nn))
        s = doc.slots.pop(p)
        doc.free.append(s)
        doc.valid[s] = False
        pid = int(doc.positions[s])
        js, ovf = jeng.apply_deletes(
            js, jnp.concatenate([jnp.asarray([s], jnp.int32), pad]))
    else:
        p = int(rng.integers(nn))
        t = int(rng.integers(cfg.vocab))
        s = doc.slots[p]
        doc.tokens[s] = t
        pid = int(doc.positions[s])
        js, ovf = jeng.apply_replaces(
            js, jnp.concatenate([jnp.asarray([s], jnp.int32), pad]),
            jnp.asarray([t, 0, 0, 0], jnp.int32))
    assert not bool(ovf)
    return js, pid


def _run_engine_stream(setup, seed, n_edits=10, key=None):
    cfg, params, jeng, sugg, oracle = setup
    rng = np.random.default_rng(seed)
    doc = _SlotDoc(cfg, rng, n=int(rng.integers(8, 13)), n_cap=16)
    js = jeng.full_forward(jnp.asarray(doc.tokens), jnp.asarray(doc.positions),
                           jnp.asarray(doc.valid))
    key = key or f"eng-{seed}-{next(_UID)}"
    s0 = sugg.refresh(jeng, js, key=key, n_new=N_NEW)
    o0 = oracle_suggestion(params, cfg, jeng, doc.tokens, doc.positions,
                           doc.valid, N_NEW, suggester=oracle)
    np.testing.assert_array_equal(s0, o0)
    touched = None
    applied = 0
    while applied < n_edits:
        js, pid = _engine_edit(cfg, jeng, js, doc, rng)
        if pid is None:
            continue
        applied += 1
        touched = pid if touched is None else min(touched, pid)
        got = sugg.refresh(jeng, js, key=key, n_new=N_NEW, invalid_from=pid,
                           export_invalid_from=touched)
        want = oracle_suggestion(params, cfg, jeng, doc.tokens, doc.positions,
                                 doc.valid, N_NEW, suggester=oracle)
        np.testing.assert_array_equal(got, want, err_msg=f"edit {applied}")
    assert sugg.stats.prefill_rows_reused > 0  # the reuse path was exercised


# ------------------------------------------------------------- engine level


def test_engine_stream_suggestions_match_oracle(setup):
    _run_engine_stream(setup, seed=0)


def test_engine_stream_second_seed(setup):
    _run_engine_stream(setup, seed=7)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(100, 2**31 - 1))
def test_engine_stream_suggestions_property(setup, seed):
    _run_engine_stream(setup, seed=seed, n_edits=6)


def test_stale_prefix_detection_falls_back(setup):
    """A wrong ``invalid_from`` watermark (claiming an edited prefix is
    clean) must be caught by the cached-prefix token/position check, not
    silently served."""
    cfg, params, jeng, sugg, oracle = setup
    rng = np.random.default_rng(3)
    doc = _SlotDoc(cfg, rng, n=10, n_cap=16)
    js = jeng.full_forward(jnp.asarray(doc.tokens), jnp.asarray(doc.positions),
                           jnp.asarray(doc.valid))
    sugg.refresh(jeng, js, key="stale", n_new=N_NEW)
    # replace the FIRST token but claim nothing before the last position id
    # changed: the engine must notice the cached prefix no longer matches
    s = doc.slots[0]
    doc.tokens[s] = (doc.tokens[s] + 1) % cfg.vocab
    pad = jnp.asarray([-1, -1, -1], jnp.int32)
    js, ovf = jeng.apply_replaces(
        js, jnp.concatenate([jnp.asarray([s], jnp.int32), pad]),
        jnp.asarray([int(doc.tokens[s]), 0, 0, 0], jnp.int32))
    assert not bool(ovf)
    lying_watermark = int(doc.seq_positions()[-1])
    got = sugg.refresh(jeng, js, key="stale", n_new=N_NEW,
                       invalid_from=lying_watermark,
                       export_invalid_from=lying_watermark)
    want = oracle_suggestion(params, cfg, jeng, doc.tokens, doc.positions,
                             doc.valid, N_NEW, suggester=oracle)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------- server level


def _run_server_stream(setup, srv, rng, ref, doc_id, n_edits, oracle_eng):
    cfg, params, jeng, sugg, oracle = setup
    for i in range(n_edits):
        r = ref[doc_id]
        kind = rng.choice(["replace", "insert", "delete"], p=[0.4, 0.4, 0.2])
        if kind == "insert":
            p = int(rng.integers(len(r) + 1))
            t = int(rng.integers(cfg.vocab))
            srv.submit_insert(doc_id, p, t)
            r.insert(p, t)
        elif kind == "delete" and len(r) > 2:
            p = int(rng.integers(len(r)))
            srv.submit_delete(doc_id, p)
            del r[p]
        else:
            p = int(rng.integers(len(r)))
            t = int(rng.integers(cfg.vocab))
            srv.submit_replace(doc_id, p, t)
            r[p] = t
        # a newer edit invalidates the pending suggestion
        assert srv.suggestion(doc_id) is None
        got = srv.suggest(doc_id, N_NEW)
        assert list(srv.tokens(doc_id)) == r
        doc = srv.docs[doc_id]
        want = oracle_suggestion(params, cfg, oracle_eng, doc.tokens,
                                 doc.positions, doc.valid, N_NEW,
                                 suggester=oracle)
        np.testing.assert_array_equal(got, want, err_msg=f"edit {i}")
        # served and fresh until the next edit
        np.testing.assert_array_equal(srv.suggestion(doc_id), got)


@pytest.fixture(scope="module")
def server(setup):
    cfg, params, jeng, sugg, oracle = setup
    return BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                       max_batch=4, min_doc_capacity=8, pos_pool=POOL)


def test_server_stream_with_grow_matches_oracle(setup, server):
    """Mixed randomized stream over a min-capacity-8 doc: inserts force a
    slot-buffer grow (n_cap doubling re-ingest) mid-stream; suggestion
    parity and freshness semantics must survive it."""
    cfg, params, jeng, sugg, oracle = setup
    rng = np.random.default_rng(11)
    ref = {"g": list(rng.integers(0, cfg.vocab, 7))}
    server.open_document("g", ref["g"])
    server.submit_suggest("g", N_NEW)
    _run_server_stream(setup, server, rng, ref, "g", 16, jeng)
    assert server.stats.grows >= 1  # the stream genuinely grew the buffer
    # every edit after the first refresh staled a fresh suggestion
    assert server.stats.suggest_invalidations >= 15
    assert server.suggest_stats.prefill_rows_reused > 0


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_server_stream_property(setup, server, seed):
    cfg, params, jeng, sugg, oracle = setup
    rng = np.random.default_rng(seed)
    doc_id = f"p{seed}-{next(_UID)}"
    ref = {doc_id: list(rng.integers(0, cfg.vocab, int(rng.integers(6, 12))))}
    server.open_document(doc_id, ref[doc_id])
    _run_server_stream(setup, server, rng, ref, doc_id, 6, jeng)


def test_server_forced_defrag_matches_oracle(setup):
    """A tiny position pool exhausts insertion gaps: ids re-spread (defrag +
    full re-ingest), the suggestion cache drops wholesale, and parity must
    hold with zero reuse."""
    cfg, params, jeng, sugg, oracle = setup
    srv = BatchServer(params, cfg, edit_capacity=4, row_capacity=16,
                      max_batch=2, min_doc_capacity=16, pos_pool=64)
    rng = np.random.default_rng(13)
    r = list(rng.integers(0, cfg.vocab, 8))
    srv.open_document("d", r)
    srv.submit_suggest("d", N_NEW)
    deng = JitIncrementalEngine(params, cfg, edit_capacity=4, row_capacity=16,
                                _weights=jeng.weights)
    for i in range(7):
        t = int(rng.integers(cfg.vocab))
        srv.submit_insert("d", 3, t)
        r.insert(3, t)
        got = srv.suggest("d", N_NEW)
        assert list(srv.tokens("d")) == r
        doc = srv.docs["d"]
        want = oracle_suggestion(params, cfg, deng, doc.tokens, doc.positions,
                                 doc.valid, N_NEW, suggester=oracle)
        np.testing.assert_array_equal(got, want, err_msg=f"insert {i}")
    assert srv.stats.defrags >= 1
