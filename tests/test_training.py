"""Training substrate: optimizer, schedules, train/distill steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vq_opt_125m import smoke_config
from repro.data import SyntheticCorpus, lm_batches
from repro.training import (
    adamw_init, adamw_update, make_distill_step, make_schedule, make_train_step,
    train_state_init,
)


def test_schedule_warmup_and_decay():
    s = make_schedule(peak_lr=1e-3, warmup_steps=10, total_steps=100, final_lr=1e-4)
    lrs = [float(s(jnp.asarray(i))) for i in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1e-3) < 1e-9  # peak at end of warmup
    assert lrs[3] < lrs[2]  # decaying
    assert abs(lrs[4] - 1e-4) < 1e-6  # final


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = adamw_update(params, grads, state, jnp.asarray(2e-2))
    assert float(jnp.abs(params["w"]).max()) < 0.3


@pytest.mark.slow
def test_train_loss_decreases():
    cfg = smoke_config(vqt=True)
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(
        cfg, make_schedule(peak_lr=5e-4, warmup_steps=5, total_steps=60)))
    corpus = SyntheticCorpus(vocab=cfg.vocab, seed=0)
    losses = []
    for batch in lm_batches(corpus, batch=8, seq_len=64, steps=40,
                            pos_pool=cfg.pos_pool):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        state, m = step(state, b)
        losses.append(float(m["lm_loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses[::8]


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    cfg = smoke_config(vqt=False)
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    sched = make_schedule(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": tokens}
    s1, m1 = jax.jit(make_train_step(cfg, sched, accum_steps=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, sched, accum_steps=4))(state, batch)
    # same data, same rng -> same loss and near-identical update
    d = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params))
    )
    assert d < 5e-3, d


def test_distill_step_runs_and_reduces_kl():
    teacher_cfg = smoke_config(vqt=False)
    student_cfg = smoke_config(vqt=True)
    teacher = train_state_init(jax.random.PRNGKey(0), teacher_cfg).params
    state = train_state_init(jax.random.PRNGKey(1), student_cfg)
    step = jax.jit(make_distill_step(
        student_cfg, teacher_cfg,
        make_schedule(peak_lr=1e-3, warmup_steps=2, total_steps=40)))
    corpus = SyntheticCorpus(vocab=student_cfg.vocab, seed=0)
    kls = []
    for batch in lm_batches(corpus, batch=4, seq_len=48, steps=25,
                            pos_pool=student_cfg.pos_pool):
        b = {"tokens": jnp.asarray(batch["tokens"]),
             "positions": jnp.asarray(batch["positions"])}
        state, m = step(state, teacher, b)
        kls.append(float(m["kl"]))
    assert np.isfinite(kls).all()
    assert np.mean(kls[-5:]) < np.mean(kls[:5]), kls[::5]
