"""Vector-quantization module tests (paper §3, §4)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import vq as V


def _params(key, d, heads, q=64):
    cfg = V.VQConfig(n_heads=heads, codebook_size=q)
    return V.init(key, d, cfg), cfg


def test_assign_is_nearest():
    key = jax.random.PRNGKey(0)
    params, cfg = _params(key, 16, 2, q=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    idx = V.assign(params, x)
    xh = x.reshape(32, 2, 8)
    d2 = jnp.sum(
        (xh[:, :, None, :] - params.codebook[None]) ** 2, axis=-1
    )  # [n, h, q]
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(jnp.argmin(d2, -1)))


def test_quantize_idempotent():
    """VQ(VQ(x)) == VQ(x): codebook vectors quantize to themselves."""
    key = jax.random.PRNGKey(0)
    params, cfg = _params(key, 16, 2, q=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 16))
    xq, idx = V.quantize(params, x)
    xq2, idx2 = V.quantize(params, xq)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))
    np.testing.assert_allclose(np.asarray(xq), np.asarray(xq2), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(heads=st.sampled_from([1, 2, 4]), q=st.sampled_from([4, 64]),
       seed=st.integers(0, 1000))
def test_combined_code_roundtrip(heads, q, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, q, (5, 7, heads)), jnp.int32)
    code = V.combined_code(idx, q)
    back = V.split_code(code, q, heads)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(idx))


def test_train_mode_gradients_flow():
    key = jax.random.PRNGKey(0)
    params, cfg = _params(key, 16, 2, q=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 16))

    def loss(p, x):
        xq, idx, aux = V.forward_train(p, x, cfg, rng=jax.random.PRNGKey(2))
        return jnp.sum(xq ** 2) + aux

    gp, gx = jax.grad(loss, argnums=(0, 1))(params, x)
    assert np.isfinite(np.asarray(gx)).all()
    assert np.isfinite(np.asarray(gp.codebook)).all()
    assert float(jnp.abs(gx).sum()) > 0  # straight-through passes gradient
    assert float(jnp.abs(gp.codebook).sum()) > 0


def test_eval_equals_hard_assignment_of_train_mode():
    key = jax.random.PRNGKey(0)
    params, cfg = _params(key, 8, 2, q=16)
    x = jax.random.normal(jax.random.PRNGKey(3), (11, 8))
    _, idx_train, _ = V.forward_train(params, x, cfg, rng=None)  # no gumbel noise
    _, idx_eval = V.quantize(params, x)
    np.testing.assert_array_equal(np.asarray(idx_train), np.asarray(idx_eval))
