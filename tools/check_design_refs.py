"""Lint: every ``DESIGN.md §<id>`` citation must resolve to a real heading.

The codebase cites design sections from docstrings, comments, tests and
benchmarks ("the causal mask argument, DESIGN.md §10"). Those citations
are load-bearing documentation — a renumbered or deleted section silently
orphans every pointer to it. This lint closes the loop:

* **headings** — ``## §<id>`` lines in DESIGN.md define the valid ids
  (numeric like ``§9`` or named like ``§Arch-applicability``);
* **citations** — ``DESIGN.md §<id>`` anywhere under src/, tests/,
  benchmarks/, examples/, tools/ (*.py) plus the top-level *.md files;
* a citation whose id has no matching heading fails the lint with
  file:line coordinates.

CI runs this next to ruff (see .github/workflows/ci.yml). Run locally::

    python tools/check_design_refs.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HEADING_RE = re.compile(r"^##\s+§([\w-]+)", re.MULTILINE)
CITATION_RE = re.compile(r"DESIGN\.md\s+§([\w-]+)")
CODE_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
# ISSUE.md is deliberately absent: it is the transient per-PR task spec
# and may cite sections it is ASKING to be written
TOP_DOCS = ("README.md", "ROADMAP.md", "CHANGES.md", "DESIGN.md",
            "PAPER.md", "PAPERS.md", "SNIPPETS.md")


def headings(design_path: str) -> set[str]:
    with open(design_path, encoding="utf-8") as f:
        return set(HEADING_RE.findall(f.read()))


def citation_files() -> list[str]:
    files = []
    for d in CODE_DIRS:
        for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, d)):
            dirnames[:] = [n for n in dirnames if n != "__pycache__"]
            files += [os.path.join(dirpath, n) for n in filenames
                      if n.endswith(".py")]
    files += [p for n in TOP_DOCS
              if os.path.exists(p := os.path.join(ROOT, n))]
    return sorted(files)


def main() -> int:
    design = os.path.join(ROOT, "DESIGN.md")
    valid = headings(design)
    if not valid:
        print(f"check_design_refs: no '## §' headings found in {design}")
        return 1
    dangling = []
    n_citations = 0
    for path in citation_files():
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                for m in CITATION_RE.finditer(line):
                    n_citations += 1
                    if m.group(1) not in valid:
                        rel = os.path.relpath(path, ROOT)
                        dangling.append(
                            f"{rel}:{lineno}: DESIGN.md §{m.group(1)} "
                            "does not match any '## §' heading")
    for d in dangling:
        print(d)
    if dangling:
        print(f"check_design_refs: {len(dangling)} dangling citation(s) "
              f"(valid sections: {', '.join(sorted(valid))})")
        return 1
    print(f"check_design_refs: {n_citations} citations across "
          f"{len(valid)} sections, all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
